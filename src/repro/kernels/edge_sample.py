"""Pallas kernel: fused stratified edge sampling (Alg. 2 inner loop).

The jnp reference path materializes a [S, b_max] grid of draws, gathered
values and f-evaluations in HBM — for S = 16 Ki strata and b_max = 8 Ki that
is gigabytes of traffic for what is mathematically a streaming reduction.
This kernel fuses draw -> gather -> f -> per-stratum (n, sum f, sum f^2) so
only [S_BLOCK, b_max] tiles ever exist, in VMEM, and only the [S] statistics
go back to HBM.  That turns the sampling stage from memory-bound to
VPU-bound — the TPU restatement of the paper's "sampling beats building the
bipartite graph" insight.

Batched layout (one slot per query of an engine batch): every operand has a
leading slot dimension and the grid is 2-D over ``(batch_slot,
strata_block)``.  Per grid step (slot ``b``, strata block of S_BLOCK rows):

  * both sides' sorted value arrays are VMEM-resident PER SLOT (the
    BlockSpec index map pins slot ``b``'s whole array to ``(b, 0)``); the
    per-draw gather is segment-local by construction (rows are sorted by
    key) but may touch anywhere in the array, so residency is required —
    the wrapper asserts the <= ~8 MiB budget over ALL slots (stacked
    layout, covering Pallas' cross-slot double buffering) and production
    shards relations below it.
  * per-stratum scalars (key, start/count per side, b_i, joinable) stream
    as [1, S_BLOCK] slices.
  * per-slot seeds are runtime array operands (one-element VMEM blocks):
    one compiled executable serves every seed of a mixed-seed batch.
  * draws are the [S_BLOCK, b_max] tile: counter-hash PRNG (same uint32
    math as core.hashing — bit-identical to the oracle), modulo into the
    segment, gather, f, masked reduce along draws.

Two-way joins only (the paper's hot case); n-way falls back to the jnp path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import bounded, counter_hash

S_BLOCK = 128
VMEM_VALUES_LIMIT = 8 * 1024 * 1024


def _kernel(seed_ref, v1_ref, v2_ref, keys_ref, s1_ref, c1_ref, s2_ref,
            c2_ref, join_ref, bi_ref, n_ref, sf_ref, sf2_ref,
            *, b_max: int, expr: str):
    seed = seed_ref[0]                  # this slot's seed (runtime operand)
    keys = keys_ref[...][0][:, None]                   # [Sb, 1]
    t = jnp.arange(b_max, dtype=jnp.uint32)[None, :]   # [1, b_max]
    h1 = counter_hash(seed, keys, t, 0)
    h2 = counter_hash(seed, keys, t, 1)
    c1 = jnp.maximum(c1_ref[...][0], 1)[:, None]
    c2 = jnp.maximum(c2_ref[...][0], 1)[:, None]
    i1 = s1_ref[...][0][:, None] + bounded(h1, c1)
    i2 = s2_ref[...][0][:, None] + bounded(h2, c2)
    v1 = v1_ref[...][0][i1]                            # [Sb, b_max] gather
    v2 = v2_ref[...][0][i2]
    fv = v1 * v2 if expr == "product" else v1 + v2
    tf = jnp.arange(b_max, dtype=jnp.float32)[None, :]
    mask = (tf < bi_ref[...][0][:, None]) & join_ref[...][0][:, None]
    fm = jnp.where(mask, fv, 0.0)
    n_ref[...] = jnp.sum(mask, axis=1, dtype=jnp.float32)[None]
    sf_ref[...] = jnp.sum(fm, axis=1)[None]
    sf2_ref[...] = jnp.sum(fm * fm, axis=1)[None]


def edge_sample_batched(values1: jnp.ndarray, values2: jnp.ndarray,
                        keys: jnp.ndarray,
                        start1: jnp.ndarray, count1: jnp.ndarray,
                        start2: jnp.ndarray, count2: jnp.ndarray,
                        joinable: jnp.ndarray, b_i: jnp.ndarray,
                        seeds: jnp.ndarray, b_max: int, expr: str = "sum",
                        interpret: bool = True):
    """Per-slot per-stratum (n_sampled, sum_f, sum_f2), each float32 [B, S].

    Values are ``[B, n_side]``; per-stratum operands ``[B, S]`` with
    ``S % S_BLOCK == 0`` (wrapper pads); ``seeds`` uint32 ``[B]``.
    """
    B, S = keys.shape
    assert S % S_BLOCK == 0, f"pad strata to a multiple of {S_BLOCK}"
    assert seeds.shape == (B,), (seeds.shape, B)
    for v in (values1, values2):
        assert v.shape[0] == B, (v.shape, B)
        assert v.shape[0] * v.shape[1] * 4 <= VMEM_VALUES_LIMIT, \
            "stacked values too large for VMEM residency: " \
            f"{v.shape[0] * v.shape[1] * 4} bytes"
    n1, n2 = values1.shape[1], values2.shape[1]
    col = pl.BlockSpec((1, S_BLOCK), lambda b, i: (b, i))
    out = jax.ShapeDtypeStruct((B, S), jnp.float32)
    return pl.pallas_call(
        functools.partial(_kernel, b_max=b_max, expr=expr),
        grid=(B, S // S_BLOCK),
        in_specs=[pl.BlockSpec((1,), lambda b, i: (b,)),
                  pl.BlockSpec((1, n1), lambda b, i: (b, 0)),  # pinned/slot
                  pl.BlockSpec((1, n2), lambda b, i: (b, 0)),
                  col, col, col, col, col, col, col],
        out_specs=[col, col, col],
        out_shape=[out, out, out],
        interpret=interpret,
    )(seeds, values1, values2, keys, start1, count1, start2, count2,
      joinable, b_i)


def edge_sample(values1: jnp.ndarray, values2: jnp.ndarray,
                keys: jnp.ndarray,
                start1: jnp.ndarray, count1: jnp.ndarray,
                start2: jnp.ndarray, count2: jnp.ndarray,
                joinable: jnp.ndarray, b_i: jnp.ndarray,
                b_max: int, seed=0, expr: str = "sum",
                interpret: bool = True):
    """Per-stratum (n_sampled, sum_f, sum_f2), each float32 [S].

    Single-slot convenience over :func:`edge_sample_batched` (B = 1).
    """
    seeds = jnp.asarray(seed, jnp.uint32).reshape(1)
    n, sf, sf2 = edge_sample_batched(
        values1[None], values2[None], keys[None], start1[None], count1[None],
        start2[None], count2[None], joinable[None], b_i[None], seeds,
        b_max, expr, interpret=interpret)
    return n[0], sf[0], sf2[0]
