"""Pallas kernel: fused stratified edge sampling (Alg. 2 inner loop).

The jnp reference path materializes a [S, b_max] grid of draws, gathered
values and f-evaluations in HBM — for S = 16 Ki strata and b_max = 8 Ki that
is gigabytes of traffic for what is mathematically a streaming reduction.
This kernel fuses draw -> gather -> f -> per-stratum (n, sum f, sum f^2) so
only [S_BLOCK, b_max] tiles ever exist, in VMEM, and only the [S] statistics
go back to HBM.  That turns the sampling stage from memory-bound to
VPU-bound — the TPU restatement of the paper's "sampling beats building the
bipartite graph" insight.

Layout per grid step (strata block of S_BLOCK rows):
  * both sides' sorted value arrays are VMEM-resident (pinned BlockSpec);
    the per-draw gather is segment-local by construction (rows are sorted by
    key) but may touch anywhere in the array, so residency is required —
    the wrapper asserts the <= ~8 MiB per side budget and production shards
    relations below it (a 1 Mi-row shard = 4 MiB).
  * per-stratum scalars (key, start/count per side, b_i, joinable) stream as
    [S_BLOCK] slices.
  * draws are the [S_BLOCK, b_max] tile: counter-hash PRNG (same uint32 math
    as core.hashing — bit-identical to the oracle), modulo into the segment,
    gather, f, masked reduce along draws.

Two-way joins only (the paper's hot case); n-way falls back to the jnp path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import bounded, counter_hash

S_BLOCK = 128
VMEM_VALUES_LIMIT = 8 * 1024 * 1024


def _kernel(v1_ref, v2_ref, keys_ref, s1_ref, c1_ref, s2_ref, c2_ref,
            join_ref, bi_ref, n_ref, sf_ref, sf2_ref,
            *, b_max: int, seed: int, expr: str):
    keys = keys_ref[...][:, None]                      # [Sb, 1]
    t = jnp.arange(b_max, dtype=jnp.uint32)[None, :]   # [1, b_max]
    h1 = counter_hash(seed, keys, t, 0)
    h2 = counter_hash(seed, keys, t, 1)
    i1 = s1_ref[...][:, None] + bounded(h1, jnp.maximum(c1_ref[...], 1)[:, None])
    i2 = s2_ref[...][:, None] + bounded(h2, jnp.maximum(c2_ref[...], 1)[:, None])
    v1 = v1_ref[...][i1]                               # [Sb, b_max] VMEM gather
    v2 = v2_ref[...][i2]
    fv = v1 * v2 if expr == "product" else v1 + v2
    tf = jnp.arange(b_max, dtype=jnp.float32)[None, :]
    mask = (tf < bi_ref[...][:, None]) & join_ref[...][:, None]
    fm = jnp.where(mask, fv, 0.0)
    n_ref[...] = jnp.sum(mask, axis=1, dtype=jnp.float32)
    sf_ref[...] = jnp.sum(fm, axis=1)
    sf2_ref[...] = jnp.sum(fm * fm, axis=1)


def edge_sample(values1: jnp.ndarray, values2: jnp.ndarray,
                keys: jnp.ndarray,
                start1: jnp.ndarray, count1: jnp.ndarray,
                start2: jnp.ndarray, count2: jnp.ndarray,
                joinable: jnp.ndarray, b_i: jnp.ndarray,
                b_max: int, seed: int = 0, expr: str = "sum",
                interpret: bool = True):
    """Per-stratum (n_sampled, sum_f, sum_f2), each float32 [S].

    S must be a multiple of S_BLOCK (wrapper pads); values arrays are whole.
    """
    S = keys.shape[0]
    assert S % S_BLOCK == 0, f"pad strata to a multiple of {S_BLOCK}"
    for v in (values1, values2):
        assert v.shape[0] * 4 <= VMEM_VALUES_LIMIT, \
            f"values too large for VMEM residency: {v.shape[0] * 4} bytes"
    n1, n2 = values1.shape[0], values2.shape[0]
    col = pl.BlockSpec((S_BLOCK,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((S,), jnp.float32)
    return pl.pallas_call(
        functools.partial(_kernel, b_max=b_max, seed=seed, expr=expr),
        grid=(S // S_BLOCK,),
        in_specs=[pl.BlockSpec((n1,), lambda i: (0,)),   # pinned values
                  pl.BlockSpec((n2,), lambda i: (0,)),
                  col, col, col, col, col, col, col],
        out_specs=[col, col, col],
        out_shape=[out, out, out],
        interpret=interpret,
    )(values1, values2, keys, start1, count1, start2, count2, joinable, b_i)
