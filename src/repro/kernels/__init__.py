"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §7):

  bloom_build  — filter hash computation (scatter-OR commit in the wrapper)
  bloom_probe  — VMEM-resident join-filter membership probe (per-tuple hot path)
  edge_sample  — fused Algorithm-2 sampler (draw -> gather -> f -> reduce)

``ops`` holds the jit'd wrappers; ``ref`` the pure-jnp oracles.  Validated in
interpret mode on CPU; Mosaic-compiled on a TPU backend.
"""
