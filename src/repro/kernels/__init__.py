"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §7):

  bloom_build  — filter hash computation (scatter-OR commit in the wrapper)
  bloom_probe  — VMEM-resident join-filter membership probe (per-tuple hot path)
  edge_sample  — fused Algorithm-2 sampler (draw -> gather -> f -> reduce)

Every kernel is BATCHED: a leading slot dimension (one slot per query of a
serving-engine batch) with a 2-D grid over ``(batch_slot, block)``, stacked
``[B, num_blocks, 8]`` filters with per-slot VMEM residency, and per-slot
seeds as runtime array operands — one compiled executable per shape class,
zero recompiles across seeds.  The single-query entry points are the B = 1
specialization of the same kernels.

``ops`` holds the jit'd wrappers (and ALL padding); ``ref`` the pure-jnp
oracles.  Validated in interpret mode on CPU; Mosaic-compiled on a TPU
backend.
"""
