"""jit'd public wrappers for the Pallas kernels.

ALL padding lives here: the raw kernels in ``bloom_build``/``bloom_probe``/
``edge_sample`` hard-assert block-multiple shapes, and every wrapper pads its
operands up to those multiples and truncates the results back — so padded
tail keys/strata can never flip a result (property-tested for pow2 and
non-pow2 lengths in ``tests/test_kernels.py``).  The wrappers also handle
the scatter-OR commit for the build kernel, StratumStats assembly for the
sampler, and the interpret-mode switch (this container is CPU-only; on a TPU
backend the kernels compile to Mosaic).

Seeds are RUNTIME ARRAY OPERANDS throughout — never static jit arguments —
so one compiled executable per shape class serves every seed (N distinct
seeds used to cost N compiles; now they cost one, asserted in the tests and
``serve_bench --kernels``).  Each ``*_batched`` wrapper takes slot-stacked
inputs with a leading batch dimension and a ``[B]`` seed vector, matching
the serving engine's fused-batch layout; the single-query wrappers are the
``B = 1`` specialization of the same kernels.

Every wrapper has a pure-jnp oracle in ``kernels/ref.py`` and the swap is
tested bit-exact.
"""

from __future__ import annotations

import functools
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom
from repro.core.estimators import StratumStats
from repro.core.relation import Relation
from repro.core.sampling import Strata
from repro.kernels import bloom_build as _build
from repro.kernels import bloom_probe as _probe
from repro.kernels import edge_sample as _edge


def use_interpret() -> bool:
    """Pallas interpret mode unless we are actually on TPU (env-overridable)."""
    forced = os.environ.get("REPRO_PALLAS_INTERPRET")
    if forced is not None:
        return forced not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad1(x: jnp.ndarray, mult: int, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


def _pad2(x: jnp.ndarray, mult: int, fill=0):
    """Pad axis 1 (the per-slot axis of a slot-stacked operand)."""
    n = x.shape[1]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full(x.shape[:1] + (pad,) + x.shape[2:], fill, x.dtype)],
        axis=1)


def _seedvec(seed) -> jnp.ndarray:
    """Seed -> uint32 [1] runtime operand.  Host ints wrap mod 2^32 HERE
    (before jit tracing, which would overflow on ints >= 2^31); traced
    arrays pass straight through."""
    if isinstance(seed, (int, np.integer)):
        seed = np.uint32(int(seed) & 0xFFFFFFFF)
    return jnp.asarray(seed, jnp.uint32).reshape(1)


# ---------------------------------------------------------------------------
# Filter build
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_blocks", "interpret"))
def build_filter_batched(keys: jnp.ndarray, valid: jnp.ndarray,
                         num_blocks: int, seeds: jnp.ndarray,
                         interpret: bool = True) -> jnp.ndarray:
    """Kernel-backed per-slot bloom build: packed words uint32 [B, nb, 8].

    ``keys``/``valid`` are slot-stacked ``[B, N]``; ``seeds`` uint32 ``[B]``
    runtime operands (zero recompiles across seeds).
    """
    n = keys.shape[1]
    kp = _pad2(keys, _build.DEFAULT_BLOCK)
    blk, masks = _build.bloom_hashes_batched(kp, seeds, num_blocks,
                                             interpret=interpret)
    commit = jax.vmap(
        lambda b, m, v: bloom.scatter_or(b, m, v, num_blocks).words)
    return commit(blk[:, :n], masks[:, :n], valid)


def build_filter(keys: jnp.ndarray, valid: jnp.ndarray, num_blocks: int,
                 seed=0, interpret: bool = True) -> bloom.BloomFilter:
    """Kernel-backed bloom.build: hash kernel + XLA scatter-OR commit.

    Unjitted shim over the jitted batched kernel (B = 1): the seed
    normalizes to a uint32 operand HERE, outside any trace, so host ints of
    any magnitude work and jit callers can pass traced seeds through.
    """
    words = build_filter_batched(keys[None], valid[None], num_blocks,
                                 _seedvec(seed), interpret=interpret)[0]
    return bloom.BloomFilter(words, seed)


# ---------------------------------------------------------------------------
# Filter probe
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("interpret",))
def probe_filter_batched(words: jnp.ndarray, keys: jnp.ndarray,
                         seeds: jnp.ndarray,
                         interpret: bool = True) -> jnp.ndarray:
    """Kernel-backed per-slot membership probe: bool [B, N].

    ``words`` is the stacked ``[B, nb, 8]`` filter layout (each slot probes
    its OWN filter — the engine's mixed-tenant batch), keys ``[B, N]``,
    ``seeds`` uint32 ``[B]``.
    """
    n = keys.shape[1]
    kp = _pad2(keys, _probe.DEFAULT_BLOCK)
    return _probe.bloom_probe_batched(words, kp, seeds,
                                      interpret=interpret)[:, :n]


def probe_filter(words: jnp.ndarray, keys: jnp.ndarray, seed=0,
                 interpret: bool = True) -> jnp.ndarray:
    """Kernel-backed bloom.contains (unjitted B = 1 shim, see build_filter)."""
    return probe_filter_batched(words[None], keys[None], _seedvec(seed),
                                interpret=interpret)[0]


# ---------------------------------------------------------------------------
# Fused edge sampler
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("b_max", "expr", "interpret"))
def sample_stats_batched(values1: jnp.ndarray, values2: jnp.ndarray,
                         strata_keys: jnp.ndarray,
                         starts: jnp.ndarray, counts: jnp.ndarray,
                         joinable: jnp.ndarray, population: jnp.ndarray,
                         b_i: jnp.ndarray, seeds: jnp.ndarray, b_max: int,
                         expr: str = "sum",
                         interpret: bool = True) -> StratumStats:
    """Kernel-backed per-slot Algorithm-2 pass: StratumStats with [B, S]
    leaves.  ``starts``/``counts`` are ``[B, 2, S]``; ``seeds`` uint32 [B]."""
    S = strata_keys.shape[1]
    pad = functools.partial(_pad2, mult=_edge.S_BLOCK)
    n, sf, sf2 = _edge.edge_sample_batched(
        values1, values2,
        pad(strata_keys), pad(starts[:, 0]), pad(counts[:, 0]),
        pad(starts[:, 1]), pad(counts[:, 1]),
        pad(joinable), pad(b_i.astype(jnp.float32)),
        seeds, b_max, expr, interpret=interpret)
    return StratumStats(valid=joinable, population=population,
                        n_sampled=n[:, :S], sum_f=sf[:, :S],
                        sum_f2=sf2[:, :S])


def sample_stats_2way(values1: jnp.ndarray, values2: jnp.ndarray,
                      strata_keys: jnp.ndarray,
                      starts: jnp.ndarray, counts: jnp.ndarray,
                      joinable: jnp.ndarray, population: jnp.ndarray,
                      b_i: jnp.ndarray, b_max: int, seed=0,
                      expr: str = "sum",
                      interpret: bool = True) -> StratumStats:
    """Kernel-backed two-way Algorithm-2 pass returning StratumStats
    (unjitted B = 1 shim, see build_filter)."""
    stats = sample_stats_batched(
        values1[None], values2[None], strata_keys[None], starts[None],
        counts[None], joinable[None], population[None], b_i[None],
        _seedvec(seed), b_max, expr, interpret=interpret)
    return jax.tree_util.tree_map(lambda x: x[0], stats)


def sample_stats(sorted_rels: Sequence[Relation], strata: Strata,
                 b_i: jnp.ndarray, b_max: int, seed=0,
                 expr: str = "sum", interpret: bool | None = None) -> StratumStats:
    """Convenience: Strata-level entry point (two-way only)."""
    assert len(sorted_rels) == 2, "kernel path is two-way; use core.sampling"
    if interpret is None:
        interpret = use_interpret()
    return sample_stats_2way(
        sorted_rels[0].values, sorted_rels[1].values,
        strata.keys, strata.starts, strata.counts,
        strata.joinable, strata.population,
        b_i, b_max, seed, expr, interpret)
