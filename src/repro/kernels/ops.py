"""jit'd public wrappers for the Pallas kernels.

Handles padding to block multiples, the scatter-OR commit for the build
kernel, StratumStats assembly for the sampler, and the interpret-mode switch
(this container is CPU-only; on a TPU backend the kernels compile to Mosaic).
Every wrapper has a pure-jnp oracle in ``kernels/ref.py`` and the swap is
tested bit-exact.
"""

from __future__ import annotations

import functools
import os
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import bloom
from repro.core.estimators import StratumStats
from repro.core.relation import Relation
from repro.core.sampling import Strata
from repro.kernels import bloom_build as _build
from repro.kernels import bloom_probe as _probe
from repro.kernels import edge_sample as _edge


def use_interpret() -> bool:
    """Pallas interpret mode unless we are actually on TPU (env-overridable)."""
    forced = os.environ.get("REPRO_PALLAS_INTERPRET")
    if forced is not None:
        return forced not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad1(x: jnp.ndarray, mult: int, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


@functools.partial(jax.jit, static_argnames=("num_blocks", "seed", "interpret"))
def build_filter(keys: jnp.ndarray, valid: jnp.ndarray, num_blocks: int,
                 seed: int = 0, interpret: bool = True) -> bloom.BloomFilter:
    """Kernel-backed bloom.build: hash kernel + XLA scatter-OR commit."""
    n = keys.shape[0]
    kp = _pad1(keys, _build.DEFAULT_BLOCK)
    blk, masks = _build.bloom_hashes(kp, num_blocks, seed,
                                     interpret=interpret)
    return bloom.scatter_or(blk[:n], masks[:n], valid, num_blocks, seed)


@functools.partial(jax.jit, static_argnames=("seed", "interpret"))
def probe_filter(words: jnp.ndarray, keys: jnp.ndarray, seed: int = 0,
                 interpret: bool = True) -> jnp.ndarray:
    """Kernel-backed bloom.contains."""
    n = keys.shape[0]
    kp = _pad1(keys, _probe.DEFAULT_BLOCK)
    return _probe.bloom_probe(words, kp, seed, interpret=interpret)[:n]


@functools.partial(jax.jit,
                   static_argnames=("b_max", "seed", "expr", "interpret"))
def sample_stats_2way(values1: jnp.ndarray, values2: jnp.ndarray,
                      strata_keys: jnp.ndarray,
                      starts: jnp.ndarray, counts: jnp.ndarray,
                      joinable: jnp.ndarray, population: jnp.ndarray,
                      b_i: jnp.ndarray, b_max: int, seed: int = 0,
                      expr: str = "sum",
                      interpret: bool = True) -> StratumStats:
    """Kernel-backed two-way Algorithm-2 pass returning StratumStats."""
    S = strata_keys.shape[0]
    pad = functools.partial(_pad1, mult=_edge.S_BLOCK)
    n, sf, sf2 = _edge.edge_sample(
        values1, values2,
        pad(strata_keys), pad(starts[0]), pad(counts[0]),
        pad(starts[1]), pad(counts[1]),
        pad(joinable), pad(b_i.astype(jnp.float32)),
        b_max, seed, expr, interpret=interpret)
    return StratumStats(valid=joinable, population=population,
                        n_sampled=n[:S], sum_f=sf[:S], sum_f2=sf2[:S])


def sample_stats(sorted_rels: Sequence[Relation], strata: Strata,
                 b_i: jnp.ndarray, b_max: int, seed: int = 0,
                 expr: str = "sum", interpret: bool | None = None) -> StratumStats:
    """Convenience: Strata-level entry point (two-way only)."""
    assert len(sorted_rels) == 2, "kernel path is two-way; use core.sampling"
    if interpret is None:
        interpret = use_interpret()
    return sample_stats_2way(
        sorted_rels[0].values, sorted_rels[1].values,
        strata.keys, strata.starts, strata.counts,
        strata.joinable, strata.population,
        b_i, b_max, seed, expr, interpret)
