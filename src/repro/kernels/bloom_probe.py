"""Pallas kernel: join-filter membership probe (the filter hot path).

Every tuple of every input probes the join filter once (§3.1), so this is
the paper's dominant per-tuple cost.  Layout:

  * the packed filter ([num_blocks, 8] uint32) stays RESIDENT in VMEM across
    the whole grid (BlockSpec index_map pins it to (0, 0)) — it is small by
    construction (Eq. 27: ~1.2 bytes/key at 1% FPR) and every key touches one
    random 256-bit block of it, which is exactly what VMEM is for;
  * keys stream through in [BLOCK] slices (double-buffered by Pallas);
  * per key: one VMEM gather of its 8-word block + lane-mask compare — no
    HBM round-trips per probe, unlike the GPU pointer-chase formulation.

VMEM budget: filter <= ~8 MiB (num_blocks <= 2^18 = 8 Mi keys at 1% FPR per
shard) + 3 small key/output blocks.  The wrapper asserts this.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bloom

DEFAULT_BLOCK = 2048
VMEM_FILTER_LIMIT = 8 * 1024 * 1024  # bytes of VMEM we allow the filter


def _kernel(words_ref, keys_ref, out_ref, *, num_blocks: int, seed: int):
    keys = keys_ref[...]
    blk = bloom.block_index(keys, num_blocks, seed)
    masks = bloom.lane_masks(keys, seed)
    words = words_ref[...]              # [num_blocks, 8], VMEM-resident
    gathered = words[blk]               # [BLOCK, 8] vector gather in VMEM
    out_ref[...] = jnp.all((gathered & masks) == masks, axis=-1)


def bloom_probe(words: jnp.ndarray, keys: jnp.ndarray, seed: int = 0,
                block: int = DEFAULT_BLOCK,
                interpret: bool = True) -> jnp.ndarray:
    """Membership mask bool [N] for keys against the packed filter words."""
    n = keys.shape[0]
    nb = words.shape[0]
    assert n % block == 0, f"pad keys to a multiple of {block} (got {n})"
    assert nb * 8 * 4 <= VMEM_FILTER_LIMIT, \
        f"filter too large for VMEM residency: {nb * 32} bytes"
    return pl.pallas_call(
        functools.partial(_kernel, num_blocks=nb, seed=seed),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((nb, 8), lambda i: (0, 0)),  # pinned filter
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(words, keys)
