"""Pallas kernel: join-filter membership probe (the filter hot path).

Every tuple of every input probes the join filter once (§3.1), so this is
the paper's dominant per-tuple cost.  Batched layout (one slot per query of
an engine batch, 2-D grid over ``(batch_slot, key_block)``):

  * the packed filters are STACKED ``[B, num_blocks, 8]`` uint32 with
    per-slot VMEM residency: the BlockSpec index map pins slot ``b``'s
    ``[num_blocks, 8]`` filter to ``(b, 0, 0)``, so it stays resident across
    that slot's whole key sweep and is swapped exactly once per slot — it is
    small by construction (Eq. 27: ~1.2 bytes/key at 1% FPR) and every key
    touches one random 256-bit block of it, which is exactly what VMEM is
    for;
  * keys stream through in ``[1, BLOCK]`` slices (double-buffered by
    Pallas);
  * per-slot seeds are runtime array operands (one-element VMEM blocks), so
    one compiled executable serves every seed of a mixed-seed batch;
  * per key: one VMEM gather of its 8-word block + lane-mask compare — no
    HBM round-trips per probe, unlike the GPU pointer-chase formulation.

VMEM budget: the whole stacked filter must fit, ``B * filter_bytes`` <= ~8
MiB (e.g. 8 slots of num_blocks <= 2^15 = 1 Mi keys each at 1% FPR per
shard) + small key/seed/output blocks.  The wrapper asserts this — the
budget is deliberately charged for ALL slots even though only one is
resident per grid step, covering Pallas' cross-slot double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bloom

DEFAULT_BLOCK = 2048
VMEM_FILTER_LIMIT = 8 * 1024 * 1024  # bytes of VMEM we allow the filters


def _kernel(seed_ref, words_ref, keys_ref, out_ref, *, num_blocks: int):
    seed = seed_ref[0]                  # this slot's seed (runtime operand)
    keys = keys_ref[...]                # [1, BLOCK]
    blk = bloom.block_index(keys, num_blocks, seed)
    masks = bloom.lane_masks(keys, seed)
    words = words_ref[...][0]           # [num_blocks, 8], VMEM-resident
    gathered = words[blk[0]]            # [BLOCK, 8] vector gather in VMEM
    out_ref[...] = jnp.all((gathered & masks[0]) == masks[0], axis=-1)[None]


def bloom_probe_batched(words: jnp.ndarray, keys: jnp.ndarray,
                        seeds: jnp.ndarray, block: int = DEFAULT_BLOCK,
                        interpret: bool = True) -> jnp.ndarray:
    """Membership mask bool [B, N]: each slot's keys against its own filter.

    ``words`` is the stacked ``[B, num_blocks, 8]`` filter layout; ``seeds``
    is uint32 ``[B]`` (runtime operands — zero recompiles across seeds).
    """
    B, n = keys.shape
    nb = words.shape[1]
    assert words.shape[0] == B and seeds.shape == (B,), \
        (words.shape, keys.shape, seeds.shape)
    assert n % block == 0, f"pad keys to a multiple of {block} (got {n})"
    assert B * nb * 8 * 4 <= VMEM_FILTER_LIMIT, \
        f"stacked filters too large for VMEM residency: {B * nb * 32} bytes"
    return pl.pallas_call(
        functools.partial(_kernel, num_blocks=nb),
        grid=(B, n // block),
        in_specs=[pl.BlockSpec((1,), lambda b, i: (b,)),
                  pl.BlockSpec((1, nb, 8), lambda b, i: (b, 0, 0)),  # pinned
                  pl.BlockSpec((1, block), lambda b, i: (b, i))],
        out_specs=pl.BlockSpec((1, block), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.bool_),
        interpret=interpret,
    )(seeds, words, keys)


def bloom_probe(words: jnp.ndarray, keys: jnp.ndarray, seed=0,
                block: int = DEFAULT_BLOCK,
                interpret: bool = True) -> jnp.ndarray:
    """Membership mask bool [N] for keys against the packed filter words.

    Single-slot convenience over :func:`bloom_probe_batched` (B = 1).
    """
    seeds = jnp.asarray(seed, jnp.uint32).reshape(1)
    return bloom_probe_batched(words[None], keys[None], seeds, block=block,
                               interpret=interpret)[0]
