"""Pallas kernel: Bloom-filter hash computation (build side, Alg. 1 map).

Batched layout: every array carries a leading SLOT dimension (one slot per
query of an engine batch) and the grid is 2-D over ``(batch_slot,
key_block)`` — each step loads a ``[1, BLOCK]`` slice of one slot's keys
into VMEM and emits the (block index, 8-lane bit masks) pair for every key —
pure VPU integer math (murmur3 finalizer + multiply-shift lane hashes), no
memory traffic beyond the streaming key blocks.

Seeds are RUNTIME OPERANDS, not static kernel parameters: each slot's seed
streams in as a one-element VMEM block indexed by the slot coordinate, so
one compiled executable serves every seed (the serving engine's
zero-recompile contract across mixed-seed batches).

The scatter-OR that folds these pairs into the packed filter runs in the jit
wrapper (XLA scatter): TPU Pallas has no scatter atomics, so committing the
bits from inside the kernel would serialize the grid.  This is the documented
GPU->TPU semantic change (DESIGN.md §2): the paper's per-worker loop becomes
hash-kernel + one XLA scatter pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bloom

DEFAULT_BLOCK = 2048


def _kernel(seed_ref, keys_ref, blk_ref, masks_ref, *, num_blocks: int):
    seed = seed_ref[0]                  # this slot's seed (runtime operand)
    keys = keys_ref[...]                # [1, BLOCK]
    blk_ref[...] = bloom.block_index(keys, num_blocks, seed)
    masks_ref[...] = bloom.lane_masks(keys, seed)


def bloom_hashes_batched(keys: jnp.ndarray, seeds: jnp.ndarray,
                         num_blocks: int, block: int = DEFAULT_BLOCK,
                         interpret: bool = True):
    """(block_index int32 [B, N], lane_masks uint32 [B, N, 8]) per slot.

    ``keys`` is ``[B, N]`` with ``N % block == 0`` (wrappers pad);
    ``seeds`` is uint32 ``[B]`` — a runtime array operand, one per slot.
    """
    B, n = keys.shape
    assert n % block == 0, f"pad keys to a multiple of {block} (got {n})"
    assert seeds.shape == (B,), (seeds.shape, B)
    return pl.pallas_call(
        functools.partial(_kernel, num_blocks=num_blocks),
        grid=(B, n // block),
        in_specs=[pl.BlockSpec((1,), lambda b, i: (b,)),
                  pl.BlockSpec((1, block), lambda b, i: (b, i))],
        out_specs=[pl.BlockSpec((1, block), lambda b, i: (b, i)),
                   pl.BlockSpec((1, block, 8), lambda b, i: (b, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, n), jnp.int32),
                   jax.ShapeDtypeStruct((B, n, 8), jnp.uint32)],
        interpret=interpret,
    )(seeds, keys)


def bloom_hashes(keys: jnp.ndarray, num_blocks: int, seed=0,
                 block: int = DEFAULT_BLOCK, interpret: bool = True):
    """(block_index int32 [N], lane_masks uint32 [N, 8]); N % block == 0.

    Single-slot convenience over :func:`bloom_hashes_batched` (B = 1) —
    the batched kernel IS the implementation, so the two can never drift.
    """
    seeds = jnp.asarray(seed, jnp.uint32).reshape(1)
    blk, masks = bloom_hashes_batched(keys[None], seeds, num_blocks,
                                      block=block, interpret=interpret)
    return blk[0], masks[0]
