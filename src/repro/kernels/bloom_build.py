"""Pallas kernel: Bloom-filter hash computation (build side, Alg. 1 map).

Grid over key blocks; each step loads a [BLOCK] slice of keys into VMEM and
emits the (block index, 8-lane bit masks) pair for every key — pure VPU
integer math (murmur3 finalizer + multiply-shift lane hashes), no memory
traffic beyond the streaming key blocks.

The scatter-OR that folds these pairs into the packed filter runs in the jit
wrapper (XLA scatter): TPU Pallas has no scatter atomics, so committing the
bits from inside the kernel would serialize the grid.  This is the documented
GPU->TPU semantic change (DESIGN.md §2): the paper's per-worker loop becomes
hash-kernel + one XLA scatter pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bloom

DEFAULT_BLOCK = 2048


def _kernel(keys_ref, blk_ref, masks_ref, *, num_blocks: int, seed: int):
    keys = keys_ref[...]
    blk_ref[...] = bloom.block_index(keys, num_blocks, seed)
    masks_ref[...] = bloom.lane_masks(keys, seed)


def bloom_hashes(keys: jnp.ndarray, num_blocks: int, seed: int = 0,
                 block: int = DEFAULT_BLOCK, interpret: bool = True):
    """(block_index int32 [N], lane_masks uint32 [N, 8]); N % block == 0."""
    n = keys.shape[0]
    assert n % block == 0, f"pad keys to a multiple of {block} (got {n})"
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_kernel, num_blocks=num_blocks, seed=seed),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block, 8), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n, 8), jnp.uint32)],
        interpret=interpret,
    )(keys)
