"""Pure-jnp oracles for every Pallas kernel in this package.

These are thin re-exports/wrappers around the core implementations so the
kernel tests assert against the *same* code the rest of the system uses —
bit-identical uint32 hashing guarantees the kernels can be swapped in
anywhere (``kernels/ops.py`` is the switch).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bloom
from repro.core.hashing import bounded, counter_hash


def bloom_hashes_ref(keys: jnp.ndarray, num_blocks: int, seed):
    """(block_index int32 [N], lane_masks uint32 [N, 8]) for each key."""
    return (bloom.block_index(keys, num_blocks, seed),
            bloom.lane_masks(keys, seed))


def bloom_probe_ref(words: jnp.ndarray, keys: jnp.ndarray,
                    seed) -> jnp.ndarray:
    """Membership mask bool [N] against packed filter words [nb, 8]."""
    return bloom.contains(bloom.BloomFilter(words, seed), keys)


def edge_sample_ref(values1: jnp.ndarray, values2: jnp.ndarray,
                    keys: jnp.ndarray,
                    start1: jnp.ndarray, count1: jnp.ndarray,
                    start2: jnp.ndarray, count2: jnp.ndarray,
                    joinable: jnp.ndarray, b_i: jnp.ndarray,
                    b_max: int, seed, expr: str = "sum"):
    """Two-way Algorithm-2 sampler: per-stratum (n, sum_f, sum_f2).

    The oracle materializes the [S, b_max] draw grid (exactly what the Pallas
    kernel avoids doing in HBM) — same math, same hashes.
    """
    t = jnp.arange(b_max, dtype=jnp.uint32)[None, :]
    k = keys[:, None]
    h1 = counter_hash(seed, k, t, 0)
    h2 = counter_hash(seed, k, t, 1)
    i1 = start1[:, None] + bounded(h1, jnp.maximum(count1, 1)[:, None])
    i2 = start2[:, None] + bounded(h2, jnp.maximum(count2, 1)[:, None])
    v1 = values1[i1]
    v2 = values2[i2]
    fv = v1 * v2 if expr == "product" else v1 + v2
    tm = jnp.arange(b_max, dtype=jnp.float32)[None, :]
    mask = (tm < b_i[:, None]) & joinable[:, None]
    fm = jnp.where(mask, fv, 0.0)
    return (jnp.sum(mask, axis=1, dtype=jnp.float32),
            jnp.sum(fm, axis=1),
            jnp.sum(fm * fm, axis=1))
