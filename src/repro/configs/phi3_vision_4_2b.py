"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (GQA kv=32, MHA)
d_ff=8192 vocab=32064; phi3-mini backbone + CLIP stub (input_specs supplies
precomputed patch embeddings) [hf:microsoft/Phi-3-vision-128k-instruct; hf]."""

from repro.models.config import ArchConfig, _register

CONFIG = _register(ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32064, num_img_tokens=576,  # 24x24 patches per image (stub)
    norm_eps=1e-5,
    attn_chunk=2048,  # flash-style softmax for >=4k sequences
))
