"""The assigned input-shape cells and per-arch applicability.

LM shapes (seq_len x global_batch):
  train_4k     4,096 x 256   lowers train_step
  prefill_32k  32,768 x 32   lowers the forward (prefill) pass
  decode_32k   32,768 x 128  lowers serve_step (1 token, KV cache of 32k)
  long_500k    524,288 x 1   lowers serve_step; SUB-QUADRATIC ARCHS ONLY

``long_500k`` is skipped for every arch whose mixer pattern contains global
attention (quadratic decode state) — per the assignment note; the skips are
listed explicitly in DESIGN.md §5 and EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

from typing import NamedTuple

from repro.models.config import ArchConfig


class ShapeCell(NamedTuple):
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("quadratic attention: 500k KV cache/attention is the "
                       "thing sub-quadratic archs exist to avoid (skip per "
                       "assignment)")
    if cfg.is_encdec and shape == "long_500k":
        return False, "enc-dec decoder is full-attention (quadratic)"
    return True, ""


def cells_for(cfg: ArchConfig) -> list[ShapeCell]:
    return [c for n, c in SHAPES.items() if applicable(cfg, n)[0]]
