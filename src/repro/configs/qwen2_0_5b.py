"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936; GQA with QKV bias [arXiv:2407.10671; hf]."""

from repro.models.config import ArchConfig, _register

CONFIG = _register(ArchConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151936, qkv_bias=True, tie_embeddings=True,
    # 12/10/14 heads don't divide a 16-way model axis: attention projections
    # replicate (semantic-unit rule), so activations shard over SEQUENCE on
    # the model axis instead — context parallelism (EXPERIMENTS.md §Perf B)
    rules=(("seq", "model"),),
))
