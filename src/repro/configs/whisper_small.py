"""whisper-small [audio] — 12L d_model=768 12H (GQA kv=12) d_ff=3072
vocab=51865; enc-dec with conv frontend stubbed to precomputed frame
embeddings [arXiv:2212.04356; unverified]."""

from repro.models.config import ArchConfig, EncoderCfg, _register

CONFIG = _register(ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, ff_kind="gelu", tie_embeddings=True,
    rope_theta=0.0,  # absolute sinusoidal positions, no rope
    encoder=EncoderCfg(n_layers=12, n_frames=1500, d_input=80),
    norm_eps=1e-5,
    # 12/10/14 heads don't divide a 16-way model axis: attention projections
    # replicate (semantic-unit rule), so activations shard over SEQUENCE on
    # the model axis instead — context parallelism (EXPERIMENTS.md §Perf B)
    rules=(("seq", "model"),),
))
