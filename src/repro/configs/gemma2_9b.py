"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; local+global alternating, attn/logit softcaps, GeGLU,
post-sublayer norms [arXiv:2408.00118; hf]."""

from repro.models.config import ArchConfig, _register

CONFIG = _register(ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
    vocab=256000, head_dim=256, mixer_pattern=("local", "attn"),
    ff_kind="geglu", window=4096, attn_softcap=50.0, logit_softcap=30.0,
    tie_embeddings=True, scale_embed=True, post_norms=True,
    attn_chunk=2048,  # flash-style softmax for >=4k sequences
))
