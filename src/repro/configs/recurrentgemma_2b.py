"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention 1:2 (pattern rglru,rglru,local);
26 = 8 full patterns + a trailing (rglru, rglru) partial block, handled by
the trunk's tail support [arXiv:2402.19427; hf]."""

from repro.models.config import ArchConfig, RGLRUCfg, _register

CONFIG = _register(ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, head_dim=256, mixer_pattern=("rglru", "rglru", "local"),
    ff_kind="geglu", rglru=RGLRUCfg(lru_width=2560), window=2048,
    tie_embeddings=True, scale_embed=True,
    # 12/10/14 heads don't divide a 16-way model axis: attention projections
    # replicate (semantic-unit rule), so activations shard over SEQUENCE on
    # the model axis instead — context parallelism (EXPERIMENTS.md §Perf B)
    rules=(("seq", "model"),),
))
