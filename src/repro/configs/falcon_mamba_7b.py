"""falcon-mamba-7b [ssm] — 64L d_model=4096 attention-free, d_ff=0,
vocab=65024, ssm_state=16 (mamba1 arch) [arXiv:2410.05355; unverified]."""

from repro.models.config import ArchConfig, SSMCfg, _register

CONFIG = _register(ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=65024, mixer_pattern=("mamba",), ff_kind="none",
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2), norm_eps=1e-5,
))
