"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64 routed top-6 + 2 shared experts (kimi/moonlight)
[hf:moonshotai/Moonlight-16B-A3B; hf]."""

from repro.models.config import ArchConfig, MoECfg, _register

CONFIG = _register(ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, ff_kind="moe",
    moe=MoECfg(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2),
    attn_chunk=2048,  # flash-style softmax for >=4k sequences
))
