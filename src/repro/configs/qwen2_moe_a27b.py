"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""

from repro.models.config import ArchConfig, MoECfg, _register

CONFIG = _register(ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=151936, ff_kind="moe", qkv_bias=True,
    moe=MoECfg(num_experts=60, top_k=4, d_ff_expert=1408, num_shared=4),
    attn_chunk=2048,  # flash-style softmax for >=4k sequences
))
