"""Arch registry: importing this package registers the 10 assigned configs
(one module per arch) plus the paper's own join-workload configs."""

from repro.configs import (falcon_mamba_7b, gemma2_9b, granite_20b,
                           moonshot_v1_16b_a3b, phi3_vision_4_2b,
                           qwen2_0_5b, qwen2_moe_a27b, qwen3_1_7b,
                           recurrentgemma_2b, whisper_small)
from repro.configs.shapes import SHAPES, ShapeCell, applicable, cells_for
from repro.models.config import ARCHS, get_config

__all__ = ["ARCHS", "get_config", "SHAPES", "ShapeCell", "applicable",
           "cells_for",
           "falcon_mamba_7b", "gemma2_9b", "granite_20b",
           "moonshot_v1_16b_a3b", "phi3_vision_4_2b", "qwen2_0_5b",
           "qwen2_moe_a27b", "qwen3_1_7b", "recurrentgemma_2b",
           "whisper_small"]
