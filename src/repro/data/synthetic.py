"""Synthetic join workloads (paper §5.1).

The paper's microbenchmarks use Poisson-valued tuples with lambda in
[10, 10000], a controlled *overlap fraction* (share of tuples participating
in the join), and key counts proportional to the worker count.

``overlapping_relations`` constructs n datasets where exactly the requested
fraction of tuples carries keys drawn from a pool shared by ALL inputs (so
they survive an n-way join filter) and the rest carries per-dataset exclusive
keys.  Keys are scrambled through fmix32 so they are uniformly spread for the
hash partitioner, exactly like hashed record ids in the paper's setting.
"""

from __future__ import annotations

import numpy as np

from repro.core.relation import Relation, relation

# key-space layout: [0, SHARED_SPAN) shared pool, then per-dataset pools.
_POOL_SPAN = 1 << 20


def _scramble(keys: np.ndarray) -> np.ndarray:
    """numpy murmur3 finalizer (matches core.hashing.fmix32 bit-for-bit)."""
    h = keys.astype(np.uint64)
    h ^= h >> np.uint64(16)
    h = (h * np.uint64(0x85EBCA6B)) & np.uint64(0xFFFFFFFF)
    h ^= h >> np.uint64(13)
    h = (h * np.uint64(0xC2B2AE35)) & np.uint64(0xFFFFFFFF)
    h ^= h >> np.uint64(16)
    return h.astype(np.uint32)


def overlapping_relations(sizes, overlap_fraction: float,
                          keys_per_dataset: int = 1024,
                          lam: float = 10.0,
                          seed: int = 0,
                          scramble: bool = True) -> list[Relation]:
    """n relations with the given overlap fraction and Poisson(lam) values."""
    rng = np.random.default_rng(seed)
    shared_keys = rng.choice(_POOL_SPAN, size=max(
        int(keys_per_dataset * overlap_fraction), 1), replace=False)
    rels = []
    for i, size in enumerate(sizes):
        n_shared = int(round(size * overlap_fraction))
        own_pool = (i + 1) * _POOL_SPAN
        own_keys = own_pool + rng.choice(
            _POOL_SPAN, size=max(keys_per_dataset - len(shared_keys), 1),
            replace=False)
        ks = np.concatenate([
            rng.choice(shared_keys, size=n_shared),
            rng.choice(own_keys, size=size - n_shared),
        ]).astype(np.uint32)
        if scramble:
            ks = _scramble(ks)
        vs = rng.poisson(lam, size=size).astype(np.float32)
        perm = rng.permutation(size)
        rels.append(relation(ks[perm], vs[perm]))
    return rels


def skewed_relation(size: int, num_keys: int, zipf_a: float = 1.5,
                    lam: float = 10.0, seed: int = 0) -> Relation:
    """Zipf-skewed key distribution (stress for the stratified sampler)."""
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(zipf_a, size=size), num_keys) - 1
    ks = _scramble(ranks.astype(np.uint32))
    vs = rng.poisson(lam, size=size).astype(np.float32)
    return relation(ks, vs)
