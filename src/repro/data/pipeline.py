"""LM training data pipeline with ApproxJoin as a first-class input stage.

Two layers:

1. **Deterministic token source** — ``lm_batch(step, shard, ...)`` generates
   the (tokens, targets) pair for any (step, shard) from a counter-based hash
   of (seed, step, shard, position).  No state, no files: after a node
   failure ANY host can regenerate ANY shard bit-exactly, which is the data
   half of the fault-tolerance story (DESIGN.md §6).

2. **ApproxJoin-weighted document selection** — the paper's operator applied
   to the training data plane: a document table (doc id -> quality weight)
   is joined against a membership table (doc id -> domain tag) with a
   latency/error budget; the per-stratum sampled counts decide how many
   sequences each domain contributes to the next batch window.  This is a
   real use of sampled joins in an ML pipeline: batch mixing from raw
   metadata without materializing the full join.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.budget import QueryBudget
from repro.core.hashing import counter_hash, u32
from repro.core.join import approx_join
from repro.core.relation import Relation


def lm_batch(step: int, shard: int, *, batch: int, seq: int, vocab: int,
             seed: int = 0, structured: bool = False) -> dict:
    """Deterministic synthetic LM batch for (step, shard).

    tokens[b, t] = counter_hash(seed, step * S + shard, b * seq + t) % vocab
    targets are tokens shifted left (next-token prediction).

    ``structured=True`` makes the stream LEARNABLE (for end-to-end training
    demos): an affine token chain t_{i+1} = 3 t_i + 7 (mod vocab) with hash
    noise on 1/8 of positions — a model that learns must drive loss toward
    the noise floor, far below ln(vocab).
    """
    rows = jnp.arange(batch, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(seq + 1, dtype=jnp.uint32)[None, :]
    stream = u32((int(step) * (1 << 16) + int(shard)) & 0xFFFFFFFF)
    h = counter_hash(seed, stream, rows * u32(seq + 1) + cols, 7)
    if structured:
        start = counter_hash(seed, stream, rows, 8)[:, :1] % u32(vocab)
        # unroll the affine chain via its closed form: t_i = a^i t_0 + c*(...)
        # cheaper: cumulative map in numpy-free jnp scan over seq+1 (small)
        def chain(t, hcol):
            nxt = (t * u32(3) + u32(7)) % u32(vocab)
            noisy = jnp.where((hcol & u32(7)) == 0, hcol % u32(vocab), nxt)
            return noisy, noisy
        _, toks = jax.lax.scan(chain, start[:, 0], h.T[1:])
        toks = jnp.concatenate([start, toks.T], axis=1).astype(jnp.int32)
    else:
        toks = (h % u32(vocab)).astype(jnp.int32)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class MixturePlan(NamedTuple):
    domain_keys: np.ndarray      # uint32 [D] surviving domain ids
    weights: np.ndarray          # float32 [D] normalized mixing weights
    estimate: float              # aggregate estimate from the join
    error_bound: float


def plan_batch_mixture(doc_table: Relation, domain_table: Relation,
                       budget: QueryBudget = QueryBudget(error=0.05),
                       seed: int = 0, max_strata: int = 1024,
                       b_max: int = 512) -> MixturePlan:
    """ApproxJoin the doc-weight table with the domain table; the
    per-stratum estimated mass becomes the batch mixing weights."""
    res = approx_join([domain_table, doc_table], budget, seed=seed,
                      max_strata=max_strata, b_max=b_max)
    assert res.stats is not None or res.strata is not None
    strata = res.strata
    keys = np.asarray(strata.keys)
    if res.stats is not None:
        b = np.maximum(np.asarray(res.stats.n_sampled), 1.0)
        mass = np.asarray(res.stats.population) * \
            np.asarray(res.stats.sum_f) / b
        ok = np.asarray(res.stats.valid)
    else:  # exact path: weight by stratum population
        mass = np.asarray(strata.population)
        ok = np.asarray(strata.joinable)
    mass = np.where(ok, np.maximum(mass, 0.0), 0.0)
    total = float(mass.sum()) or 1.0
    keep = ok & (mass > 0)
    return MixturePlan(keys[keep].astype(np.uint32),
                       (mass[keep] / total).astype(np.float32),
                       float(res.estimate), float(res.error_bound))


def mixture_shard_counts(plan: MixturePlan, batch: int,
                         seed: int = 0) -> np.ndarray:
    """Integerize mixing weights into per-domain sequence counts for a batch
    (largest-remainder rounding; deterministic)."""
    if len(plan.weights) == 0:
        return np.zeros((0,), np.int32)
    raw = plan.weights * batch
    base = np.floor(raw).astype(np.int32)
    rem = batch - int(base.sum())
    order = np.argsort(-(raw - base))
    base[order[:rem]] += 1
    return base
