"""Data substrates: the paper's evaluation datasets (synthetic Poisson,
TPC-H-lite, CAIDA-like flows, Netflix-like ratings) and the LM token pipeline
that feeds the training examples (deterministic per (step, shard) — any host
can regenerate any shard after a failure)."""
