"""Netflix-Prize-like workload (paper §6.2).

training_set: ~100 M ratings of 17 770 movies; qualifying.txt: movie ids to
be scored.  The paper joins the two on MovieID and measures latency (no
meaningful aggregate; we still aggregate ratings so the same query machinery
runs).  Scaled generator keeps the movie-popularity skew (Zipf) that makes
this join stratified-sampling-relevant: popular movies have enormous strata.
"""

from __future__ import annotations

import numpy as np

from repro.core.relation import Relation, relation
from repro.data.synthetic import _scramble

NUM_MOVIES = 17_770


def ratings_tables(n_ratings: int = 1 << 16, n_qualifying: int = 1 << 13,
                   num_movies: int = NUM_MOVIES,
                   seed: int = 0) -> list[Relation]:
    """[training, qualifying] keyed by movie id; training value = rating."""
    rng = np.random.default_rng(seed)
    # Zipf movie popularity, ratings 1..5 skewed to 3-4 like the real data
    movie = np.minimum(rng.zipf(1.2, size=n_ratings), num_movies) - 1
    rating = rng.choice([1, 2, 3, 4, 5], p=[0.05, 0.10, 0.30, 0.35, 0.20],
                        size=n_ratings).astype(np.float32)
    qual_movie = np.minimum(rng.zipf(1.2, size=n_qualifying), num_movies) - 1
    training = relation(_scramble(movie.astype(np.uint32)), rating)
    qualifying = relation(_scramble(qual_movie.astype(np.uint32)),
                          np.ones(n_qualifying, np.float32))
    return [qualifying, training]  # lead with the smaller relation
