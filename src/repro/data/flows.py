"""CAIDA-like network flow workload (paper §6.1).

The paper joins TCP, UDP and ICMP flow tables (keyed by the src/dst pair) and
asks for the total size of flows present in ALL three.  Real CAIDA counts are
115.5 M / 67.1 M / 2.8 M flows; we scale them down preserving the ratios and
draw flow sizes from a lognormal (the classic heavy-tail of backbone traffic).
Keys are hashed 2-tuples, so a configurable fraction of flow pairs is shared
across the three protocol tables.
"""

from __future__ import annotations

import numpy as np

from repro.core.relation import Relation, relation
from repro.data.synthetic import _scramble

CAIDA_RATIOS = (115_472_322, 67_098_852, 2_801_002)


def flow_tables(scale: int = 1 << 14, shared_fraction: float = 0.05,
                seed: int = 0) -> list[Relation]:
    """[tcp, udp, icmp] Relations; value = flow bytes (lognormal).

    ``scale`` = ICMP table size; the others follow CAIDA's ratios.
    ``shared_fraction`` = fraction of each table's flows whose (src, dst)
    pair appears in all three protocols (the join survivors).
    """
    rng = np.random.default_rng(seed)
    sizes = [max(int(scale * r / CAIDA_RATIOS[2]), 8) for r in CAIDA_RATIOS]
    n_shared_keys = max(int(scale * shared_fraction), 1)
    shared = rng.choice(1 << 24, size=n_shared_keys, replace=False)
    rels = []
    for i, size in enumerate(sizes):
        n_shared = int(round(size * shared_fraction))
        own = (1 << 26) * (i + 1) + rng.choice(1 << 24, size=size,
                                               replace=True)
        ks = np.concatenate([rng.choice(shared, size=n_shared),
                             own[: size - n_shared]]).astype(np.uint32)
        ks = _scramble(ks)
        sizes_b = rng.lognormal(mean=7.0, sigma=2.0, size=size)
        vs = np.minimum(sizes_b, 1e9).astype(np.float32)
        perm = rng.permutation(size)
        rels.append(relation(ks[perm], vs[perm]))
    return rels
