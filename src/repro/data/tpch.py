"""TPC-H-lite generator (paper §5.5).

The paper strips TPC-H Q3/Q4/Q10 down to their join cores and also runs the
"money before ordering" query SUM(o_totalprice + c_acctbal) over
CUSTOMER |><| ORDERS.  We generate schema-faithful scaled tables:

  CUSTOMER  (c_custkey,  c_acctbal)     — 150 K rows / SF
  ORDERS    (o_orderkey, o_custkey, o_totalprice) — 1.5 M rows / SF
  LINEITEM  (l_orderkey, l_extendedprice)         — ~6 M rows / SF

Value distributions follow TPC-H's uniform specs (acctbal in [-999.99,
9999.99], totalprice compound).  Each query core returns the Relations keyed
on the join attribute, ready for approx_join / the baselines.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.relation import Relation, relation


class TPCH(NamedTuple):
    customer_key: np.ndarray      # c_custkey
    customer_acctbal: np.ndarray
    orders_key: np.ndarray        # o_orderkey
    orders_custkey: np.ndarray
    orders_totalprice: np.ndarray
    lineitem_orderkey: np.ndarray
    lineitem_extprice: np.ndarray


def generate(scale: float = 0.01, seed: int = 0) -> TPCH:
    """Scaled TPC-H tables (scale=1.0 ~ the 1 GB spec; default 0.01)."""
    rng = np.random.default_rng(seed)
    n_cust = max(int(150_000 * scale), 16)
    n_ord = n_cust * 10
    n_li = int(n_ord * 4)  # avg ~4 lineitems per order

    cust_key = np.arange(1, n_cust + 1, dtype=np.uint32)
    acctbal = rng.uniform(-999.99, 9999.99, n_cust).astype(np.float32)

    ord_key = np.arange(1, n_ord + 1, dtype=np.uint32)
    # TPC-H: only 2/3 of customers have orders
    custs_with_orders = rng.choice(cust_key, size=max(2 * n_cust // 3, 1),
                                   replace=False)
    ord_cust = rng.choice(custs_with_orders, size=n_ord).astype(np.uint32)
    totalprice = rng.uniform(800.0, 500_000.0, n_ord).astype(np.float32)

    li_ord = rng.choice(ord_key, size=n_li).astype(np.uint32)
    extprice = rng.uniform(900.0, 100_000.0, n_li).astype(np.float32)
    return TPCH(cust_key, acctbal, ord_key, ord_cust, totalprice,
                li_ord, extprice)


def q_customer_orders(t: TPCH) -> list[Relation]:
    """§5.5 query: SUM(o_totalprice + c_acctbal) over CUSTOMER |><| ORDERS."""
    return [relation(t.orders_custkey, t.orders_totalprice),
            relation(t.customer_key, t.customer_acctbal)]


def q3_core(t: TPCH) -> list[list[Relation]]:
    """Q3 join core: customer |><| orders (custkey), orders |><| lineitem
    (orderkey) — two joins, returned as two relation pairs."""
    return [
        [relation(t.orders_custkey, t.orders_totalprice),
         relation(t.customer_key, t.customer_acctbal)],
        [relation(t.orders_key, t.orders_totalprice),
         relation(t.lineitem_orderkey, t.lineitem_extprice)],
    ]


def q4_core(t: TPCH) -> list[Relation]:
    """Q4 join core: orders |><| lineitem on orderkey (one join)."""
    return [relation(t.orders_key, t.orders_totalprice),
            relation(t.lineitem_orderkey, t.lineitem_extprice)]


def q10_core(t: TPCH) -> list[list[Relation]]:
    """Q10 join core: customer |><| orders |><| lineitem (two joins)."""
    return q3_core(t)
