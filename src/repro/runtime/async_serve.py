"""Always-on asynchronous serving tier: per-replica event loops with
continuous batching, and a tenant-sharded multi-replica front door.

The engine (``runtime/join_serve.py``) is caller-driven: nothing happens
between ``step()`` calls, so a query's queue latency is however long the
driver sleeps, not however long the engine needs — ``BENCH_serve.json``
recorded queue-latency p95s of seconds against ~130 ms of per-window
compute.  This module closes that gap the way LLM serving engines do:

* :class:`AsyncJoinServer` runs ONE engine on a dedicated event-loop
  thread.  ``submit()`` is ingestion only — it appends to a lock-protected
  ingress ring and returns a ``concurrent.futures.Future`` immediately;
  admission (bucketing, sharding, validation) and every device dispatch
  happen on the loop thread.  The loop serves **continuous batches**: it
  never waits for a full same-class batch.  Whatever is queued when the
  previous step retires is dispatched after at most ``linger_s`` of slot
  backfill, and requests arriving while a step is in flight land in the
  ingress ring and backfill the NEXT batch's open slots instead of waiting
  for a caller to come back.  The linger is cut short the moment some
  shape class can fill every slot, or a queued latency budget's deadline
  comes within ``deadline_margin_s``; scheduling *within* a step stays the
  engine's deadline-aware ``_take_batch``.
* :class:`AsyncJoinFrontDoor` runs N replica event loops and shards
  TENANTS (the ``query_id`` prefix, :func:`~.join_serve.tenant_of`) across
  them — sticky, so one tenant's sigma feedback stays sequential on one
  replica.  All replicas share one ``SigmaRegistry``.  An idle replica
  STEALS the entire pending run of one tenant from the most backed-up
  replica: whole-tenant moves preserve same-``query_id`` order (nothing of
  that tenant is in flight while the victim's engine lock is held), so
  stolen work is bit-identical to unstolen work.  Streaming tenants are
  pinned — their admission bookkeeping and session state live on the
  owning replica.

Correctness contract: per-query results through the async tier are
bit-identical to the synchronous server (and therefore to a direct
``approx_join``).  Slot results never depend on batch composition, and
per-``query_id`` execution order — the only thing sigma feedback
observes — is preserved end to end: ingress is FIFO, the engine's
scheduler keeps same-id FIFO (sigma pipelining defers repeats without
reordering), and stealing moves a tenant wholesale under the front-door
lock.  Asserted in ``tests/test_async_serve.py`` and replayed at trace
scale by ``benchmarks/serve_bench.py --async-trace``.

Locking (strict order ``front-door _alock`` > ``replica _elock`` >
``replica _cv``; no thread ever acquires leftward while holding
rightward): ``_cv`` guards the ingress ring and is held only for ring
append/swap; ``_elock`` guards every engine mutation — the loop holds it
across ``step()``, a thief acquires the victim's with a short bounded wait
(flagging ``_steal_wanted`` so a saturated victim loop yields between
steps; a victim mid-step past the wait is simply skipped this round);
``_alock`` serialises tenant routing
against steals so a submission racing a steal cannot land behind its
predecessors.
"""

from __future__ import annotations

import os
import threading
import time
from collections import Counter
from concurrent.futures import Future
from functools import partial
from typing import Callable, Optional, Sequence

from repro.core.cost import SigmaRegistry
from repro.core.relation import Relation
from repro.runtime.checkpoint import latest_step, save_checkpoint
from repro.runtime.fault import (Heartbeat, InjectedFault,
                                 elastic_restore_engine, guarded_step)
from repro.runtime.join_serve import JoinRequest, JoinServer, tenant_of
from repro.runtime.stream_join import StreamJoinServer, StreamJoinSession
from repro.runtime.telemetry import NULL_TRACER, Tracer

DEFAULT_LINGER_S = 0.002


class AsyncJoinServer:
    """One engine + one event-loop thread: ingestion-decoupled, always on.

    ``engine`` is any :class:`~.join_serve.JoinServer` (a
    :class:`~.stream_join.StreamJoinServer` enables :meth:`open_stream` /
    :meth:`push`); with ``engine=None`` one is constructed from
    ``engine_kw``.  The server owns the engine exclusively once
    constructed: callers interact through :meth:`submit` (returns a
    future), :meth:`call` (run a closure on the loop thread — the door to
    every other engine method), and :meth:`close`.
    """

    def __init__(self, engine: Optional[JoinServer] = None, *,
                 linger_s: float = DEFAULT_LINGER_S,
                 deadline_margin_s: float = 0.010,
                 idle_wait_s: float = 0.010,
                 name: str = "replica0",
                 front_door: Optional["AsyncJoinFrontDoor"] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every_s: float = 0.0,
                 heartbeat: Optional[Heartbeat] = None,
                 step_retries: int = 0, step_backoff_s: float = 0.0,
                 **engine_kw):
        self.engine = JoinServer(**engine_kw) if engine is None else engine
        assert self.engine.on_done is None, \
            "engine already owned by an async tier"
        self.engine.on_done = self._on_done
        # replica-tag the engine's trace lane: every event the engine emits
        # from here on carries this replica's name, so a shared front-door
        # tracer separates replicas into distinct perfetto threads
        self.engine.trace_name = name
        self.linger_s = linger_s
        self.deadline_margin_s = deadline_margin_s
        self.idle_wait_s = idle_wait_s
        self.name = name
        self.error: Optional[BaseException] = None
        self.stats = {"ingested": 0, "calls": 0, "backfilled": 0,
                      "stolen_in": 0, "stolen_out": 0, "checkpoints": 0}
        self._front = front_door
        # crash safety: when checkpoint_dir is set the loop snapshots the
        # engine (under _elock, between steps) whenever state changed and
        # the cadence allows — every opportunity at the 0.0 default — and
        # hands the host arrays to checkpoint.py's async writer, so a
        # successor can elastic_restore the newest complete checkpoint
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every_s = checkpoint_every_s
        self.heartbeat = heartbeat
        # transient-failure policy for engine steps (guarded_step): 0
        # retries by default — a serving step is not a training step whose
        # inputs regenerate deterministically, so retry only on request
        self.step_retries = step_retries
        self.step_backoff_s = step_backoff_s
        self._ckpt_writer: Optional[threading.Thread] = None
        last = latest_step(checkpoint_dir) if checkpoint_dir else None
        self._ckpt_step = 0 if last is None else last + 1
        self._last_ckpt_t = 0.0
        self._dirty = False
        self._kill_after: Optional[int] = None
        # ingress ring: ("req", JoinRequest, Future) | ("call", fn, Future)
        self._ingress: list[tuple] = []
        self._cv = threading.Condition()
        self._elock = threading.RLock()
        self._running = True
        self._in_linger = False
        self._steal_wanted = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"async-join-{name}")
        self._thread.start()

    # -- ingestion (any thread) ---------------------------------------------

    def submit(self, req: JoinRequest) -> Future:
        """Enqueue one query; returns a future resolving to the served
        request (``req.result`` populated; ``req.shed`` set if admission
        dropped it).  O(1): admission and execution happen on the loop."""
        fut: Future = Future()
        now = time.perf_counter()
        with self._cv:
            self._check_open()
            if not req._ingest_t:
                req._ingest_t = now
            self._ingress.append(("req", req, fut))
            self._cv.notify_all()
        return fut

    def call(self, fn: Callable) -> Future:
        """Run ``fn()`` on the event-loop thread (between steps), resolving
        to its return value — the safe door to every engine method that
        ``submit`` doesn't cover (``register_dataset``, ``open_stream``,
        diagnostics mutation, ...)."""
        fut: Future = Future()
        with self._cv:
            self._check_open()
            self._ingress.append(("call", fn, fut))
            self._cv.notify_all()
        return fut

    def register_dataset(self, name: str, rels: Sequence[Relation]) -> None:
        self.call(partial(self.engine.register_dataset, name, rels)).result()

    def open_stream(self, name: str, spec, **kw) -> StreamJoinSession:
        """Open a streaming session on the loop thread (engine must be a
        ``StreamJoinServer``).  Interact with the session via :meth:`push`;
        results arrive through the returned window futures."""
        assert isinstance(self.engine, StreamJoinServer), \
            "open_stream needs a StreamJoinServer engine"
        return self.call(
            partial(self.engine.open_stream, name, spec, **kw)).result()

    def push(self, session: StreamJoinSession,
             rels: Sequence[Relation]) -> list[Future]:
        """Admit one micro-batch per side; returns one future per window
        that became due.  A future resolves when its window is served — or
        immediately with ``.shed`` set if per-tenant admission later drops
        it (the engine's shed hook fires this tier's resolver)."""
        def _push():
            out = session.push(rels)
            futs = []
            for req in out:
                f: Future = Future()
                req._future = f
                futs.append(f)
            return futs
        return self.call(_push).result()

    def push_by_name(self, name: str, rels: Sequence[Relation]) -> \
            list[Future]:
        """:meth:`push` by session name — the session object is resolved on
        the loop thread.  The failover door: after a replica death the
        caller's session object belongs to the dead engine, but the
        successor's restored session answers to the same name."""
        def _push():
            session = self.engine.sessions[name]
            out = session.push(rels)
            futs = []
            for req in out:
                f: Future = Future()
                req._future = f
                futs.append(f)
            return futs
        return self.call(_push).result()

    def submit_plan(self, plan, *, query_id: str = "plan0",
                    **kw) -> dict:
        """Submit a query plan on the loop thread; returns one future per
        plan node (node name -> future resolving to the served request).
        Node requests share the ``query_id`` tenant prefix, so a front door
        keeps (or steals, or fails over) a plan whole."""
        def _submit():
            handle = self.engine.submit_plan(plan, query_id=query_id, **kw)
            futs = {}
            for name, req in handle.requests.items():
                f: Future = Future()
                req._future = f
                futs[name] = f
            return futs
        return self.call(_submit).result()

    @property
    def tracer(self) -> Tracer:
        """The engine's tracer (``NULL_TRACER`` unless one was attached)."""
        return self.engine.tracer

    def backlog(self) -> int:
        """Pending request count (ingress ring + engine queue)."""
        return len(self._ingress) + len(self.engine.queue)

    def snapshot(self) -> dict:
        with self._elock:
            d = self.engine.diagnostics.snapshot()
        d.update(self.stats)
        d["backlog"] = self.backlog()
        return d

    def close(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the loop; with ``drain`` (default) serve everything pending
        first.  Unserved requests' futures fail with ``RuntimeError``."""
        if drain:
            deadline = time.monotonic() + timeout
            while (self.backlog() and self.error is None
                   and self._thread.is_alive()
                   and time.monotonic() < deadline):
                time.sleep(0.001)
        with self._cv:
            self._running = False
            self._cv.notify_all()
        self._thread.join(timeout)
        if self._ckpt_writer is not None:
            self._ckpt_writer.join(timeout)
        self._fail_pending(RuntimeError(f"AsyncJoinServer {self.name} "
                                        "closed"))

    def __enter__(self) -> "AsyncJoinServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    # -- event loop (loop thread only) --------------------------------------

    def _loop(self) -> None:
        try:
            while self._running:
                if self.heartbeat is not None:
                    self.heartbeat.beat(self.name)
                if self._kill_after is not None and self._kill_after <= 0:
                    # fault drill: die exactly like a crashed process would —
                    # InjectedFault is a BaseException, so nothing below
                    # absorbs it; the handler marks the replica dead and
                    # fails every pending future, and the front door's
                    # failover hands the newest checkpoint to a successor
                    self.tracer.instant("fault", cat="fleet", tid=self.name,
                                        replica=self.name)
                    raise InjectedFault(f"replica {self.name} killed by "
                                        "fault injection")
                if self._steal_wanted.is_set():
                    # a thief is parked on _elock: a saturated loop holds it
                    # back-to-back (drain -> linger -> step), so yield for a
                    # moment or the steal can never win the reacquire race
                    time.sleep(0.001)
                self._drain()
                self._maybe_checkpoint()
                if not self.engine.queue:
                    if self._front is not None:
                        self._front.maybe_failover(blocking=False)
                        if self._front._steal_for(self):
                            continue
                    with self._cv:
                        if self._running and not self._ingress:
                            self._cv.wait(self.idle_wait_s)
                    continue
                if self.tracer.enabled:
                    with self.tracer.span("linger", cat="batch",
                                          tid=self.name,
                                          backlog=self.backlog()):
                        self._linger()
                else:
                    self._linger()
                if not self._running:
                    break
                with self._elock:
                    # guarded_step: transient device failures retry with
                    # exponential backoff when step_retries > 0; an
                    # InjectedFault passes straight through (BaseException)
                    n = guarded_step(lambda _s, _b: self.engine.step(),
                                     None, None, retries=self.step_retries,
                                     backoff_s=self.step_backoff_s)
                if n:
                    self._dirty = True
                    if self._kill_after is not None:
                        self._kill_after -= 1
                self._maybe_checkpoint()
        except BaseException as e:  # noqa: BLE001 — fail futures, don't hang
            self.error = e
            self._fail_pending(e)

    def _maybe_checkpoint(self) -> None:
        """Checkpoint the engine if state changed and the cadence allows.

        Capture (snapshot + device_get) is synchronous under the engine
        lock — the checkpoint is exactly the state at a step boundary —
        then serialization rides checkpoint.py's async writer thread.  The
        previous writer is joined first, so at most one write is in flight
        and a reader joining ``_ckpt_writer`` sees every rename."""
        if self.checkpoint_dir is None or not self._dirty:
            return
        now = time.monotonic()
        if self._last_ckpt_t and \
                now - self._last_ckpt_t < self.checkpoint_every_s:
            return
        if self._ckpt_writer is not None:
            self._ckpt_writer.join()
            if self._ckpt_writer.exception is not None:
                # a writer failure must take the replica down loudly (the
                # loop's error path), never quietly stop checkpointing while
                # serving continues — that would hand a failover successor
                # an arbitrarily stale snapshot
                raise self._ckpt_writer.exception
        with self._elock, \
                self.tracer.span("checkpoint", cat="fleet", tid=self.name,
                                 step=self._ckpt_step):
            flat, meta = self.engine.snapshot_state()
            meta["replica"] = self.name
            self._ckpt_writer = save_checkpoint(
                self.checkpoint_dir, self._ckpt_step, flat, sync=False,
                extra=meta)
        self._ckpt_step += 1
        self._last_ckpt_t = now
        self._dirty = False
        self.stats["checkpoints"] += 1

    def kill_after(self, steps: int) -> None:
        """Fault injection: the loop raises :class:`InjectedFault` after
        serving ``steps`` more engine steps (0 = at the next iteration).
        The last checkpoint before death holds every admitted-but-unserved
        request — the state a failover successor adopts."""
        self._kill_after = steps

    def _drain(self) -> int:
        """Move the ingress ring into the engine (admission on the loop
        thread).  Per-item failures (validation errors) fail that item's
        future only."""
        with self._cv:
            items, self._ingress = self._ingress, []
        if not items:
            return 0
        # any drained item can mutate engine state ("call" items included:
        # a streaming push emits windows) — mark for the next checkpoint
        self._dirty = True
        admitted = 0
        with self._elock:
            for kind, payload, fut in items:
                try:
                    if kind == "req":
                        payload._future = fut
                        self.engine.submit(payload)
                        self.stats["ingested"] += 1
                        admitted += 1
                    else:
                        fut.set_result(payload())
                        self.stats["calls"] += 1
                except Exception as e:  # noqa: BLE001
                    fut.set_exception(e)
        return admitted

    def _linger(self) -> None:
        """Continuous batching: give open slots up to ``linger_s`` to
        backfill from the ingress ring, cut short by a fillable batch or an
        imminent deadline.  This is the ONLY place the loop trades latency
        for batch width, and the trade is bounded."""
        if self.linger_s <= 0:
            return
        t_end = time.perf_counter() + self.linger_s
        while self._running:
            with self._elock:
                if self._batch_ready():
                    return
                guard = self._earliest_deadline() - self.deadline_margin_s
            now = time.perf_counter()
            if now >= t_end or now >= guard:
                return
            with self._cv:
                if not self._ingress:
                    self._cv.wait(max(min(t_end, guard) - now, 0.0))
            self.stats["backfilled"] += self._drain()

    def _batch_ready(self) -> bool:
        """True when some shape class can fill every slot of its next
        batch — lingering past that point buys nothing."""
        counts = Counter(r._class for r in self.engine.queue)
        return any(n >= self.engine._slot_cap(cls)
                   for cls, n in counts.items())

    def _earliest_deadline(self) -> float:
        return min((self.engine._deadline(r) for r in self.engine.queue),
                   default=float("inf"))

    # -- completion / shutdown ----------------------------------------------

    def _on_done(self, req: JoinRequest) -> None:
        """Engine completion hook: resolve the request's future (served or
        shed).  Runs on the loop thread, result fully populated."""
        fut = req._future
        if fut is not None:
            req._future = None
            if not fut.done():
                fut.set_result(req)

    def _check_open(self) -> None:
        if self.error is not None:
            raise RuntimeError(
                f"AsyncJoinServer {self.name} failed") from self.error
        if not self._running:
            raise RuntimeError(f"AsyncJoinServer {self.name} is closed")

    def _fail_pending(self, exc: BaseException) -> None:
        with self._cv:
            self._running = False
            items, self._ingress = self._ingress, []
            self._cv.notify_all()
        futs = [fut for _, _, fut in items]
        with self._elock:
            futs += [r._future for r in self.engine.queue
                     if r._future is not None]
        for fut in futs:
            if not fut.done():
                fut.set_exception(exc)

    # -- work stealing (called by the front door, victim side) ---------------

    def _release_one_tenant(self) -> Optional[tuple]:
        """Cut ONE tenant's entire pending run out of this replica for a
        steal: ``(tenant, admitted requests, raw ingress items)`` or None.
        Bounded-blocking on the engine lock: ``_steal_wanted`` makes the
        victim's loop yield between steps, and the thief waits briefly — a
        victim mid-step for longer than the wait is skipped this round
        rather than stalled on.  The oldest queued non-streaming tenant is
        picked (FIFO fairness; streaming tenants are pinned)."""
        self._steal_wanted.set()
        try:
            if not self._elock.acquire(timeout=0.05):
                return None
        finally:
            self._steal_wanted.clear()
        try:
            with self._cv:
                pinned = {tenant_of(r.query_id) for r in self.engine.queue
                          if r.stream is not None}
                pinned |= {tenant_of(it[1].query_id) for it in self._ingress
                           if it[0] == "req" and it[1].stream is not None}
                tenant = next(
                    (tenant_of(r.query_id) for r in self.engine.queue
                     if tenant_of(r.query_id) not in pinned), None)
                if tenant is None:
                    tenant = next(
                        (tenant_of(it[1].query_id) for it in self._ingress
                         if it[0] == "req"
                         and tenant_of(it[1].query_id) not in pinned), None)
                if tenant is None:
                    return None
                admitted = [r for r in self.engine.queue
                            if tenant_of(r.query_id) == tenant]
                self.engine.queue = [r for r in self.engine.queue
                                     if tenant_of(r.query_id) != tenant]
                moved = [it for it in self._ingress if it[0] == "req"
                         and tenant_of(it[1].query_id) == tenant]
                if moved:
                    self._ingress = [it for it in self._ingress
                                     if it not in moved]
                self.stats["stolen_out"] += len(admitted) + len(moved)
                return tenant, admitted, moved
        finally:
            self._elock.release()

    def _accept_stolen(self, admitted: list[JoinRequest],
                       ingress_items: list[tuple]) -> None:
        """Thief side: adopt a stolen tenant's pending run.  Admitted
        requests keep their shape class — replicas must be homogeneous
        (the front door builds them from one configuration)."""
        if admitted:
            with self._elock:
                self.engine.queue.extend(admitted)
        with self._cv:
            if ingress_items:
                self._ingress.extend(ingress_items)
            self._cv.notify_all()
        self.stats["stolen_in"] += len(admitted) + len(ingress_items)


class AsyncJoinFrontDoor:
    """N replica event loops behind one ``submit``: sticky tenant sharding,
    shared sigma registry, work stealing.

    Tenants (the ``query_id`` prefix) are assigned least-loaded-first on
    first sight and stay put, so a tenant's sigma feedback chain runs
    sequentially on one replica; an idle replica steals the whole pending
    run of one tenant from the most backed-up replica (``steals`` counts
    moves).  All replicas share ``self.sigma`` — safe because tenant
    single-ownership means no two replicas ever update the same
    ``query_id`` concurrently.  Replicas are homogeneous by construction:
    one ``engine_factory`` (or one ``engine_kw`` set) builds them all, so
    stolen requests' shape classes stay valid.
    """

    def __init__(self, *, replicas: int = 2,
                 engine_factory: Optional[Callable[[int], JoinServer]] = None,
                 sigma_registry: Optional[SigmaRegistry] = None,
                 work_stealing: bool = True, steal_min_backlog: int = 2,
                 linger_s: float = DEFAULT_LINGER_S,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every_s: float = 0.0,
                 heartbeat_timeout_s: float = 5.0,
                 tracer: Optional[Tracer] = None, **engine_kw):
        assert replicas >= 1, replicas
        # one SHARED tracer across the fleet: replica engines tag their
        # events with their replica name (pid lanes in the chrome export),
        # and fleet-level events (steal/failover) land on the "front-door"
        # lane.  Sharing also keeps span ids unique fleet-wide.
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.sigma = SigmaRegistry() if sigma_registry is None \
            else sigma_registry
        self.work_stealing = work_stealing
        self.steal_min_backlog = steal_min_backlog
        self.steals = 0
        self.failovers = 0
        self.checkpoint_dir = checkpoint_dir
        # every replica loop beats this once per iteration; a replica whose
        # beat goes stale past the timeout (or whose .error is set — the
        # fast path for in-process deaths) is declared dead by
        # maybe_failover and its tenants move to a successor
        self.heartbeat = Heartbeat(timeout_s=heartbeat_timeout_s)
        self._failed: set[str] = set()
        self._alock = threading.RLock()
        self._assign: dict[str, AsyncJoinServer] = {}
        self.replicas: list[AsyncJoinServer] = []
        for i in range(replicas):
            if engine_factory is not None:
                eng = engine_factory(i)
                eng.sigma = self.sigma        # shared: see class docstring
            else:
                eng = JoinServer(sigma_registry=self.sigma, **engine_kw)
            if tracer is not None:
                eng.tracer = tracer
            ckdir = os.path.join(checkpoint_dir, f"replica{i}") \
                if checkpoint_dir is not None else None
            self.replicas.append(AsyncJoinServer(
                eng, name=f"replica{i}", linger_s=linger_s, front_door=self,
                checkpoint_dir=ckdir, checkpoint_every_s=checkpoint_every_s,
                heartbeat=self.heartbeat))

    def submit(self, req: JoinRequest) -> Future:
        """Route by tenant and enqueue.  The routing lock is held through
        the replica enqueue so a submission can never race a steal of its
        own tenant onto the wrong replica (reordering same-id requests)."""
        req._ingest_t = time.perf_counter()
        with self._alock:
            self.maybe_failover()
            return self._route(tenant_of(req.query_id)).submit(req)

    def push(self, name: str, rels: Sequence[Relation]) -> list[Future]:
        """Push a micro-batch to stream ``name`` wherever its session lives
        NOW — on the opening replica, or on the failover successor that
        adopted it.  The crash-safe way to feed a stream: unlike holding the
        ``(replica, session)`` pair from :meth:`open_stream`, this re-routes
        after a failover."""
        with self._alock:
            self.maybe_failover()
            rep = self._route(name)
        return rep.push_by_name(name, rels)

    def submit_plan(self, plan, *, query_id: str = "plan0", **kw) -> dict:
        """Route a whole plan to its tenant's replica (the plan id IS the
        tenant, and every node's query id shares it — one plan never splits
        across replicas); returns node name -> future."""
        with self._alock:
            self.maybe_failover()
            rep = self._route(tenant_of(query_id))
        return rep.submit_plan(plan, query_id=query_id, **kw)

    def open_stream(self, name: str, spec, **kw):
        """Open a streaming session on the tenant's replica; returns
        ``(replica, session)`` — push via ``replica.push(session, ...)``.
        The tenant is pinned (never stolen) for the session's life."""
        with self._alock:
            rep = self._route(name)
        return rep, rep.open_stream(name, spec, **kw)

    def register_dataset(self, name: str, rels: Sequence[Relation]) -> None:
        """Broadcast: a stolen tenant's follow-up queries must resolve the
        handle wherever they land."""
        futs = [rep.call(partial(rep.engine.register_dataset, name, rels))
                for rep in self.replicas]
        for f in futs:
            f.result()

    def _live(self) -> list[AsyncJoinServer]:
        return [r for r in self.replicas
                if r.error is None and r.name not in self._failed]

    def _route(self, tenant: str) -> AsyncJoinServer:
        rep = self._assign.get(tenant)
        if rep is None or rep.error is not None or rep.name in self._failed:
            rep = min(self._live(), key=lambda r: r.backlog())
            self._assign[tenant] = rep
        return rep

    # -- failover -----------------------------------------------------------

    def maybe_failover(self, *, blocking: bool = True,
                       now: Optional[float] = None) -> int:
        """Detect dead replicas and fail each over; returns how many moved.

        Death = replica ``.error`` set (the in-process fast path: the loop
        thread died) OR its heartbeat stale past the timeout with the loop
        thread actually gone.  The thread-liveness conjunct matters: a
        replica mid-compile holds the engine lock for seconds without
        beating, and failing over a replica that is merely slow would fork
        its tenants' state (in a real multi-host deployment there is no
        thread handle and the stale beat alone decides — after a fencing
        step this test setup doesn't need).  Replica loops call this every
        iteration with ``blocking=False`` — a loop must never block on the
        routing lock while another thread holding it waits on that loop
        (the ``call()`` rendezvous in ``_failover``)."""
        if blocking:
            self._alock.acquire()
        elif not self._alock.acquire(blocking=False):
            return 0
        try:
            stale = set(self.heartbeat.dead_hosts(now))
            dead = [r for r in self.replicas if r.name not in self._failed
                    and (r.error is not None
                         or (r.name in stale
                             and not r._thread.is_alive()))]
            return sum(1 for r in dead if self._failover(r))
        finally:
            self._alock.release()

    def _failover(self, dead: AsyncJoinServer) -> bool:
        """Adopt ``dead``'s tenants onto a successor (caller holds _alock).

        The successor restores the dead replica's newest complete engine
        checkpoint (:func:`~repro.runtime.fault.elastic_restore_engine`,
        merge semantics) ON ITS LOOP THREAD, then inherits every tenant
        assignment.  Requests admitted after the last checkpoint are the
        loss window — their futures already failed with the replica's
        error, so callers know to resubmit; with ``checkpoint_every_s=0``
        the window is empty at every step boundary."""
        if dead.name in self._failed:
            return False
        alive = [r for r in self._live() if r is not dead]
        if not alive:
            return False        # nobody left to adopt; keep it failable
        self._failed.add(dead.name)
        successor = min(alive, key=lambda r: r.backlog())
        if dead._ckpt_writer is not None:
            dead._ckpt_writer.join()       # let the final write finish
        if dead.checkpoint_dir is not None:
            restore = partial(elastic_restore_engine, dead.checkpoint_dir,
                              successor.engine)
            if threading.current_thread() is successor._thread:
                # the successor's own loop detected the death: run inline
                # (a call() rendezvous with yourself never returns)
                with successor._elock:
                    restore()
            else:
                successor.call(restore).result()
        moved = 0
        for tenant, rep in list(self._assign.items()):
            if rep is dead:
                self._assign[tenant] = successor
                moved += 1
        self.failovers += 1
        self.tracer.instant("failover", cat="fleet", tid="front-door",
                            dead=dead.name, successor=successor.name,
                            tenants=moved)
        return True

    def _steal_for(self, thief: AsyncJoinServer) -> bool:
        """Move one whole tenant from the most backed-up replica to an idle
        ``thief``.  Returns True if work moved.  Non-blocking on the
        routing lock: the thief is a loop thread, and a loop thread parked
        on ``_alock`` while its holder waits on that loop's ``call()``
        queue would deadlock the pair — skipping a steal round is free."""
        if not self.work_stealing or len(self.replicas) < 2:
            return False
        if not self._alock.acquire(blocking=False):
            return False
        try:
            for victim in sorted((r for r in self._live() if r is not thief),
                                 key=lambda r: -r.backlog()):
                if victim.backlog() < self.steal_min_backlog:
                    break
                got = victim._release_one_tenant()
                if got is None:
                    continue
                tenant, admitted, ingress_items = got
                self._assign[tenant] = thief
                thief._accept_stolen(admitted, ingress_items)
                self.steals += 1
                self.tracer.instant(
                    "steal", cat="fleet", tid="front-door", tenant=tenant,
                    victim=victim.name, thief=thief.name,
                    moved=len(admitted) + len(ingress_items))
                return True
        finally:
            self._alock.release()
        return False

    def snapshot(self) -> dict:
        return {"steals": self.steals, "failovers": self.failovers,
                "failed": sorted(self._failed),
                "tenants": {t: rep.name for t, rep in self._assign.items()},
                "replicas": {rep.name: rep.snapshot()
                             for rep in self.replicas}}

    def close(self, drain: bool = True) -> None:
        for rep in self.replicas:
            rep.close(drain=drain)

    def __enter__(self) -> "AsyncJoinFrontDoor":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))
