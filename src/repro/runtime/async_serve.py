"""Always-on asynchronous serving tier: per-replica event loops with
continuous batching, and a tenant-sharded multi-replica front door.

The engine (``runtime/join_serve.py``) is caller-driven: nothing happens
between ``step()`` calls, so a query's queue latency is however long the
driver sleeps, not however long the engine needs — ``BENCH_serve.json``
recorded queue-latency p95s of seconds against ~130 ms of per-window
compute.  This module closes that gap the way LLM serving engines do:

* :class:`AsyncJoinServer` runs ONE engine on a dedicated event-loop
  thread.  ``submit()`` is ingestion only — it appends to a lock-protected
  ingress ring and returns a ``concurrent.futures.Future`` immediately;
  admission (bucketing, sharding, validation) and every device dispatch
  happen on the loop thread.  The loop serves **continuous batches**: it
  never waits for a full same-class batch.  Whatever is queued when the
  previous step retires is dispatched after at most ``linger_s`` of slot
  backfill, and requests arriving while a step is in flight land in the
  ingress ring and backfill the NEXT batch's open slots instead of waiting
  for a caller to come back.  The linger is cut short the moment some
  shape class can fill every slot, or a queued latency budget's deadline
  comes within ``deadline_margin_s``; scheduling *within* a step stays the
  engine's deadline-aware ``_take_batch``.
* :class:`AsyncJoinFrontDoor` runs N replica event loops and shards
  TENANTS (the ``query_id`` prefix, :func:`~.join_serve.tenant_of`) across
  them — sticky, so one tenant's sigma feedback stays sequential on one
  replica.  All replicas share one ``SigmaRegistry``.  An idle replica
  STEALS the entire pending run of one tenant from the most backed-up
  replica: whole-tenant moves preserve same-``query_id`` order (nothing of
  that tenant is in flight while the victim's engine lock is held), so
  stolen work is bit-identical to unstolen work.  Streaming tenants are
  pinned — their admission bookkeeping and session state live on the
  owning replica.

Correctness contract: per-query results through the async tier are
bit-identical to the synchronous server (and therefore to a direct
``approx_join``).  Slot results never depend on batch composition, and
per-``query_id`` execution order — the only thing sigma feedback
observes — is preserved end to end: ingress is FIFO, the engine's
scheduler keeps same-id FIFO (sigma pipelining defers repeats without
reordering), and stealing moves a tenant wholesale under the front-door
lock.  Asserted in ``tests/test_async_serve.py`` and replayed at trace
scale by ``benchmarks/serve_bench.py --async-trace``.

Locking (strict order ``front-door _alock`` > ``replica _elock`` >
``replica _cv``; no thread ever acquires leftward while holding
rightward): ``_cv`` guards the ingress ring and is held only for ring
append/swap; ``_elock`` guards every engine mutation — the loop holds it
across ``step()``, a thief acquires the victim's with a short bounded wait
(flagging ``_steal_wanted`` so a saturated victim loop yields between
steps; a victim mid-step past the wait is simply skipped this round);
``_alock`` serialises tenant routing
against steals so a submission racing a steal cannot land behind its
predecessors.
"""

from __future__ import annotations

import threading
import time
from collections import Counter
from concurrent.futures import Future
from functools import partial
from typing import Callable, Optional, Sequence

from repro.core.cost import SigmaRegistry
from repro.core.relation import Relation
from repro.runtime.join_serve import JoinRequest, JoinServer, tenant_of
from repro.runtime.stream_join import StreamJoinServer, StreamJoinSession

DEFAULT_LINGER_S = 0.002


class AsyncJoinServer:
    """One engine + one event-loop thread: ingestion-decoupled, always on.

    ``engine`` is any :class:`~.join_serve.JoinServer` (a
    :class:`~.stream_join.StreamJoinServer` enables :meth:`open_stream` /
    :meth:`push`); with ``engine=None`` one is constructed from
    ``engine_kw``.  The server owns the engine exclusively once
    constructed: callers interact through :meth:`submit` (returns a
    future), :meth:`call` (run a closure on the loop thread — the door to
    every other engine method), and :meth:`close`.
    """

    def __init__(self, engine: Optional[JoinServer] = None, *,
                 linger_s: float = DEFAULT_LINGER_S,
                 deadline_margin_s: float = 0.010,
                 idle_wait_s: float = 0.010,
                 name: str = "replica0",
                 front_door: Optional["AsyncJoinFrontDoor"] = None,
                 **engine_kw):
        self.engine = JoinServer(**engine_kw) if engine is None else engine
        assert self.engine.on_done is None, \
            "engine already owned by an async tier"
        self.engine.on_done = self._on_done
        self.linger_s = linger_s
        self.deadline_margin_s = deadline_margin_s
        self.idle_wait_s = idle_wait_s
        self.name = name
        self.error: Optional[BaseException] = None
        self.stats = {"ingested": 0, "calls": 0, "backfilled": 0,
                      "stolen_in": 0, "stolen_out": 0}
        self._front = front_door
        # ingress ring: ("req", JoinRequest, Future) | ("call", fn, Future)
        self._ingress: list[tuple] = []
        self._cv = threading.Condition()
        self._elock = threading.RLock()
        self._running = True
        self._in_linger = False
        self._steal_wanted = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"async-join-{name}")
        self._thread.start()

    # -- ingestion (any thread) ---------------------------------------------

    def submit(self, req: JoinRequest) -> Future:
        """Enqueue one query; returns a future resolving to the served
        request (``req.result`` populated; ``req.shed`` set if admission
        dropped it).  O(1): admission and execution happen on the loop."""
        fut: Future = Future()
        now = time.perf_counter()
        with self._cv:
            self._check_open()
            if not req._ingest_t:
                req._ingest_t = now
            self._ingress.append(("req", req, fut))
            self._cv.notify_all()
        return fut

    def call(self, fn: Callable) -> Future:
        """Run ``fn()`` on the event-loop thread (between steps), resolving
        to its return value — the safe door to every engine method that
        ``submit`` doesn't cover (``register_dataset``, ``open_stream``,
        diagnostics mutation, ...)."""
        fut: Future = Future()
        with self._cv:
            self._check_open()
            self._ingress.append(("call", fn, fut))
            self._cv.notify_all()
        return fut

    def register_dataset(self, name: str, rels: Sequence[Relation]) -> None:
        self.call(partial(self.engine.register_dataset, name, rels)).result()

    def open_stream(self, name: str, spec, **kw) -> StreamJoinSession:
        """Open a streaming session on the loop thread (engine must be a
        ``StreamJoinServer``).  Interact with the session via :meth:`push`;
        results arrive through the returned window futures."""
        assert isinstance(self.engine, StreamJoinServer), \
            "open_stream needs a StreamJoinServer engine"
        return self.call(
            partial(self.engine.open_stream, name, spec, **kw)).result()

    def push(self, session: StreamJoinSession,
             rels: Sequence[Relation]) -> list[Future]:
        """Admit one micro-batch per side; returns one future per window
        that became due.  A future resolves when its window is served — or
        immediately with ``.shed`` set if per-tenant admission later drops
        it (the engine's shed hook fires this tier's resolver)."""
        def _push():
            out = session.push(rels)
            futs = []
            for req in out:
                f: Future = Future()
                req._future = f
                futs.append(f)
            return futs
        return self.call(_push).result()

    def backlog(self) -> int:
        """Pending request count (ingress ring + engine queue)."""
        return len(self._ingress) + len(self.engine.queue)

    def snapshot(self) -> dict:
        with self._elock:
            d = self.engine.diagnostics.snapshot()
        d.update(self.stats)
        d["backlog"] = self.backlog()
        return d

    def close(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the loop; with ``drain`` (default) serve everything pending
        first.  Unserved requests' futures fail with ``RuntimeError``."""
        if drain:
            deadline = time.monotonic() + timeout
            while (self.backlog() and self.error is None
                   and self._thread.is_alive()
                   and time.monotonic() < deadline):
                time.sleep(0.001)
        with self._cv:
            self._running = False
            self._cv.notify_all()
        self._thread.join(timeout)
        self._fail_pending(RuntimeError(f"AsyncJoinServer {self.name} "
                                        "closed"))

    def __enter__(self) -> "AsyncJoinServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    # -- event loop (loop thread only) --------------------------------------

    def _loop(self) -> None:
        try:
            while self._running:
                if self._steal_wanted.is_set():
                    # a thief is parked on _elock: a saturated loop holds it
                    # back-to-back (drain -> linger -> step), so yield for a
                    # moment or the steal can never win the reacquire race
                    time.sleep(0.001)
                self._drain()
                if not self.engine.queue:
                    if self._front is not None \
                            and self._front._steal_for(self):
                        continue
                    with self._cv:
                        if self._running and not self._ingress:
                            self._cv.wait(self.idle_wait_s)
                    continue
                self._linger()
                if not self._running:
                    break
                with self._elock:
                    self.engine.step()
        except BaseException as e:  # noqa: BLE001 — fail futures, don't hang
            self.error = e
            self._fail_pending(e)

    def _drain(self) -> int:
        """Move the ingress ring into the engine (admission on the loop
        thread).  Per-item failures (validation errors) fail that item's
        future only."""
        with self._cv:
            items, self._ingress = self._ingress, []
        if not items:
            return 0
        admitted = 0
        with self._elock:
            for kind, payload, fut in items:
                try:
                    if kind == "req":
                        payload._future = fut
                        self.engine.submit(payload)
                        self.stats["ingested"] += 1
                        admitted += 1
                    else:
                        fut.set_result(payload())
                        self.stats["calls"] += 1
                except Exception as e:  # noqa: BLE001
                    fut.set_exception(e)
        return admitted

    def _linger(self) -> None:
        """Continuous batching: give open slots up to ``linger_s`` to
        backfill from the ingress ring, cut short by a fillable batch or an
        imminent deadline.  This is the ONLY place the loop trades latency
        for batch width, and the trade is bounded."""
        if self.linger_s <= 0:
            return
        t_end = time.perf_counter() + self.linger_s
        while self._running:
            with self._elock:
                if self._batch_ready():
                    return
                guard = self._earliest_deadline() - self.deadline_margin_s
            now = time.perf_counter()
            if now >= t_end or now >= guard:
                return
            with self._cv:
                if not self._ingress:
                    self._cv.wait(max(min(t_end, guard) - now, 0.0))
            self.stats["backfilled"] += self._drain()

    def _batch_ready(self) -> bool:
        """True when some shape class can fill every slot of its next
        batch — lingering past that point buys nothing."""
        counts = Counter(r._class for r in self.engine.queue)
        return any(n >= self.engine._slot_cap(cls)
                   for cls, n in counts.items())

    def _earliest_deadline(self) -> float:
        return min((self.engine._deadline(r) for r in self.engine.queue),
                   default=float("inf"))

    # -- completion / shutdown ----------------------------------------------

    def _on_done(self, req: JoinRequest) -> None:
        """Engine completion hook: resolve the request's future (served or
        shed).  Runs on the loop thread, result fully populated."""
        fut = req._future
        if fut is not None:
            req._future = None
            if not fut.done():
                fut.set_result(req)

    def _check_open(self) -> None:
        if self.error is not None:
            raise RuntimeError(
                f"AsyncJoinServer {self.name} failed") from self.error
        if not self._running:
            raise RuntimeError(f"AsyncJoinServer {self.name} is closed")

    def _fail_pending(self, exc: BaseException) -> None:
        with self._cv:
            self._running = False
            items, self._ingress = self._ingress, []
            self._cv.notify_all()
        futs = [fut for _, _, fut in items]
        with self._elock:
            futs += [r._future for r in self.engine.queue
                     if r._future is not None]
        for fut in futs:
            if not fut.done():
                fut.set_exception(exc)

    # -- work stealing (called by the front door, victim side) ---------------

    def _release_one_tenant(self) -> Optional[tuple]:
        """Cut ONE tenant's entire pending run out of this replica for a
        steal: ``(tenant, admitted requests, raw ingress items)`` or None.
        Bounded-blocking on the engine lock: ``_steal_wanted`` makes the
        victim's loop yield between steps, and the thief waits briefly — a
        victim mid-step for longer than the wait is skipped this round
        rather than stalled on.  The oldest queued non-streaming tenant is
        picked (FIFO fairness; streaming tenants are pinned)."""
        self._steal_wanted.set()
        try:
            if not self._elock.acquire(timeout=0.05):
                return None
        finally:
            self._steal_wanted.clear()
        try:
            with self._cv:
                pinned = {tenant_of(r.query_id) for r in self.engine.queue
                          if r.stream is not None}
                pinned |= {tenant_of(it[1].query_id) for it in self._ingress
                           if it[0] == "req" and it[1].stream is not None}
                tenant = next(
                    (tenant_of(r.query_id) for r in self.engine.queue
                     if tenant_of(r.query_id) not in pinned), None)
                if tenant is None:
                    tenant = next(
                        (tenant_of(it[1].query_id) for it in self._ingress
                         if it[0] == "req"
                         and tenant_of(it[1].query_id) not in pinned), None)
                if tenant is None:
                    return None
                admitted = [r for r in self.engine.queue
                            if tenant_of(r.query_id) == tenant]
                self.engine.queue = [r for r in self.engine.queue
                                     if tenant_of(r.query_id) != tenant]
                moved = [it for it in self._ingress if it[0] == "req"
                         and tenant_of(it[1].query_id) == tenant]
                if moved:
                    self._ingress = [it for it in self._ingress
                                     if it not in moved]
                self.stats["stolen_out"] += len(admitted) + len(moved)
                return tenant, admitted, moved
        finally:
            self._elock.release()

    def _accept_stolen(self, admitted: list[JoinRequest],
                       ingress_items: list[tuple]) -> None:
        """Thief side: adopt a stolen tenant's pending run.  Admitted
        requests keep their shape class — replicas must be homogeneous
        (the front door builds them from one configuration)."""
        if admitted:
            with self._elock:
                self.engine.queue.extend(admitted)
        with self._cv:
            if ingress_items:
                self._ingress.extend(ingress_items)
            self._cv.notify_all()
        self.stats["stolen_in"] += len(admitted) + len(ingress_items)


class AsyncJoinFrontDoor:
    """N replica event loops behind one ``submit``: sticky tenant sharding,
    shared sigma registry, work stealing.

    Tenants (the ``query_id`` prefix) are assigned least-loaded-first on
    first sight and stay put, so a tenant's sigma feedback chain runs
    sequentially on one replica; an idle replica steals the whole pending
    run of one tenant from the most backed-up replica (``steals`` counts
    moves).  All replicas share ``self.sigma`` — safe because tenant
    single-ownership means no two replicas ever update the same
    ``query_id`` concurrently.  Replicas are homogeneous by construction:
    one ``engine_factory`` (or one ``engine_kw`` set) builds them all, so
    stolen requests' shape classes stay valid.
    """

    def __init__(self, *, replicas: int = 2,
                 engine_factory: Optional[Callable[[int], JoinServer]] = None,
                 sigma_registry: Optional[SigmaRegistry] = None,
                 work_stealing: bool = True, steal_min_backlog: int = 2,
                 linger_s: float = DEFAULT_LINGER_S, **engine_kw):
        assert replicas >= 1, replicas
        self.sigma = SigmaRegistry() if sigma_registry is None \
            else sigma_registry
        self.work_stealing = work_stealing
        self.steal_min_backlog = steal_min_backlog
        self.steals = 0
        self._alock = threading.RLock()
        self._assign: dict[str, AsyncJoinServer] = {}
        self.replicas: list[AsyncJoinServer] = []
        for i in range(replicas):
            if engine_factory is not None:
                eng = engine_factory(i)
                eng.sigma = self.sigma        # shared: see class docstring
            else:
                eng = JoinServer(sigma_registry=self.sigma, **engine_kw)
            self.replicas.append(AsyncJoinServer(
                eng, name=f"replica{i}", linger_s=linger_s, front_door=self))

    def submit(self, req: JoinRequest) -> Future:
        """Route by tenant and enqueue.  The routing lock is held through
        the replica enqueue so a submission can never race a steal of its
        own tenant onto the wrong replica (reordering same-id requests)."""
        req._ingest_t = time.perf_counter()
        with self._alock:
            return self._route(tenant_of(req.query_id)).submit(req)

    def open_stream(self, name: str, spec, **kw):
        """Open a streaming session on the tenant's replica; returns
        ``(replica, session)`` — push via ``replica.push(session, ...)``.
        The tenant is pinned (never stolen) for the session's life."""
        with self._alock:
            rep = self._route(name)
        return rep, rep.open_stream(name, spec, **kw)

    def register_dataset(self, name: str, rels: Sequence[Relation]) -> None:
        """Broadcast: a stolen tenant's follow-up queries must resolve the
        handle wherever they land."""
        futs = [rep.call(partial(rep.engine.register_dataset, name, rels))
                for rep in self.replicas]
        for f in futs:
            f.result()

    def _route(self, tenant: str) -> AsyncJoinServer:
        rep = self._assign.get(tenant)
        if rep is None:
            rep = min(self.replicas, key=lambda r: r.backlog())
            self._assign[tenant] = rep
        return rep

    def _steal_for(self, thief: AsyncJoinServer) -> bool:
        """Move one whole tenant from the most backed-up replica to an idle
        ``thief``.  Returns True if work moved."""
        if not self.work_stealing or len(self.replicas) < 2:
            return False
        with self._alock:
            for victim in sorted((r for r in self.replicas if r is not thief),
                                 key=lambda r: -r.backlog()):
                if victim.backlog() < self.steal_min_backlog:
                    break
                got = victim._release_one_tenant()
                if got is None:
                    continue
                tenant, admitted, ingress_items = got
                self._assign[tenant] = thief
                thief._accept_stolen(admitted, ingress_items)
                self.steals += 1
                return True
        return False

    def snapshot(self) -> dict:
        return {"steals": self.steals,
                "tenants": {t: rep.name for t, rep in self._assign.items()},
                "replicas": {rep.name: rep.snapshot()
                             for rep in self.replicas}}

    def close(self, drain: bool = True) -> None:
        for rep in self.replicas:
            rep.close(drain=drain)

    def __enter__(self) -> "AsyncJoinFrontDoor":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))
