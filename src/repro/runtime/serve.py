"""Batched serving loop: prefill + decode with slot-based continuous
batching (vLLM-lite).

``Server`` keeps B decode slots.  Requests (prompt token lists) are admitted
into free slots; each engine step runs one jitted ``decode_step`` for the
whole batch (finished/empty slots are masked); finished sequences (EOS or
max_new) free their slot.  Prefill is per-request teacher-forced decode into
the slot's cache region (token-by-token — simple and correct; the dry-run
prefill shape measures the fused full-sequence prefill instead).

Sampling: greedy or temperature, counter-hash PRNG keyed by (slot, position)
for reproducibility.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import counter_hash
from repro.models.model import Model


@dataclass
class Request:
    prompt: list[int]
    max_new: int = 16
    temperature: float = 0.0
    out: list[int] = field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, model: Model, params, *, batch_slots: int = 4,
                 max_seq: int = 256, eos_id: int = 1, seed: int = 0):
        self.model, self.params = model, params
        self.B, self.S = batch_slots, max_seq
        self.eos = eos_id
        self.seed = seed
        self.cache = model.init_cache(None, batch_slots, max_seq)
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self.queue: list[Request] = []
        self._step = jax.jit(model.decode_step)
        self._pos = np.zeros(batch_slots, np.int64)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _reset_slot(self, i: int) -> None:
        """Zero slot i's cache region (stale KV from the previous occupant
        would otherwise leak into the new request's attention)."""

        def one(path, x):
            names = [str(getattr(p, "key", getattr(p, "name", "")))
                     for p in path]
            if "tail" in names:   # tail block caches lack the layers dim
                return x.at[i].set(jnp.zeros_like(x[i]))
            return x.at[:, i].set(jnp.zeros_like(x[:, i]))

        self.cache = jax.tree_util.tree_map_with_path(one, self.cache)
        self._pos[i] = 0

    def _admit(self) -> None:
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self._reset_slot(i)
                self.slots[i] = req
                req._feed = list(req.prompt)  # tokens still to prefill
                req._generated = 0

    def _sample(self, logits: jnp.ndarray, slot: int, temp: float) -> int:
        if temp <= 0.0:
            return int(jnp.argmax(logits))
        g = counter_hash(self.seed, slot, int(self._pos[slot]), 11)
        u = (np.float64(g) + 0.5) / 2**32
        probs = np.asarray(jax.nn.softmax(logits / temp))
        return int(np.searchsorted(np.cumsum(probs), u))

    def step(self) -> int:
        """One engine step; returns number of active slots."""
        self._admit()
        tokens = np.zeros(self.B, np.int32)
        active = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req._feed:
                tokens[i] = req._feed.pop(0)       # prefill one token
            else:
                tokens[i] = req.out[-1] if req.out else self.eos
            active.append(i)
        if not active:
            return 0
        logits, self.cache = self._step(self.params,
                                        jnp.asarray(tokens), self.cache)
        for i in active:
            req = self.slots[i]
            self._pos[i] += 1
            if req._feed:                           # still prefilling
                continue
            tok = self._sample(logits[i], i, req.temperature)
            req.out.append(tok)
            req._generated += 1
            if tok == self.eos or req._generated >= req.max_new:
                req.done = True
                self.slots[i] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
