"""Distributed runtime: train step factories, sharded atomic
checkpointing with elastic restore, fault-tolerance scaffolding
(step retries, straggler detection, deterministic data re-generation),
the batched multi-tenant ApproxJoin serving engine (join_serve), and the
always-on async serving tier over it (async_serve)."""

from repro.runtime.train import TrainState, make_train_step, train_state_init
from repro.runtime.checkpoint import (latest_step, restore_checkpoint,
                                      save_checkpoint)
from repro.runtime.join_serve import JoinRequest, JoinServer
from repro.runtime.async_serve import AsyncJoinFrontDoor, AsyncJoinServer

__all__ = ["TrainState", "make_train_step", "train_state_init",
           "save_checkpoint", "restore_checkpoint", "latest_step",
           "JoinRequest", "JoinServer", "AsyncJoinServer",
           "AsyncJoinFrontDoor"]
