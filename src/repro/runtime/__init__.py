"""Distributed runtime: train/serve step factories, sharded atomic
checkpointing with elastic restore, and fault-tolerance scaffolding
(step retries, straggler detection, deterministic data re-generation)."""

from repro.runtime.train import TrainState, make_train_step, train_state_init
from repro.runtime.checkpoint import (latest_step, restore_checkpoint,
                                      save_checkpoint)

__all__ = ["TrainState", "make_train_step", "train_state_init",
           "save_checkpoint", "restore_checkpoint", "latest_step"]
