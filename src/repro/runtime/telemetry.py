"""Unified telemetry: span tracing, a metrics registry, byte reconciliation.

Three cooperating pieces, shared by every serving layer (`JoinServer`,
`StreamJoinServer`, `AsyncJoinServer`/`AsyncJoinFrontDoor`):

* `Tracer` — per-query / per-window / per-plan-node spans (ingest,
  admission/shed, batch-formation, compile, prepare / filter-exchange /
  shuffle / sample, complete) recorded into a bounded ring.  Disabled
  tracers cost one attribute read per call site (`span()` hands back a
  shared no-op span; `instant()`/`event()` return immediately), so the
  hot path is unchanged with tracing off.  Rings export as Chrome
  trace-event JSON (`chrome_trace`) viewable in Perfetto / chrome://tracing,
  tagged with replica and mesh identity.

* `MetricsRegistry` — named counters / gauges / histograms.  The server
  diagnostics objects route their fields through one registry, which is
  therefore the single backing store for every snapshot dict, and exports
  as JSON (`to_dict`) or Prometheus text exposition format (`prometheus`).

* Byte reconciliation — per-query records pairing each modeled cost
  (`filter_exchange_bytes`, `node_bytes_model`, `_wire_bytes_model`) with
  its metered counterpart (`per_device_shuffled_bytes`,
  `dist_shuffled_tuple_bytes`, `kernel_gather_bytes`) and the relative
  model error, aggregated per serving path by `reconciliation_report`.

Crash safety: the only tracer state that must survive failover is the
span-id sequence (successor spans must not reuse the dead replica's ids);
`Tracer.state()`/`Tracer.adopt()` ride `snapshot_state`/`restore_state`.
Metrics survive via the diagnostics scalar merge that already existed.
"""
from __future__ import annotations

import json
import re
import threading
from collections import OrderedDict, deque
from time import perf_counter
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------


class Counter:
    """Monotonic-by-convention numeric cell (restore may add, never read-modify
    concurrently without the caller's lock — same contract the diagnostics
    counters always had)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Any = 0

    def inc(self, v: Any = 1) -> None:
        self.value += v


class Gauge:
    """Point-in-time value; may hold a scalar or a numpy vector (per-device
    meters).  `None` means never set."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Any = None

    def set(self, v: Any) -> None:
        self.value = v


class Histogram:
    """Bounded sample ring plus cumulative count/sum.  The ring keeps the most
    recent `cap` observations (the percentile window); count/total never
    reset, so rates stay meaningful across `reset_latencies()`."""

    __slots__ = ("name", "cap", "samples", "count", "total")

    def __init__(self, name: str, cap: int = 4096):
        self.name = name
        self.cap = int(cap)
        self.samples: List[float] = []
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        s = self.samples
        s.append(v)
        if len(s) > self.cap:
            del s[: len(s) - self.cap]

    def reset_samples(self) -> None:
        del self.samples[:]

    def percentiles(self, prefix: str) -> Dict[str, float]:
        return latency_pcts(self.samples, prefix)


def latency_pcts(samples: Sequence[float], prefix: str) -> Dict[str, float]:
    """p50/p95/max summary with a stable key schema — the one helper behind
    both `ServerDiagnostics` and `StreamDiagnostics` snapshots."""
    if len(samples):
        arr = np.asarray(samples, np.float64)
        return {f"{prefix}_p50_s": float(np.percentile(arr, 50)),
                f"{prefix}_p95_s": float(np.percentile(arr, 95)),
                f"{prefix}_max_s": float(arr.max())}
    return {f"{prefix}_p50_s": 0.0, f"{prefix}_p95_s": 0.0,
            f"{prefix}_max_s": 0.0}


_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _PROM_BAD.sub("_", name)
    return name if not name[:1].isdigit() else "_" + name


class MetricsRegistry:
    """Get-or-create store of named metrics.  Creating a name twice returns
    the same object; creating it as a different kind is an error (it would
    silently fork the backing store)."""

    def __init__(self):
        self._metrics: "OrderedDict[str, Any]" = OrderedDict()

    def _get(self, name: str, kind, *args):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind(name, *args)
        elif type(m) is not kind:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        return self._get(name, Histogram, cap)

    def __iter__(self):
        return iter(self._metrics.values())

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def to_dict(self) -> Dict[str, Any]:
        """JSON-able view: counters/gauges by value, histograms as summary
        dicts.  Read-only — building it mutates nothing."""
        out: Dict[str, Any] = {}
        for m in self._metrics.values():
            if isinstance(m, Histogram):
                out[m.name] = {"count": m.count, "total": m.total,
                               **m.percentiles("sample")}
            elif isinstance(m.value, np.ndarray):
                out[m.name] = [float(x) for x in m.value]
            else:
                out[m.name] = m.value
        return out

    def prometheus(self, prefix: str = "repro") -> str:
        """Prometheus text exposition format.  Histograms export as summaries
        (quantile labels over the bounded window + cumulative _count/_sum);
        vector gauges export one sample per index under a `device` label."""
        lines: List[str] = []
        for m in self._metrics.values():
            name = _prom_name(f"{prefix}_{m.name}" if prefix else m.name)
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {float(m.value)}")
            elif isinstance(m, Gauge):
                if m.value is None:
                    continue
                lines.append(f"# TYPE {name} gauge")
                if isinstance(m.value, (np.ndarray, list, tuple)):
                    for i, x in enumerate(m.value):
                        lines.append(f'{name}{{device="{i}"}} {float(x)}')
                else:
                    lines.append(f"{name} {float(m.value)}")
            else:
                lines.append(f"# TYPE {name} summary")
                if len(m.samples):
                    arr = np.asarray(m.samples, np.float64)
                    for q in (0.5, 0.95, 0.99):
                        lines.append(f'{name}{{quantile="{q}"}} '
                                     f"{float(np.percentile(arr, 100 * q))}")
                lines.append(f"{name}_count {m.count}")
                lines.append(f"{name}_sum {float(m.total)}")
        return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------
# tracing
# --------------------------------------------------------------------------


class _NullSpan:
    """Shared no-op span — what a disabled tracer's `span()` returns, so call
    sites can unconditionally use `with tracer.span(...) as s`."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    """Context manager recording one duration event on exit."""

    __slots__ = ("_tracer", "name", "cat", "tid", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, tid: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name, self.cat, self.tid, self.args = name, cat, tid, args
        self.t0 = 0.0

    def set(self, **kw) -> None:
        self.args.update(kw)

    def __enter__(self):
        self.t0 = perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer.event(self.name, self.t0, perf_counter() - self.t0,
                           cat=self.cat, tid=self.tid, **self.args)
        return False


class Tracer:
    """Bounded span/event ring with a monotone id sequence.

    Events are plain dicts (`id`, `name`, `cat`, `tid`, `ts`, `dur`, `args`)
    with seconds-since-perf_counter-epoch timestamps; `chrome_trace` converts
    to the Chrome trace-event JSON schema.  `tags` (e.g. replica name, mesh
    size) are merged into every event's args.  The id sequence is the only
    state that must survive failover — `state()`/`adopt()` round-trip it
    through engine snapshots so a successor never reuses a dead replica's
    span ids.
    """

    def __init__(self, enabled: bool = True, capacity: int = 65536,
                 tags: Optional[Dict[str, Any]] = None):
        self.enabled = enabled
        self.capacity = int(capacity)
        self.events: "deque[Dict[str, Any]]" = deque(maxlen=self.capacity)
        self.recon: "deque[Dict[str, Any]]" = deque(maxlen=self.capacity)
        self.tags = dict(tags or {})
        self._lock = threading.Lock()
        self._seq = 0

    # -- ids / crash-safety ------------------------------------------------

    def next_id(self) -> int:
        with self._lock:
            self._seq += 1
            return self._seq

    def state(self) -> Dict[str, Any]:
        """JSON-able state for `snapshot_state` meta."""
        with self._lock:
            return {"seq": self._seq}

    def adopt(self, state: Dict[str, Any]) -> None:
        """Merge a snapshot's id sequence (max-merge: ids stay unique when a
        successor adopts a dead replica's state on top of its own)."""
        with self._lock:
            self._seq = max(self._seq, int(state.get("seq", 0)))

    # -- recording ---------------------------------------------------------

    def span(self, name: str, cat: str = "serve", tid: str = "engine",
             **args):
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, cat, tid, args)

    def event(self, name: str, ts: float, dur: float, cat: str = "serve",
              tid: str = "engine", **args) -> None:
        """Record a duration event with explicit perf_counter timestamps —
        for spans whose boundaries were stamped elsewhere (e.g. a query's
        ingest/dispatch/complete times stamped by the engine)."""
        if not self.enabled:
            return
        self.events.append({"id": self.next_id(), "name": name, "cat": cat,
                            "tid": tid, "ts": float(ts),
                            "dur": max(0.0, float(dur)),
                            "args": {**self.tags, **args}})

    def instant(self, name: str, cat: str = "serve", tid: str = "engine",
                ts: Optional[float] = None, **args) -> None:
        if not self.enabled:
            return
        self.events.append({"id": self.next_id(), "name": name, "cat": cat,
                            "tid": tid,
                            "ts": perf_counter() if ts is None else float(ts),
                            "dur": None, "args": {**self.tags, **args}})

    def note_recon(self, record: Dict[str, Any]) -> None:
        if self.enabled:
            self.recon.append(record)


#: Module-level disabled tracer — the default for every server, so call sites
#: never branch on `tracer is None`.  Never enable or `adopt()` onto it.
NULL_TRACER = Tracer(enabled=False, capacity=1)


# --------------------------------------------------------------------------
# chrome trace export
# --------------------------------------------------------------------------


def chrome_trace(tracer: Tracer,
                 reconciliation: Optional[Dict[str, Any]] = None
                 ) -> Dict[str, Any]:
    """Render a tracer's ring as a Chrome trace-event JSON object.

    One pid per replica tag, one tid row per lane string; "M" metadata events
    name both so Perfetto shows readable tracks.  Extra top-level keys
    (`otherData`, `reconciliation`) are ignored by viewers but carried for
    `trace_dump`.
    """
    pids: Dict[str, int] = {}
    tids: Dict[Tuple[int, str], int] = {}
    evs: List[Dict[str, Any]] = []
    for e in tracer.events:
        proc = str(e["args"].get("replica", tracer.tags.get("replica",
                                                            "serve")))
        if proc not in pids:
            pids[proc] = pid = len(pids) + 1
            evs.append({"name": "process_name", "ph": "M", "pid": pid,
                        "tid": 0, "ts": 0,
                        "args": {"name": f"repro/{proc}"}})
        pid = pids[proc]
        lane = (pid, str(e["tid"]))
        if lane not in tids:
            tids[lane] = tid = len(tids) + 1
            evs.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "ts": 0, "args": {"name": lane[1]}})
        tid = tids[lane]
        ts_us = e["ts"] * 1e6
        args = {"span_id": e["id"], **e["args"]}
        if e["dur"] is None:
            evs.append({"name": e["name"], "cat": e["cat"], "ph": "i",
                        "ts": ts_us, "pid": pid, "tid": tid, "s": "t",
                        "args": args})
        else:
            evs.append({"name": e["name"], "cat": e["cat"], "ph": "X",
                        "ts": ts_us, "dur": e["dur"] * 1e6, "pid": pid,
                        "tid": tid, "args": args})
    out: Dict[str, Any] = {"traceEvents": evs, "displayTimeUnit": "ms",
                           "otherData": {"tags": dict(tracer.tags)}}
    if reconciliation is not None:
        out["reconciliation"] = reconciliation
    return out


def validate_chrome_trace(obj: Any) -> int:
    """Validate a Chrome trace-event JSON object; return the event count.

    Raises ValueError on schema violations (missing/ill-typed fields, events
    that would not load in Perfetto / chrome://tracing)."""
    if not isinstance(obj, dict) or not isinstance(obj.get("traceEvents"),
                                                   list):
        raise ValueError("trace must be a dict with a traceEvents list")
    for i, e in enumerate(obj["traceEvents"]):
        if not isinstance(e, dict):
            raise ValueError(f"event {i}: not a dict")
        ph = e.get("ph")
        if ph not in ("X", "i", "M"):
            raise ValueError(f"event {i}: unsupported ph {ph!r}")
        if not isinstance(e.get("name"), str):
            raise ValueError(f"event {i}: name must be a string")
        if not isinstance(e.get("pid"), int) or not isinstance(
                e.get("tid"), int):
            raise ValueError(f"event {i}: pid/tid must be ints")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: bad dur {dur!r}")
        if "args" in e and not isinstance(e["args"], dict):
            raise ValueError(f"event {i}: args must be a dict")
    json.dumps(obj)   # must be serializable end to end
    return len(obj["traceEvents"])


def dump_chrome_trace(tracer: Tracer, path: str,
                      reconciliation: Optional[Dict[str, Any]] = None) -> int:
    """Write (and validate) a chrome trace file; return the event count."""
    obj = chrome_trace(tracer, reconciliation=reconciliation)
    n = validate_chrome_trace(obj)
    with open(path, "w") as fh:
        json.dump(obj, fh, indent=1)
    return n


def span_tree(events: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Nest tracer-ring duration events by time containment on each lane.

    Returns a forest of `{"name", "cat", "ts", "dur", "args", "children"}`
    nodes — the per-query span tree when given one query's events (see
    `JoinServer.query_trace`)."""
    lanes: Dict[str, List[Dict[str, Any]]] = {}
    for e in events:
        if e.get("dur") is None:
            continue
        lanes.setdefault(str(e["tid"]), []).append(e)
    forest: List[Dict[str, Any]] = []
    for lane in lanes.values():
        lane.sort(key=lambda e: (e["ts"], -e["dur"], e["id"]))
        stack: List[Dict[str, Any]] = []
        for e in lane:
            node = {"name": e["name"], "cat": e["cat"], "ts": e["ts"],
                    "dur": e["dur"], "args": e["args"], "children": []}
            end = e["ts"] + e["dur"]
            eps = 1e-9
            while stack and end > stack[-1]["ts"] + stack[-1]["dur"] + eps:
                stack.pop()
            (stack[-1]["children"] if stack else forest).append(node)
            if e["dur"] > 0:     # zero-duration markers are always leaves
                stack.append(node)
    return forest


# --------------------------------------------------------------------------
# byte reconciliation
# --------------------------------------------------------------------------


def recon_pair(name: str, modeled: float,
               measured: Optional[float]) -> Dict[str, Any]:
    """One modeled-vs-metered byte pair.  `measured=None` means the path has
    no meter for this cost (e.g. single-device serving moves no wire bytes);
    rel_error is the signed relative model error against the meter."""
    rel = None
    if measured is not None and measured > 0:
        rel = (float(modeled) - float(measured)) / float(measured)
    return {"name": name, "modeled": float(modeled),
            "measured": None if measured is None else float(measured),
            "rel_error": rel}


def reconciliation_report(records: Iterable[Dict[str, Any]],
                          server_pairs: Optional[List[Dict[str, Any]]] = None
                          ) -> Dict[str, Any]:
    """Aggregate per-query reconciliation records into a per-path report.

    `records` come from `Tracer.recon` (one dict per traced query, with a
    `path` tag and a `pairs` list); `server_pairs` are cumulative
    server-level pairs (amortized costs that have no per-query meter, e.g.
    the filter exchange, which is cached across queries)."""
    records = list(records)
    paths: Dict[str, Dict[str, Dict[str, float]]] = {}
    for r in records:
        agg = paths.setdefault(r["path"], {})
        for p in r["pairs"]:
            a = agg.setdefault(p["name"],
                               {"queries": 0, "modeled": 0.0,
                                "measured": 0.0, "metered_queries": 0})
            a["queries"] += 1
            a["modeled"] += p["modeled"]
            if p["measured"] is not None:
                a["measured"] += p["measured"]
                a["metered_queries"] += 1
    for agg in paths.values():
        for a in agg.values():
            if a["metered_queries"]:
                a["rel_error"] = ((a["modeled"] - a["measured"])
                                  / max(a["measured"], 1e-12))
            else:
                a["measured"] = None
                a["rel_error"] = None
    return {"queries": records, "paths": paths,
            "server": list(server_pairs or [])}


def format_reconciliation(report: Dict[str, Any]) -> str:
    """Human-readable rendering of a reconciliation report."""
    lines = []
    for path, agg in sorted(report["paths"].items()):
        lines.append(f"path {path}:")
        for name, a in agg.items():
            err = ("n/a (unmetered)" if a["rel_error"] is None
                   else f"{100 * a['rel_error']:+.1f}%")
            meas = ("-" if a["measured"] is None
                    else f"{a['measured']:.0f}")
            lines.append(f"  {name:<24} modeled {a['modeled']:>12.0f}  "
                         f"measured {meas:>12}  model err {err}  "
                         f"({a['queries']} queries)")
    if report["server"]:
        lines.append("server (cumulative/amortized):")
        for p in report["server"]:
            err = ("n/a (unmetered)" if p["rel_error"] is None
                   else f"{100 * p['rel_error']:+.1f}%")
            meas = ("-" if p["measured"] is None
                    else f"{p['measured']:.0f}")
            lines.append(f"  {p['name']:<24} modeled {p['modeled']:>12.0f}  "
                         f"measured {meas:>12}  model err {err}")
    return "\n".join(lines) if lines else "(no reconciliation records)"
