"""Batched multi-tenant ApproxJoin serving engine — single-device or mesh.

The ``JoinServer`` batches ApproxJoin queries the way LLM serving engines
batch token decodes across slots.  A :class:`JoinRequest`
carries relations (or a named dataset handle), a :class:`QueryBudget`, the
aggregate/expression, and a tenant ``query_id``.  The engine:

* **buckets** every relation to a power-of-two capacity
  (:func:`repro.core.relation.bucket_to_pow2`) so queries fall into a small
  number of *shape classes*;
* keeps a **compiled-executable cache** keyed by
  ``(stage, shape_class, batch)`` — repeat tenants never recompile.  Shape
  classes also key on the **mesh shape**, so a server can serve mixed
  single-device and distributed classes without collisions;
* **batches same-shape-class queries with vmap** across the
  filter-probe/sort/strata and sample/estimate stages, so one engine step is
  one fused device dispatch per stage regardless of how many tenants share
  it — and, when constructed with ``mesh=``, that one dispatch **spans all
  mesh devices** through ``core/distributed.py``'s shard_map pipeline;
* caches **per-dataset Bloom filter words** keyed by
  ``(relation fingerprint, num_blocks, seed)``: a registered dataset pays
  the filter build once, then every subsequent step reuses the cached words
  (``ServerDiagnostics.filter_builds`` / ``filter_cache_hits``);
* shares one :class:`SigmaRegistry` and :class:`CostModel` across tenants, so
  a repeated ``query_id`` gets the paper's §3.2-II adaptive sample sizing for
  free — and tenants never see each other's sigmas (the registry is keyed by
  ``query_id``).

Results are bit-identical to a direct :func:`repro.core.join.approx_join`
call on the same (bucketed) relations with the same seed — on a mesh too:
the distributed stages merge per-device strata/statistics back into the
canonical single-device slot layout before estimating, so a mesh of any size
reproduces the single-device arithmetic exactly (asserted across mesh sizes
1/2/4/8 in ``tests/test_join_serve_distributed.py``).

That bit-parity merge is the expensive one: per-stratum stats all_gather to
every device and the shuffle buckets default to the lossless worst case.  At
cluster scale the server can instead run ``serve_mode='psum'``: per-device
estimator parts merge with a single psum (the paper's own dataflow) and the
shuffle buckets are CAPACITY-PLANNED from the Bloom-intersection overlap
estimate taken at ``register_dataset`` time (the dry-run's overlap-hint
trick) — so the filter's data-movement saving reaches the wire of the
static-shape dataflow.  Rows beyond the plan are dropped *and counted*
(``ServerDiagnostics.dist_dropped_tuples``, per device in
``per_device_dropped_tuples``, per query in the result diagnostics).  psum
results agree with exact-parity up to float reassociation; the guarantee is
statistical, asserted by the accuracy gate (``tests/test_accuracy_gate.py``:
CLT-bounded relative error, nominal CI coverage, allocation-faithful
per-stratum draws, at mesh 1/2/4/8).  Shape classes key on
``(serve_mode, bucket_cap)`` too, so the two modes never collide in the
executable cache.

Per-query dynamic decisions (exact-affordable?  per-stratum ``b_i`` from the
budget + sigma feedback) stay on the host, exactly as in ``approx_join`` —
the driver role.  Sigma feedback lands *between engine steps*, which is why
the scheduler runs **cross-step sigma pipelining** (``sigma_pipeline``, on
by default): same-``query_id`` error-budget repeats co-batched into one step
would all see the registry state at dispatch time, so the scheduler defers
each repeat to the NEXT step — every execution sees the previous one's
measured sigma, bit-identical to a sequential driver — and fills the freed
slot with the next same-class query, so a queue with id diversity loses no
throughput (asserted in ``tests/test_join_serve.py``).

Scheduling is FIFO until the queue backs up past ``backlog_slots``, then
**deadline-aware**: latency-budget queries (deadline = submission +
``latency_s``) are served before error-budget/exact ones (deadline
infinity), FIFO on ties.  Queue latency is tracked as a bounded sample ring
and surfaced as p50/p95/max in ``ServerDiagnostics.snapshot()`` — the
distribution the admission policy consults (and the one ``serve_bench``
records).

``use_kernels`` queries are FIRST-CLASS batched citizens: kernel shape
classes flow through the same ``_batch_inputs``/``_run_batch`` machinery
and executable cache as the jnp classes, with kernel-backed stage
executables (``core.join.prepare_stage_kernels_batched`` /
``sample_stage_kernels_batched``) whose Pallas grids carry the slot
dimension themselves — a 2-D ``(batch_slot, key_block)`` sweep over the
stacked ``[B, num_blocks, 8]`` filter layout instead of a per-query loop.
Seeds (and the decoupled ``filter_seed``) are runtime array operands, so a
mixed-seed batch is one executable and N distinct seeds cost zero
recompiles; prebuilt/cached filter words (dataset cache, streaming window
OR-merges) feed the stacked probe directly.  The kernels are single-device:
a mesh server still serves them on the default device, gathering sharded
rows back to the host first — that round-trip is metered as
``ServerDiagnostics.kernel_gather_bytes`` (zero at mesh 1, where rows
already sit on the one device).

The streaming subsystem (``runtime/stream_join.py``) layers windowed
sessions on this engine: ``JoinRequest.filter_seed`` decouples the filter
hash from the sampling seed, ``_words`` carries a window's pre-merged
sub-window filter words past the per-dataset cache, and ``overlap_hint``
re-plans psum shuffle buckets from the session's rolling overlap estimate.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom
from repro.core.budget import QueryBudget
from repro.core.cost import CostModel, SigmaRegistry
from repro.core.distributed import (make_serve_exact, make_serve_exact_psum,
                                    make_serve_filter_build,
                                    make_serve_prepare, make_serve_sample,
                                    make_serve_sample_psum,
                                    planned_bucket_cap)
from repro.core.estimators import SumParts
from repro.core.join import (EXPRS, TUPLE_BYTES, JoinDiagnostics, JoinResult,
                             decide_sample_sizes, exact_stage,
                             filter_exchange_bytes, measured_sigma,
                             prepare_stage_kernels_batched, prepare_stage_pre,
                             sample_stage, sample_stage_kernels_batched)
from repro.core.plan import CompiledPlan, Plan, compile_plan
from repro.core.relation import (Relation, bucket_capacity, bucket_to_pow2,
                                 fingerprint, shard_to_mesh)
from repro.runtime.telemetry import (NULL_TRACER, Histogram, MetricsRegistry,
                                     Tracer, latency_pcts, recon_pair,
                                     span_tree)
from repro.runtime.telemetry import reconciliation_report as _recon_report

DEFAULT_B_MAX = 2048
AGGS = ("sum", "count", "avg", "stdev")
SERVE_MODES = ("exact-parity", "psum")


def tenant_of(query_id: str) -> str:
    """Tenant key of a query id — the ``'/'``-prefix convention
    (``'tenantA/sum0'`` -> ``'tenantA'``; un-prefixed ids are their own
    tenant).  The front door shards and steals by this key, and per-tenant
    latency percentiles group by it."""
    return query_id.split("/", 1)[0]


def bloom_overlap_estimate(rels: Sequence[Relation], fp_rate: float = 0.01,
                           seed: int = 0) -> float:
    """Planning-time live-fraction estimate from the Bloom intersection.

    Builds one filter per input, ANDs them, probes every input against the
    join filter and returns surviving/total — the same estimate the dry-run
    feeds as ``overlap_hint`` to size capacity-planned shuffle buckets.
    Biased UP only (Bloom false positives), so a bucket plan with slack on
    top of it errs on the lossless side.  One-off host-side work at dataset
    registration; the serving hot path never pays it.
    """
    num_blocks = bloom.num_blocks_for(max(r.capacity for r in rels), fp_rate)
    filters = [bloom.build(r.keys, r.valid, num_blocks, seed) for r in rels]
    jf = bloom.intersect_all(filters)
    live = sum(int(jax.device_get(jnp.sum(r.valid & bloom.contains(jf,
                                                                   r.keys))))
               for r in rels)
    total = sum(int(jax.device_get(r.count())) for r in rels)
    return live / max(total, 1)


class ShapeClass(NamedTuple):
    """Static compilation signature of a query (the executable-cache key).

    ``mesh`` is ``()`` for a single-device server, else the ordered
    ``(axis name, axis size)`` pairs of the join axes — so the same query
    stream served on different meshes compiles (and caches) per mesh shape.
    ``serve_mode`` and ``bucket_cap`` are part of the key too: the psum and
    exact-parity pipelines are different programs with different shapes
    (the shuffle buffers are ``bucket_cap``-sized), so entries of one mode
    can never collide with — or evict compilations of — the other.
    """

    caps: tuple[int, ...]    # per-side bucketed capacities
    n_inputs: int
    max_strata: int
    b_max: int
    expr: str
    agg: str
    dedup: bool
    use_kernels: bool
    fp_rate: float
    confidence: float
    mesh: tuple = ()
    serve_mode: str = "exact-parity"
    bucket_cap: int = 0      # mesh classes only; 0 = single-device


@dataclass(eq=False)
class JoinRequest:
    """One tenant query: relations (or dataset handle) + budget + query id.

    ``eq=False``: requests are identities, not values — a generated
    ``__eq__`` would compare the relation arrays (ambiguous-truth-value
    errors from jnp) and queue bookkeeping must never conflate two requests
    that happen to carry equal payloads.
    """

    rels: Optional[Sequence[Relation]] = None
    dataset: Optional[str] = None
    # multi-dataset handle (plan-node requests): the fused stage joins the
    # concatenation of the named datasets' relation lists, each resolved
    # through the same fingerprint path as a single-dataset handle — so a
    # table shared by several plan nodes builds its filter words once
    datasets: Optional[Sequence[str]] = None
    budget: QueryBudget = QueryBudget()
    agg: str = "sum"
    expr: str = "sum"
    query_id: str = "q0"
    seed: int = 0
    fp_rate: float = 0.01
    max_strata: Optional[int] = None
    b_max: Optional[int] = DEFAULT_B_MAX
    dedup: bool = False
    use_kernels: bool = False
    serve_mode: Optional[str] = None   # None -> the server's default
    # filter-hash seed, decoupled from the sampling seed so a streaming
    # session can vary draws per window while reusing cached filter words
    # (None -> ``seed``, the classic coupled behaviour)
    filter_seed: Optional[int] = None
    # psum bucket planning: live-fraction estimate overriding the dataset's
    # registration-time one (streaming sessions re-plan from the rolling
    # measured overlap)
    overlap_hint: Optional[float] = None
    # streaming metadata (set by StreamJoinSession)
    stream: Optional[str] = None
    window_id: Optional[int] = None
    # plan metadata (set by submit_plan): the owning plan's id and this
    # request's node name within it — restore_state regroups requests
    # carrying these into live PlanHandles, so a failover never drops an
    # in-flight plan
    plan: Optional[str] = None
    plan_node: Optional[str] = None
    # filled by the server
    result: Optional[JoinResult] = None
    done: bool = False
    shed: bool = False                 # dropped by admission control, unserved
    queue_latency_s: float = 0.0       # ingest -> dispatch (batch former wait)
    e2e_latency_s: float = 0.0         # ingest -> complete
    _class: Optional[ShapeClass] = field(default=None, repr=False)
    _submit_t: float = field(default=0.0, repr=False)
    # ingest -> dispatch -> complete timestamps (perf_counter).  The async
    # tier stamps _ingest_t at front-door ingestion, BEFORE engine
    # admission, so queue latency covers the ingress ring too; the
    # synchronous path stamps it in submit() (== _submit_t).
    _ingest_t: float = field(default=0.0, repr=False)
    _dispatch_t: float = field(default=0.0, repr=False)
    _complete_t: float = field(default=0.0, repr=False)
    # per-query completion future (async tier); resolved by the engine's
    # on_done hook for served AND shed requests
    _future: Optional[object] = field(default=None, repr=False)
    _fps: Optional[list[str]] = field(default=None, repr=False)
    # prebuilt per-side filter words (e.g. the OR of cached sub-window
    # words); when set, the batch path uses them verbatim instead of
    # fetching through the per-dataset cache
    _words: Optional[list] = field(default=None, repr=False)
    # compile-time byte model of the owning plan node (submit_plan copies
    # the node's node_bytes_model dict here) — the reconciliation report
    # pairs its bytes_pushdown against the serve-time metered bytes
    _bytes_model: Optional[dict] = field(default=None, repr=False)
    # tracer span id grouping every span of this request's execution
    # (unique per request instance, survives failover via Tracer.adopt)
    _span_id: Optional[int] = field(default=None, repr=False)


@dataclass
class PlanHandle:
    """An in-flight plan: one engine request per plan node.

    Node requests ride the normal queue (their query ids are
    ``'<plan_id>/<node>'``, so the whole plan is one tenant to the front
    door) and the handle is just the grouping — the engine tracks live
    handles in ``JoinServer.plans`` and drops a handle once every node
    finished, and ``restore_state`` rebuilds handles from the requests'
    plan metadata after a failover.
    """

    plan_id: str
    requests: dict = field(default_factory=dict)   # node name -> JoinRequest

    @property
    def done(self) -> bool:
        return all(r.done or r.shed for r in self.requests.values())

    def results(self) -> dict:
        """node name -> JoinResult (finished nodes only)."""
        return {name: r.result for name, r in self.requests.items()
                if r.done and r.result is not None}


# ServerDiagnostics scalar counters in snapshot order, with their comments:
#   queries..kernel_queries — served-query counts by decision/backend
#   queue_latency_s/e2e_latency_s — summed ingest->dispatch / ->complete
#   plan_compiles/plan_cache_hits — compiled-plan cache misses/reuses
#   sigma_deferrals — same-id repeats pushed to the next step
#   deadline_promotions — backlog steps served out of FIFO order
#   filter_s/filter_build_s/filter_builds/filter_cache_hits — Bloom stage
#   shuffled_bytes_saved — repartition-vs-filtered delta over served queries
#   kernel_gather_bytes — host gather bytes for kernel queries on a mesh
#     server (zero at mesh 1 and meshless — asserted in tests)
#   dist_shuffled_tuple_bytes — measured live bytes moved (mesh only)
#   dist_dropped_tuples — shuffle rows dropped beyond the bucket plan
#     (always 0 under the lossless exact-parity default)
#   dist_wire_bytes_model — static per-device collective-buffer bytes (the
#     Eq. 24 serve-time wire model; what a dense dataflow puts on the wire)
#   filter_exchange_bytes_model — summed §3.1 (n+1)-exchange model over
#     served queries; its metered counterpart below counts ACTUAL word
#     bytes put on the wire by mesh filter builds (cache hits move none),
#     so the pair exposes the serving tier's filter-exchange amortization
#   tenant_evictions — per-tenant latency rings LRU-evicted past tenant_cap
_DIAG_SCALAR_FIELDS = (
    "queries", "steps", "cache_hits", "compiles", "exact_queries",
    "sampled_queries", "kernel_queries", "queue_latency_s", "e2e_latency_s",
    "plan_compiles", "plan_cache_hits", "sigma_deferrals",
    "deadline_promotions", "filter_s", "filter_build_s", "filter_builds",
    "filter_cache_hits", "shuffled_bytes_saved", "kernel_gather_bytes",
    "dist_shuffled_tuple_bytes", "dist_dropped_tuples",
    "dist_wire_bytes_model", "filter_exchange_bytes_model",
    "filter_exchange_bytes_measured", "tenant_evictions", "max_batch")
# per-device f64 [k] meters (mesh servers only; None elsewhere)
_DIAG_VECTOR_FIELDS = ("per_device_shuffled_bytes",
                       "per_device_dropped_tuples")


class ServerDiagnostics:
    """Server-level counters (cumulative since construction).

    Every field is backed by a :class:`repro.runtime.telemetry.MetricsRegistry`
    metric (scalars by counters, per-device meters by gauges, the latency
    rings by histograms) — the registry is the single store behind
    ``snapshot()``, the Prometheus export, and the stream diagnostics that
    share it.  Attribute access routes through the registry, so the classic
    ``diag.queries += 1`` call sites (and the additive restore merge) are
    unchanged.

    Per-tenant latency rings are LRU-bounded at ``tenant_cap`` distinct
    tenants (an adversarial tenant-id stream must not grow ``per_tenant``
    without limit); evictions are counted in ``tenant_evictions``.
    """

    _SCALARS = frozenset(_DIAG_SCALAR_FIELDS)
    _VECTORS = frozenset(_DIAG_VECTOR_FIELDS)

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 tenant_cap: int = 256):
        self.registry = MetricsRegistry() if registry is None else registry
        self.tenant_cap = tenant_cap
        for f in _DIAG_SCALAR_FIELDS:
            self.registry.counter("serve_" + f)
        for f in _DIAG_VECTOR_FIELDS:
            self.registry.gauge("serve_" + f)
        # bounded rings of recent per-query latencies; snapshot() reduces
        # each to p50/p95/max (the distributions the deadline-aware
        # admission and the async tier's SLO reporting consult — a running
        # sum cannot see tail latency)
        self._q_hist = self.registry.histogram("serve_queue_latencies")
        self._e_hist = self.registry.histogram("serve_e2e_latencies")
        # tenant -> (queue Histogram, e2e Histogram), LRU order: a front
        # door reading one replica snapshot can attribute a latency
        # regression to a tenant
        self._tenants: OrderedDict = OrderedDict()

    def __getattr__(self, name):
        # only reached when normal lookup fails — i.e. the registry-backed
        # fields and the legacy ring views
        d = object.__getattribute__(self, "__dict__")
        reg = d.get("registry")
        if reg is not None:
            if name in self._SCALARS:
                return reg.counter("serve_" + name).value
            if name in self._VECTORS:
                return reg.gauge("serve_" + name).value
            if name == "queue_latencies":
                return d["_q_hist"].samples
            if name == "e2e_latencies":
                return d["_e_hist"].samples
            if name == "tenant_latencies":
                return {t: (qh.samples, eh.samples)
                        for t, (qh, eh) in d["_tenants"].items()}
        raise AttributeError(name)

    def __setattr__(self, name, value):
        if name in self._SCALARS:
            self.registry.counter("serve_" + name).value = value
        elif name in self._VECTORS:
            self.registry.gauge("serve_" + name).value = value
        else:
            object.__setattr__(self, name, value)

    def note_latency(self, tenant: str, queue_s: float, e2e_s: float,
                     cap: int) -> None:
        """Record one finished query's ingest->dispatch / ingest->complete
        latencies into the global and per-tenant bounded rings."""
        self.queue_latency_s += queue_s
        self.e2e_latency_s += e2e_s
        per = self._tenants.get(tenant)
        if per is None:
            per = (Histogram(f"tenant_queue_latencies/{tenant}", cap),
                   Histogram(f"tenant_e2e_latencies/{tenant}", cap))
            self._tenants[tenant] = per
            while len(self._tenants) > self.tenant_cap:
                self._tenants.popitem(last=False)
                self.tenant_evictions += 1
        else:
            self._tenants.move_to_end(tenant)
        for hist, x in ((self._q_hist, queue_s), (self._e_hist, e2e_s),
                        (per[0], queue_s), (per[1], e2e_s)):
            hist.cap = cap
            hist.observe(x)

    def reset_latencies(self) -> None:
        """Clear the latency sample rings (cumulative counters stay).  A
        bench reusing one warmed server calls this between timed segments
        so warmup-era samples cannot leak into a later segment's
        percentiles."""
        self._q_hist.reset_samples()
        self._e_hist.reset_samples()
        self._tenants.clear()

    @staticmethod
    def _pcts(lat, prefix: str) -> dict:
        return latency_pcts(lat, prefix)

    def scalars(self) -> dict:
        """The scalar counters as a plain dict (the crash-safe meta form)."""
        return {f: getattr(self, f) for f in _DIAG_SCALAR_FIELDS}

    def prometheus(self, prefix: str = "repro") -> str:
        """Prometheus text exposition of the backing registry."""
        return self.registry.prometheus(prefix)

    def snapshot(self) -> dict:
        """Point-in-time dict view — strictly read-only and idempotent:
        building a snapshot mutates nothing, and two consecutive snapshots
        of an idle server are equal (asserted in tests)."""
        d: dict = self.scalars()
        for f in _DIAG_VECTOR_FIELDS:
            v = getattr(self, f)
            d[f] = None if v is None else [float(x) for x in v]
        d.update(latency_pcts(self._q_hist.samples, "queue_latency"))
        d.update(latency_pcts(self._e_hist.samples, "e2e_latency"))
        d["per_tenant"] = {
            t: {"samples": len(qh.samples),
                **latency_pcts(qh.samples, "queue_latency"),
                **latency_pcts(eh.samples, "e2e_latency")}
            for t, (qh, eh) in self._tenants.items()}
        return d


def shape_class_of(req: JoinRequest, mesh_shape: tuple = (),
                   serve_mode: str = "exact-parity",
                   bucket_cap: int = 0) -> ShapeClass:
    caps = tuple(bucket_capacity(r.capacity) for r in req.rels)
    return ShapeClass(caps, len(caps), req.max_strata, req.b_max,
                      req.expr, req.agg, req.dedup, req.use_kernels,
                      req.fp_rate, req.budget.confidence, mesh_shape,
                      serve_mode, bucket_cap)


def _make_prepare(max_strata: int):
    def fn(rels, words, seed):
        return prepare_stage_pre(rels, words, max_strata, seed)
    return jax.jit(jax.vmap(fn))


def _make_sample(b_max: int, agg: str, dedup: bool, confidence: float,
                 expr: str):
    f_fn = EXPRS[expr][0]
    def fn(sorted_rels, strata, b_i, seed):
        return sample_stage(sorted_rels, strata, b_i, b_max, seed,
                            agg=agg, dedup=dedup, confidence=confidence,
                            f_fn=f_fn)
    return jax.jit(jax.vmap(fn))


def _make_exact(agg: str, expr: str):
    def fn(sorted_rels, strata):
        return exact_stage(sorted_rels, strata, agg=agg, expr=expr)
    return jax.jit(jax.vmap(fn))


def _make_filter_build(num_blocks: int):
    def fn(keys, valid, seed):
        return bloom.build(keys, valid, num_blocks, seed).words
    return jax.jit(fn)


# -- kernel-backed stage builders (Pallas grids own the slot dimension, so
# -- these take the engine's slot-stacked batch directly instead of vmap) ---

def _make_prepare_kernels(max_strata: int, interpret: bool):
    def fn(rels, words, seeds):
        return prepare_stage_kernels_batched(rels, words, max_strata, seeds,
                                             interpret=interpret)
    return jax.jit(fn)


def _make_sample_kernels(b_max: int, agg: str, confidence: float, expr: str,
                         interpret: bool):
    def fn(sorted_rels, strata, b_i, seeds):
        return sample_stage_kernels_batched(
            sorted_rels, strata, b_i, b_max, seeds, agg=agg,
            confidence=confidence, expr=expr, interpret=interpret)
    return jax.jit(fn)


def _make_filter_build_kernels(num_blocks: int, interpret: bool):
    from repro.kernels import ops as kops

    def fn(keys, valid, seed):
        return kops.build_filter(keys, valid, num_blocks, seed,
                                 interpret=interpret).words
    return jax.jit(fn)


class JoinServer:
    """Slot-based batched ApproxJoin engine (caller-driven ``step()`` loop;
    ``runtime/async_serve.py`` wraps it into an always-on event loop).

    ``mesh=None`` serves every batch on the default device.  With a
    ``jax.sharding.Mesh``, registered datasets are sharded over
    ``join_axes`` at :meth:`register_dataset` time and every engine step's
    fused dispatch runs through the shard_map pipeline — one batched step
    spans all mesh devices, with bit-identical results.

    ``bucket_cap`` bounds the per-(source, dest) shuffle buckets of the
    distributed path; the default (local rows) can never drop a row, which
    the bit-parity guarantee needs — tighter caps trade memory for counted
    overflow (surfaced in the result diagnostics).

    ``serve_mode`` picks the cluster-scale merge strategy (overridable per
    request):

    * ``'exact-parity'`` (default): gather merge, lossless buckets —
      bit-identical to the single-device pipeline at any mesh size.
    * ``'psum'``: single-psum merge of estimator parts + buckets
      capacity-planned from the dataset's Bloom-intersection overlap
      estimate — the paper's cheap-collective dataflow; accuracy is
      statistical (the accuracy gate), dropped rows are counted.
    """

    def __init__(self, *, batch_slots: int = 4,
                 cost_model: Optional[CostModel] = None,
                 sigma_registry: Optional[SigmaRegistry] = None,
                 mesh=None, join_axes: Optional[Sequence[str]] = None,
                 bucket_cap: Optional[int] = None,
                 serve_mode: str = "exact-parity",
                 filter_cache_entries: int = 256,
                 sigma_pipeline: bool = True,
                 backlog_slots: Optional[int] = None,
                 latency_samples: int = 4096,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        assert serve_mode in SERVE_MODES, serve_mode
        self.serve_mode = serve_mode
        self.batch_slots = batch_slots
        # cross-step sigma pipelining: same-query_id error-budget repeats
        # are deferred to the NEXT step so each sees the previous
        # execution's measured sigma (sequential-feedback adaptive sizing);
        # slots freed by a deferral fill with other same-class queries
        self.sigma_pipeline = sigma_pipeline
        # queue length beyond which the scheduler goes deadline-aware:
        # latency-budget queries (deadline = submit + latency_s) are served
        # before error-budget/exact ones (deadline = infinity), FIFO on ties
        self.backlog_slots = 2 * batch_slots if backlog_slots is None \
            else backlog_slots
        self.latency_samples = latency_samples
        self.cost_model = cost_model
        self.sigma = SigmaRegistry() if sigma_registry is None \
            else sigma_registry
        self.queue: list[JoinRequest] = []
        self.datasets: dict[str, list[Relation]] = {}
        self._dataset_fps: dict[str, list[str]] = {}
        self._dataset_overlap: dict[str, float] = {}
        self._exec_cache: dict = {}
        # compiled plans, cached by plan signature the way shape classes key
        # the executable cache: resubmitting a plan shape skips the
        # flatten/validate/cost pass entirely (per-node stage executables
        # land in _exec_cache through the normal shape-class route)
        self._plan_cache: dict = {}
        self.plans: dict[str, PlanHandle] = {}   # in-flight plan handles
        # LRU of (fingerprint, num_blocks, seed) -> words: bounded so a
        # long-running server with ever-fresh seeds cannot accumulate
        # device-resident filter words without limit
        self._filter_words: OrderedDict = OrderedDict()
        self.filter_cache_entries = filter_cache_entries
        # telemetry: a disabled NULL_TRACER by default — span()/event()/
        # instant() early-return, so the untraced hot path pays one
        # attribute read per site.  The metrics registry is the single
        # backing store of the diagnostics (and of a StreamDiagnostics
        # sharing it); `tracer.tags` carries replica/mesh identity into
        # every recorded event.
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.trace_name = "engine"   # lane/replica label for step spans
        self.diagnostics = ServerDiagnostics(registry=metrics)
        # per-step scratch the tracer consumes (None while tracing is off)
        self._stage_trace: Optional[dict] = None
        self._recon_batch: Optional[dict] = None
        # completion callback (request -> None), fired by _notify_done for
        # every finished or shed request; the async tier installs its
        # future-resolver here
        self.on_done = None
        self.mesh = mesh
        self.bucket_cap = bucket_cap
        if mesh is not None:
            axes = tuple(join_axes) if join_axes is not None \
                else tuple(mesh.axis_names)
            assert all(a in mesh.axis_names for a in axes), (axes, mesh)
            self.join_axes = axes
            self.mesh_k = 1
            for a in axes:
                self.mesh_k *= mesh.shape[a]
            self.mesh_shape = tuple((a, mesh.shape[a]) for a in axes)
            self.diagnostics.per_device_shuffled_bytes = np.zeros(
                self.mesh_k, np.float64)
            self.diagnostics.per_device_dropped_tuples = np.zeros(
                self.mesh_k, np.float64)
        else:
            self.join_axes = ()
            self.mesh_k = 1
            self.mesh_shape = ()
        if tracer is not None and self.mesh is not None:
            tracer.tags.setdefault(
                "mesh", "x".join(str(s) for _, s in self.mesh_shape))

    # -- admission ----------------------------------------------------------

    def _admit_rels(self, rels: Sequence[Relation]) -> list[Relation]:
        rels = [bucket_to_pow2(r, minimum=self.mesh_k) for r in rels]
        if self.mesh is not None:
            rels = [shard_to_mesh(r, self.mesh, self.join_axes) for r in rels]
        return rels

    def register_dataset(self, name: str, rels: Sequence[Relation]) -> None:
        """Store a named (bucketed, mesh-sharded) dataset for handle queries.

        Fingerprints are taken here, once — N steps over the dataset build
        its Bloom filter words exactly once per ``(num_blocks, seed)``, and
        re-registering identical relations under a new name reuses the same
        cached words.  On a mesh the Bloom-intersection overlap estimate is
        also taken here (on the host copy, before device placement) — it
        sizes the capacity-planned shuffle buckets of psum-mode queries.
        """
        if self.mesh is not None:
            self._dataset_overlap[name] = bloom_overlap_estimate(rels)
        self.datasets[name] = self._admit_rels(rels)
        self._dataset_fps[name] = [fingerprint(r) for r in self.datasets[name]]

    def submit(self, req: JoinRequest) -> JoinRequest:
        if req.rels is None:
            if req.datasets is not None:
                for name in req.datasets:
                    if name not in self.datasets:
                        raise ValueError(f"unknown dataset {name!r}")
                req.rels = [r for name in req.datasets
                            for r in self.datasets[name]]
                req._fps = [fp for name in req.datasets
                            for fp in self._dataset_fps[name]]
            elif req.dataset is not None:
                req.rels = self.datasets[req.dataset]
                req._fps = self._dataset_fps[req.dataset]
            else:
                raise ValueError("JoinRequest needs rels or a dataset handle")
        else:
            # inline relations are NOT fingerprinted: hashing every ad-hoc
            # submission would put a device_get + sha1 of the whole key set
            # on the admission hot path to feed a cache that only pays off
            # for repeated identical key sets — that contract belongs to
            # register_dataset.  Their filter words build per step, uncached.
            req.rels = self._admit_rels(req.rels)
            req._fps = [None] * len(req.rels)
        if len(req.rels) < 2:
            raise ValueError("join needs at least two relations")
        if req.expr not in EXPRS:
            raise ValueError(f"unknown expr {req.expr!r}")
        if req.agg not in AGGS:
            raise ValueError(f"unknown agg {req.agg!r}")
        if req.max_strata is None:
            # size from the LARGEST input (mirrors approx_join): the old
            # rels[0] default under-sized the strata grid whenever a later
            # relation was bigger, silently inflating strata_overflow
            req.max_strata = max(r.capacity for r in req.rels)
        if req.b_max is None:
            # approx_join's b_max=None adaptive grid sizes the draw capacity
            # from data-dependent peak b_i — incompatible with a pre-keyed
            # executable cache, so refuse rather than silently diverge.
            raise ValueError("JoinServer needs a concrete b_max "
                             f"(e.g. the default {DEFAULT_B_MAX}); the "
                             "adaptive b_max=None grid is driver-side only")
        mode = req.serve_mode or self.serve_mode
        if mode not in SERVE_MODES:
            raise ValueError(f"unknown serve_mode {mode!r}")
        if self.mesh is None or req.use_kernels:
            # psum vs exact-parity only distinguishes mesh merge strategies;
            # off-mesh (and on the single-device kernel route) there is one
            # pipeline and it IS the exact one
            mode = "exact-parity"
        req._class = shape_class_of(
            req, () if req.use_kernels else self.mesh_shape, mode,
            self._planned_cap(req, mode))
        req._submit_t = time.perf_counter()
        if not req._ingest_t:
            # async ingestion pre-stamps _ingest_t at the front door so the
            # ingress-ring wait counts; the synchronous path starts here
            req._ingest_t = req._submit_t
        if self.tracer.enabled:
            if req._span_id is None:
                req._span_id = self.tracer.next_id()
            self.tracer.instant(
                "ingest", cat="admission", tid=self.trace_name,
                ts=req._ingest_t, query_id=req.query_id,
                tenant=tenant_of(req.query_id), qspan=req._span_id)
        self.queue.append(req)
        return req

    # -- query plans --------------------------------------------------------

    def compile_plan(self, plan: Plan) -> CompiledPlan:
        """Compile (or fetch) a plan against this server's datasets.

        Flattening, validation, and the pushdown-vs-binary byte model run
        once per plan signature; repeats are cache hits.  Registering new
        data under a name already baked into a cached plan is fine — the
        compiled form only holds dataset *names*; relations resolve at
        submit time through the normal handle path.
        """
        key = plan.signature()
        compiled = self._plan_cache.get(key)
        if compiled is None:
            with self.tracer.span("plan-compile", cat="plan",
                                  tid=self.trace_name,
                                  nodes=len(plan.nodes)):
                compiled = compile_plan(plan, self.datasets)
            self._plan_cache[key] = compiled
            self.diagnostics.plan_compiles += 1
        else:
            self.diagnostics.plan_cache_hits += 1
        return compiled

    def submit_plan(self, plan: Plan, *, query_id: str = "plan0",
                    seed: int = 0, serve_mode: Optional[str] = None,
                    use_kernels: Optional[bool] = None) -> PlanHandle:
        """Submit every node of a plan as one engine request each.

        Node requests are ordinary queue entries (query id
        ``'<query_id>/<node>'``), so each node's result is bit-identical to
        a direct ``approx_join`` over its flattened leaf relations with the
        node's own budget — the compiler changes *what* is submitted, never
        how it executes.  The compiled byte model's live fraction seeds each
        request's ``overlap_hint`` (psum bucket planning).
        """
        compiled = self.compile_plan(plan)
        handle = PlanHandle(query_id)
        # plan -> node span hierarchy: node spans carry plan/plan_node args
        # and this instant carries the node-reference edges, so trace
        # consumers (trace_dump) can nest each node's query span under the
        # nodes that reference it
        self.tracer.instant("plan", cat="plan", tid=self.trace_name,
                            plan=query_id, hierarchy=plan.hierarchy())
        for cn in compiled.nodes:
            node = cn.node
            model = compiled.bytes_model.get(node.name)
            req = JoinRequest(
                datasets=cn.datasets, budget=node.budget, agg=node.agg,
                expr=node.expr, query_id=f"{query_id}/{node.name}",
                seed=seed, fp_rate=node.fp_rate, max_strata=node.max_strata,
                b_max=node.b_max, dedup=node.dedup,
                use_kernels=node.use_kernels if use_kernels is None
                else use_kernels,
                serve_mode=serve_mode,
                overlap_hint=None if model is None else model["overlap"],
                plan=query_id, plan_node=node.name)
            req._bytes_model = None if model is None else dict(model)
            self.submit(req)
            handle.requests[node.name] = req
        self.plans[query_id] = handle
        return handle

    def _planned_cap(self, req: JoinRequest, mode: str) -> int:
        """Static per-(source, dest) shuffle bucket capacity for this query.

        exact-parity: the lossless worst case (local rows) unless the server
        was constructed with an explicit ``bucket_cap``.  psum: planned from
        the dataset's registration-time Bloom overlap estimate with 2x slack
        (the dry-run's overlap-hint trick), pow2-bucketed so near-identical
        estimates share one compiled executable; inline relations (no
        registration, no estimate) fall back to overlap 1.0 — still the
        2x/k uniform-hashing plan, just not filter-informed.
        """
        if self.mesh is None or req.use_kernels:
            return 0
        local_n = max(bucket_capacity(r.capacity) for r in req.rels) \
            // self.mesh_k
        if self.bucket_cap:
            return min(self.bucket_cap, local_n)
        if mode != "psum":
            return local_n
        overlap = req.overlap_hint
        if overlap is None:
            overlap = self._dataset_overlap.get(req.dataset, 1.0)
        cap = planned_bucket_cap(local_n, self.mesh_k, overlap)
        return min(bucket_capacity(cap), local_n)

    # -- executable + filter-word caches ------------------------------------

    def _executable(self, stage: str, cls, variant, builder):
        """Fetch-or-build a compiled executable; ``variant`` is the rest of
        the cache key (batch bucket for vmapped stages, seed for the
        static-seed kernel route).  Returns (fn, freshly_built)."""
        key = (stage, cls, variant)
        fn = self._exec_cache.get(key)
        fresh = fn is None
        if fresh:
            fn = builder()
            self._exec_cache[key] = fn
            self.diagnostics.compiles += 1
        else:
            self.diagnostics.cache_hits += 1
        return fn, fresh

    def _words_for(self, rel: Relation, fp: Optional[str], num_blocks: int,
                   seed: int, use_kernels: bool = False) -> jnp.ndarray:
        """Per-relation dataset-filter words, built once per (fp, nb, seed).

        ``fp=None`` (inline relations) always builds — no cache entry.  On a
        mesh the build runs sharded (local build + OR-reduce) and the cached
        words are replicated — bit-identical to a single-device build.
        ``use_kernels`` routes a meshless build through the Pallas hash
        kernel; the words are bit-identical either way (asserted in
        ``tests/test_kernels.py``), so kernel and jnp queries share one
        word cache without divergence.
        """
        key = (fp, num_blocks, seed)
        if fp is not None:
            words = self._filter_words.get(key)
            if words is not None:
                self._filter_words.move_to_end(key)
                self.diagnostics.filter_cache_hits += 1
                return words
        t0 = time.perf_counter()
        if self.mesh is not None:
            build, _ = self._executable(
                "fbuild", (rel.capacity, num_blocks, self.mesh_shape), None,
                partial(make_serve_filter_build, self.mesh, self.join_axes,
                        num_blocks=num_blocks))
        elif use_kernels:
            from repro.kernels import ops as kops
            build, _ = self._executable(
                "fbuild_k", (rel.capacity, num_blocks), None,
                partial(_make_filter_build_kernels, num_blocks,
                        kops.use_interpret()))
        else:
            build, _ = self._executable(
                "fbuild", (rel.capacity, num_blocks), None,
                partial(_make_filter_build, num_blocks))
        words = build(rel.keys, rel.valid, jnp.uint32(seed))
        jax.block_until_ready(words)
        if self.mesh is not None and self.mesh_k > 1:
            # metered filter-exchange bytes: a mesh build OR-reduces local
            # words across k devices, putting ~(k-1) copies of the word
            # array on the wire; cache hits move nothing — so this meter
            # vs the per-query §3.1 model exposes the cache amortization
            self.diagnostics.filter_exchange_bytes_measured += \
                float(words.size * words.dtype.itemsize) * (self.mesh_k - 1)
        if fp is not None:
            self._filter_words[key] = words
            while len(self._filter_words) > self.filter_cache_entries:
                self._filter_words.popitem(last=False)
        self.diagnostics.filter_builds += 1
        self.diagnostics.filter_build_s += time.perf_counter() - t0
        return words

    # -- engine -------------------------------------------------------------

    def _deadline(self, req: JoinRequest) -> float:
        """Absolute serve-by time: latency budgets are deadlines, error and
        exact budgets are best-effort (infinite deadline)."""
        if req.budget.latency_s is None:
            return float("inf")
        # relative to INGESTION: through the async tier the caller's clock
        # starts when submit() returns the future, not when the event loop
        # admits the request (synchronously the two coincide)
        return req._ingest_t + req.budget.latency_s

    def _slot_cap(self, cls: ShapeClass) -> int:
        """Batch width cap for one step of this shape class.

        Kernel classes stack per-slot filters and value arrays in VMEM, so
        the per-slot working set divides the kernel budget: a class whose
        single-query footprint was fine under the old per-query loop must
        still serve — in narrower batches — rather than trip the wrappers'
        stacked-layout asserts.  Floored to a power of two (batches pad to
        their pow2 bucket, and pad slots occupy real VMEM slots too); at
        1 the capacity is exactly the retired per-query path's.
        """
        if not cls.use_kernels:
            return self.batch_slots
        from repro.kernels import bloom_probe, edge_sample
        filter_bytes = bloom.num_blocks_for(max(cls.caps), cls.fp_rate) \
            * bloom.WORDS_PER_BLOCK * 4
        values_bytes = max(cls.caps) * 4
        cap = min(bloom_probe.VMEM_FILTER_LIMIT // filter_bytes,
                  edge_sample.VMEM_VALUES_LIMIT // values_bytes,
                  self.batch_slots)
        cap = max(cap, 1)
        return 1 << (cap.bit_length() - 1)          # floor to pow2

    def _take_batch(self) -> tuple:
        """Pick the next step's shape class and batch.

        FIFO until the queue backs up past ``backlog_slots``; then
        deadline-aware — the class of the tightest-deadline request is
        served, and within the class candidates are ordered by deadline
        (stable, so all-error queues stay FIFO).  With ``sigma_pipeline``,
        at most one error-budget request per ``query_id`` joins a batch:
        the repeat is deferred one step so it sees this step's measured
        sigma (sequential-feedback adaptive sizing), and its slot fills
        with the next same-class query instead.
        """
        backlog = len(self.queue) > self.backlog_slots
        if backlog:
            head = min(self.queue, key=self._deadline)
            if head._class != self.queue[0]._class:
                self.diagnostics.deadline_promotions += 1
            cls = head._class
        else:
            cls = self.queue[0]._class
        candidates = [r for r in self.queue if r._class == cls]
        if backlog:
            candidates.sort(key=self._deadline)   # stable: FIFO on ties
        batch, seen_ids = [], set()
        slots = self._slot_cap(cls)
        for r in candidates:
            if len(batch) == slots:
                break
            if (self.sigma_pipeline and r.budget.error is not None
                    and r.query_id in seen_ids):
                self.diagnostics.sigma_deferrals += 1
                continue
            batch.append(r)
            seen_ids.add(r.query_id)
        taken = set(map(id, batch))
        self.queue = [r for r in self.queue if id(r) not in taken]
        return cls, batch

    def step(self) -> int:
        """Serve one batch of same-shape-class queries; returns batch size."""
        if not self.queue:
            return 0
        t_form = time.perf_counter()
        cls, batch = self._take_batch()
        t_dispatch = time.perf_counter()
        self.diagnostics.steps += 1
        self.diagnostics.max_batch = max(self.diagnostics.max_batch,
                                         len(batch))
        self._run_batch(cls, batch)
        t_done = time.perf_counter()
        for req in batch:
            req._dispatch_t = t_dispatch
            req._complete_t = t_done
            req.queue_latency_s = t_dispatch - req._ingest_t
            req.e2e_latency_s = t_done - req._ingest_t
            req.done = True
            self.diagnostics.note_latency(
                tenant_of(req.query_id), req.queue_latency_s,
                req.e2e_latency_s, self.latency_samples)
            self.diagnostics.queries += 1
            d = req.result.diagnostics
            self.diagnostics.shuffled_bytes_saved += float(
                d.shuffled_bytes_repartition - d.shuffled_bytes_filtered)
            self._notify_done(req)
        if self.tracer.enabled:
            self._trace_step(cls, batch, t_form, t_dispatch, t_done)
        self._stage_trace = self._recon_batch = None
        return len(batch)

    def _path_of(self, cls: ShapeClass) -> str:
        """Serving-path tag for trace/reconciliation grouping."""
        if cls.use_kernels:
            return "kernel"
        if cls.mesh:
            return f"mesh{self.mesh_k}/{cls.serve_mode}"
        return "single"

    def _trace_step(self, cls: ShapeClass, batch: list[JoinRequest],
                    t_form: float, t_dispatch: float, t_done: float) -> None:
        """Emit the step's spans: one engine-lane group (batch-formation,
        step, stage timings) plus a complete per-query span tree (query ->
        queued/execute -> prepare/filter-exchange/shuffle/sample|exact ->
        complete) on a lane per request instance, and the per-query byte
        reconciliation records collected by ``_run_batch``."""
        tr, lane, path = self.tracer, self.trace_name, self._path_of(cls)
        tr.event("batch-formation", t_form, t_dispatch - t_form, cat="batch",
                 tid=lane, batch=len(batch), path=path)
        tr.event("step", t_dispatch, t_done - t_dispatch, cat="serve",
                 tid=lane, batch=len(batch), path=path)
        stages = self._stage_trace or {}
        for name, (ts, dur, extra) in stages.items():
            tr.event(name, ts, dur, cat="stage", tid=lane, path=path,
                     **extra)
        recs = self._recon_batch or {}
        for req in batch:
            tid = f"q:{req.query_id}#{req._span_id}"
            base = dict(query_id=req.query_id, qspan=req._span_id, path=path)
            if req.stream is not None:
                base.update(stream=req.stream, window=req.window_id)
            if req.plan is not None:
                base.update(plan=req.plan, plan_node=req.plan_node)
            tr.event("query", req._ingest_t,
                     req._complete_t - req._ingest_t, cat="query", tid=tid,
                     seed=req.seed, tenant=tenant_of(req.query_id), **base)
            tr.event("queued", req._ingest_t,
                     req._dispatch_t - req._ingest_t, cat="query", tid=tid,
                     **base)
            tr.event("execute", req._dispatch_t,
                     req._complete_t - req._dispatch_t, cat="query", tid=tid,
                     **base)
            for name, (ts, dur, extra) in stages.items():
                tr.event(name, ts, dur, cat="stage", tid=tid, **base,
                         **extra)
            rec = recs.get(id(req))
            if rec is not None:
                tr.note_recon(rec)
                # zero-duration sub-phase markers carrying the byte pairs
                # (filter exchange and shuffle are fused into the prepare
                # dispatch — one XLA program — so they mark, not span)
                p_ts, p_dur, _ = stages.get("prepare",
                                            (req._dispatch_t, 0.0, None))
                pairs = {p["name"]: p for p in rec["pairs"]}
                fe = pairs.get("filter_exchange_bytes")
                if fe is not None:
                    tr.event("filter-exchange", p_ts + p_dur, 0.0,
                             cat="stage", tid=tid, modeled=fe["modeled"],
                             **base)
                sh = pairs.get("live_tuple_bytes")
                if sh is not None:
                    tr.event("shuffle", p_ts + p_dur, 0.0, cat="stage",
                             tid=tid, modeled=sh["modeled"],
                             measured=sh["measured"], **base)
            tr.instant("complete", cat="query", tid=tid,
                       ts=req._complete_t, **base)

    def _notify_done(self, req: JoinRequest) -> None:
        """Completion hook — fires once per finished OR shed request.  The
        async tier resolves the request's per-query future here; the hook
        runs after the result (or the shed flag) is fully populated."""
        if self.on_done is not None:
            self.on_done(req)
        if req.plan is not None:
            handle = self.plans.get(req.plan)
            if handle is not None and handle.done:
                del self.plans[req.plan]

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0:
                break

    # -- crash safety: snapshot / restore -----------------------------------
    #
    # A snapshot is ``(flat arrays, meta)``: every device-resident piece of
    # engine state as a flat {key: array} dict (what runtime/checkpoint.py
    # serializes, one .npy + checksum per key) plus a JSON-able meta dict
    # carrying the host-side structure (dataset names/fingerprints, the
    # sigma table, queue descriptors, scalar counters).  Keys are
    # index-based (``ds/0/1/keys``) so user-chosen names never have to
    # round-trip through a file name.  NOT captured: the executable cache
    # (recompiles on the restoring server — a warmup cost, not state) and
    # in-flight latency timestamps (latency across a crash is ill-defined;
    # restored requests re-stamp at restore admission).

    # scalar diagnostics that survive a crash (cumulative counters; the
    # latency rings and per-device arrays restart empty)
    _DIAG_SCALARS = _DIAG_SCALAR_FIELDS

    @staticmethod
    def _req_meta(req: JoinRequest) -> dict:
        return {"dataset": req.dataset,
                "datasets": None if req.datasets is None
                else list(req.datasets),
                "plan": req.plan, "plan_node": req.plan_node,
                "budget": list(req.budget),
                "agg": req.agg, "expr": req.expr, "query_id": req.query_id,
                "seed": req.seed, "fp_rate": req.fp_rate,
                "max_strata": req.max_strata, "b_max": req.b_max,
                "dedup": req.dedup, "use_kernels": req.use_kernels,
                "serve_mode": req.serve_mode, "filter_seed": req.filter_seed,
                "overlap_hint": req.overlap_hint, "stream": req.stream,
                "window_id": req.window_id,
                "n_rels": len(req.rels) if req.rels is not None else 0,
                "n_words": 0 if req._words is None else len(req._words)}

    @staticmethod
    def _rel_arrays(flat: dict, prefix: str, r: Relation) -> None:
        flat[f"{prefix}/keys"] = r.keys
        flat[f"{prefix}/values"] = r.values
        flat[f"{prefix}/valid"] = r.valid

    def _rel_restore(self, flat: dict, prefix: str) -> Relation:
        r = Relation(jnp.asarray(flat[f"{prefix}/keys"]),
                     jnp.asarray(flat[f"{prefix}/values"]),
                     jnp.asarray(flat[f"{prefix}/valid"]))
        if self.mesh is not None:
            r = shard_to_mesh(r, self.mesh, self.join_axes)
        return r

    def snapshot_state(self) -> tuple[dict, dict]:
        """Capture the full serving state as ``(flat arrays, meta)``.

        Feed the pair to :func:`repro.runtime.checkpoint.save_checkpoint`
        (``tree=flat``, ``extra=meta``); the inverse is ``load_checkpoint``
        + :meth:`restore_state`.  The capture is synchronous with respect to
        engine mutation — call between steps (the async tier snapshots on
        its loop thread under the engine lock)."""
        flat: dict = {}
        meta: dict = {}
        ds_meta = []
        for di, (name, rels) in enumerate(self.datasets.items()):
            for i, r in enumerate(rels):
                self._rel_arrays(flat, f"ds/{di}/{i}", r)
            ds_meta.append({"name": name, "n": len(rels),
                            "fps": self._dataset_fps[name],
                            "overlap": self._dataset_overlap.get(name)})
        meta["datasets"] = ds_meta
        fw_keys = []
        for j, (key, words) in enumerate(self._filter_words.items()):
            fw_keys.append(list(key))            # [fp, num_blocks, seed]
            flat[f"fw/{j}"] = words
        meta["filter_cache"] = fw_keys           # in LRU order
        meta["sigma"] = {q: {str(k): float(v) for k, v in t.items()}
                         for q, t in self.sigma.table.items()}
        q_meta = []
        for j, req in enumerate(self.queue):
            m = self._req_meta(req)
            # handle requests (single- or multi-dataset) need no arrays: the
            # datasets themselves are in the snapshot and resolve by name
            if req.dataset is None and req.datasets is None:
                for i, r in enumerate(req.rels):
                    self._rel_arrays(flat, f"q/{j}/rels/{i}", r)
            if req._words is not None:           # pre-merged window words
                for i, w in enumerate(req._words):
                    flat[f"q/{j}/words/{i}"] = w
            q_meta.append(m)
        meta["queue"] = q_meta
        meta["diag"] = {f: getattr(self.diagnostics, f)
                        for f in self._DIAG_SCALARS}
        # span-id sequence: the successor adopting this snapshot must never
        # reuse this engine's span ids (Tracer.adopt max-merges)
        meta["telemetry"] = self.tracer.state()
        return flat, meta

    def restore_state(self, flat: dict, meta: dict) -> list[JoinRequest]:
        """Merge a snapshot into this engine; returns the re-queued requests.

        Merge semantics (not replace): restoring into a fresh engine is a
        plain restore, restoring into a live one ADOPTS the snapshot's
        tenants — the failover path, where a successor absorbs a dead
        replica's datasets, filter words, sigma entries (overwritten per
        query_id, continuing each sigma sequence exactly) and queued
        requests (appended in saved order, so same-``query_id`` FIFO — the
        only order sigma feedback observes — is preserved).  Served-but-
        undrained results are NOT part of a snapshot: their futures resolved
        at completion time, before any crash this snapshot survives."""
        for di, d in enumerate(meta.get("datasets", [])):
            rels = [self._rel_restore(flat, f"ds/{di}/{i}")
                    for i in range(d["n"])]
            self.datasets[d["name"]] = rels
            self._dataset_fps[d["name"]] = list(d["fps"])
            if d["overlap"] is not None:
                self._dataset_overlap[d["name"]] = d["overlap"]
        for j, key in enumerate(meta.get("filter_cache", [])):
            fp, num_blocks, seed = key
            self._filter_words[(fp, int(num_blocks), int(seed))] = \
                jnp.asarray(flat[f"fw/{j}"])
        while len(self._filter_words) > self.filter_cache_entries:
            self._filter_words.popitem(last=False)
        for q, t in meta.get("sigma", {}).items():
            self.sigma.table[q] = {int(k): float(v) for k, v in t.items()}
        restored = []
        for j, m in enumerate(meta.get("queue", [])):
            if m["dataset"] is None and not m.get("datasets"):
                rels = [self._rel_restore(flat, f"q/{j}/rels/{i}")
                        for i in range(m["n_rels"])]
            else:
                rels = None
            req = JoinRequest(
                rels=rels, dataset=m["dataset"], datasets=m.get("datasets"),
                budget=QueryBudget(*m["budget"]), agg=m["agg"],
                expr=m["expr"], query_id=m["query_id"], seed=m["seed"],
                fp_rate=m["fp_rate"], max_strata=m["max_strata"],
                b_max=m["b_max"], dedup=m["dedup"],
                use_kernels=m["use_kernels"], serve_mode=m["serve_mode"],
                filter_seed=m["filter_seed"], overlap_hint=m["overlap_hint"],
                stream=m["stream"], window_id=m["window_id"],
                plan=m.get("plan"), plan_node=m.get("plan_node"))
            if m["n_words"]:
                req._words = [jnp.asarray(flat[f"q/{j}/words/{i}"])
                              for i in range(m["n_words"])]
            self.submit(req)
            restored.append(req)
            if req.plan is not None:
                # regroup plan-node requests into a live handle so the
                # successor tracks (and completes) the adopted plan whole
                handle = self.plans.setdefault(req.plan,
                                               PlanHandle(req.plan))
                handle.requests[req.plan_node] = req
        for f, v in meta.get("diag", {}).items():
            if f == "max_batch":
                self.diagnostics.max_batch = max(self.diagnostics.max_batch,
                                                 v)
            else:
                setattr(self.diagnostics, f,
                        getattr(self.diagnostics, f) + v)
        tel = meta.get("telemetry")
        if tel and self.tracer is not NULL_TRACER:
            self.tracer.adopt(tel)
        return restored

    # -- execution paths ----------------------------------------------------

    def _kernel_gather(self, arrays) -> list:
        """Round-trip device arrays to the host for the kernel path (the
        Pallas kernels are single-device; a mesh server's rows/words are
        sharded or replicated across the mesh).  Metered: the batched
        kernel path must keep this at ZERO on meshless servers and mesh 1."""
        host = [np.asarray(jax.device_get(x)) for x in arrays]
        self.diagnostics.kernel_gather_bytes += float(
            sum(h.nbytes for h in host))
        return [jnp.asarray(h) for h in host]

    def _batch_inputs(self, cls: ShapeClass, batch: list[JoinRequest]):
        """Pad to the pow2 batch bucket; stack relations, words and seeds."""
        B = bucket_capacity(len(batch))
        reqs = batch + [batch[-1]] * (B - len(batch))  # pad slots (discarded)
        # kernel classes on a multi-device mesh serve on the default device:
        # sharded rows gather back to the host, once per DISTINCT array this
        # step (dataset-handle requests share Relation objects — B slots of
        # one dataset move its rows once, and kernel_gather_bytes counts
        # actual transfers), counted in kernel_gather_bytes
        gather = (cls.use_kernels and self.mesh is not None
                  and self.mesh_k > 1)
        memo: dict = {}

        def host(x):
            hit = memo.get(id(x))
            if hit is None:
                # the memo entry pins x so its id cannot be recycled mid-step
                hit = (x, self._kernel_gather([x])[0])
                memo[id(x)] = hit
            return hit[1]

        def rels_of(r):
            if not gather:
                return r.rels
            return [Relation(*(host(x) for x in rel)) for rel in r.rels]
        rels_b = [Relation(jnp.stack([rels_of(r)[s].keys for r in reqs]),
                           jnp.stack([rels_of(r)[s].values for r in reqs]),
                           jnp.stack([rels_of(r)[s].valid for r in reqs]))
                  for s in range(cls.n_inputs)]
        seeds = jnp.asarray([r.seed for r in reqs], jnp.uint32)
        fseeds = jnp.asarray([r.seed if r.filter_seed is None
                              else r.filter_seed for r in reqs], jnp.uint32)
        num_blocks = bloom.num_blocks_for(max(cls.caps), cls.fp_rate)
        # words are fetched per REAL request only (pad slots replay the last
        # request's words) so the build/reuse counters stay honest; a
        # streaming request carries its window's pre-merged words instead
        per_req = []
        for r in batch:
            if r._words is not None:
                assert len(r._words) == cls.n_inputs, r
                ws = list(r._words)
            else:
                fs = r.seed if r.filter_seed is None else r.filter_seed
                ws = [self._words_for(r.rels[s], r._fps[s], num_blocks, fs,
                                      use_kernels=cls.use_kernels)
                      for s in range(cls.n_inputs)]
            if gather:  # replicated mesh words -> default device, metered
                # per side, pre-stack: cached word arrays are shared across
                # slots of one dataset, so each moves at most once per step
                ws = [host(x) for x in ws]
            per_req.append(jnp.stack(ws))
        words_b = jnp.stack(per_req + [per_req[-1]] * (B - len(batch)))
        return B, rels_b, words_b, seeds, fseeds, num_blocks

    def _decide_b_rows(self, cls: ShapeClass, batch, B, population, skeys,
                       strata_slice, d_filter):
        """Host decisions: exact-affordable?  b_i from budget + sigma.

        The strata layout is whatever the prepare stage emitted — canonical
        [S] for exact-parity, concatenated per-device [k*S] for psum; both
        are complete disjoint covers of the strata, and every decision here
        is per-stratum, so the same code sizes both.
        """
        sampled_idx, b_rows = [], []
        zeros_b = jnp.zeros((population.shape[1],), jnp.float32)
        for i, req in enumerate(batch):
            budget, total_pop = req.budget, float(population[i].sum())
            exact_ok = budget.is_exact or (
                budget.latency_s is not None and self.cost_model is not None
                and float(self.cost_model.beta_compute) * total_pop
                + self.cost_model.epsilon + d_filter <= budget.latency_s
                and budget.error is None)
            if exact_ok:
                b_rows.append(zeros_b)
                continue
            sigma = None
            if budget.error is not None and self.sigma.has(req.query_id):
                sigma = self.sigma.lookup(req.query_id, skeys[i])
            b_rows.append(decide_sample_sizes(
                budget, strata_slice(i), self.cost_model, d_filter, sigma,
                budget.confidence))
            sampled_idx.append(i)
        exact_idx = [i for i in range(len(batch)) if i not in sampled_idx]
        b_rows += [zeros_b] * (B - len(batch))
        return sampled_idx, exact_idx, b_rows

    def _finish_batch(self, batch, *, strata_slice, live_counts, total_counts,
                      fbytes, d_filter, exact_idx, e_est, e_cnt,
                      value, err, cnt, dof, stats, skeys, dropped=None):
        """Per-query results + sigma feedback (shared by both backends)."""
        n = batch[0]._class.n_inputs
        for i, req in enumerate(batch):
            strata_i = strata_slice(i)
            live_i, tot_i = live_counts[i], total_counts[i]
            diag = dict(
                dist_dropped_tuples=0.0 if dropped is None
                else float(dropped[i]),
                total_counts=tot_i, live_counts=live_i,
                overlap_fraction=jnp.sum(live_i)
                / jnp.maximum(jnp.sum(tot_i), 1),
                filter_bytes=fbytes,
                shuffled_bytes_filtered=jnp.sum(live_i) * TUPLE_BYTES
                + filter_exchange_bytes(n, fbytes),
                shuffled_bytes_repartition=jnp.sum(tot_i) * TUPLE_BYTES,
                num_strata=strata_i.num_strata,
                strata_overflow=strata_i.overflow,
                total_population=jnp.sum(strata_i.population),
                d_filter_s=d_filter)
            if i in exact_idx:
                req.result = JoinResult(
                    e_est[i], jnp.zeros(()), e_cnt[i], jnp.zeros(()),
                    JoinDiagnostics(sample_draws=jnp.zeros(()), sampled=False,
                                    **diag),
                    strata=strata_i)
                self.diagnostics.exact_queries += 1
                continue
            stats_i = jax.tree_util.tree_map(lambda x: x[i], stats)
            req.result = JoinResult(
                value[i], err[i], cnt[i], dof[i],
                JoinDiagnostics(sample_draws=jnp.sum(stats_i.n_sampled),
                                sampled=True, **diag),
                stats=stats_i, strata=strata_i)
            sig = np.asarray(jax.device_get(measured_sigma(stats_i)))
            ok = np.asarray(jax.device_get(
                stats_i.valid & (stats_i.n_sampled > 1)))
            self.sigma.update(req.query_id, skeys[i], sig, ok)
            self.diagnostics.sampled_queries += 1

    def _stage_builders(self, cls: ShapeClass, num_blocks: int):
        """Per-backend stage builders + dispatch-argument adapters.

        The single-device, kernel and mesh paths share every other line of
        the step (warmup, timing, host decisions, result assembly); only the
        compiled stage programs and two extra sample/exact arguments differ.
        """
        if cls.use_kernels:
            from repro.kernels import ops as kops
            interp = kops.use_interpret()
            # the fused Pallas sampler is two-way/non-dedup (the paper's hot
            # case); other kernel classes keep the kernel-backed prepare and
            # fall back to the vmapped jnp sampler — exactly approx_join's
            # own use_kernels composition, so bit-parity holds either way
            if cls.n_inputs == 2 and not cls.dedup:
                sample = partial(_make_sample_kernels, cls.b_max, cls.agg,
                                 cls.confidence, cls.expr, interp)
            else:
                sample = partial(_make_sample, cls.b_max, cls.agg, cls.dedup,
                                 cls.confidence, cls.expr)
            return dict(
                prepare=partial(_make_prepare_kernels, cls.max_strata,
                                interp),
                sample=sample,
                exact=partial(_make_exact, cls.agg, cls.expr),
                sample_args=lambda prep, b, s: (prep.sorted_rels, prep.strata,
                                                b, s),
                exact_args=lambda prep: (prep.sorted_rels, prep.strata))
        if self.mesh is None:
            return dict(
                prepare=partial(_make_prepare, cls.max_strata),
                sample=partial(_make_sample, cls.b_max, cls.agg, cls.dedup,
                               cls.confidence, cls.expr),
                exact=partial(_make_exact, cls.agg, cls.expr),
                sample_args=lambda prep, b, s: (prep.sorted_rels, prep.strata,
                                                b, s),
                exact_args=lambda prep: (prep.sorted_rels, prep.strata))
        cap = cls.bucket_cap or max(cls.caps) // self.mesh_k
        if cls.serve_mode == "psum":
            return dict(
                prepare=partial(make_serve_prepare, self.mesh,
                                self.join_axes, n_rels=cls.n_inputs,
                                num_blocks=num_blocks,
                                max_strata=cls.max_strata, bucket_cap=cap,
                                merge="psum"),
                sample=partial(make_serve_sample_psum, self.mesh,
                               self.join_axes, n_rels=cls.n_inputs,
                               b_max=cls.b_max, agg=cls.agg, dedup=cls.dedup,
                               confidence=cls.confidence, expr=cls.expr),
                exact=partial(make_serve_exact_psum, self.mesh,
                              self.join_axes, n_rels=cls.n_inputs,
                              agg=cls.agg, expr=cls.expr),
                sample_args=lambda prep, b, s: (prep.sorted_rels,
                                                prep.local_strata, b, s),
                exact_args=lambda prep: (prep.sorted_rels,
                                         prep.local_strata))
        return dict(
            prepare=partial(make_serve_prepare, self.mesh, self.join_axes,
                            n_rels=cls.n_inputs, num_blocks=num_blocks,
                            max_strata=cls.max_strata, bucket_cap=cap),
            sample=partial(make_serve_sample, self.mesh, self.join_axes,
                           n_rels=cls.n_inputs, b_max=cls.b_max, agg=cls.agg,
                           dedup=cls.dedup, confidence=cls.confidence,
                           expr=cls.expr),
            exact=partial(make_serve_exact, self.mesh, self.join_axes,
                          n_rels=cls.n_inputs, agg=cls.agg, expr=cls.expr),
            sample_args=lambda prep, b, s: (prep.sorted_rels,
                                            prep.local_strata,
                                            prep.strata.keys,
                                            prep.strata.valid, b, s),
            exact_args=lambda prep: (prep.sorted_rels, prep.local_strata,
                                     prep.strata))

    def _wire_bytes_model(self, cls: ShapeClass) -> float:
        """Static per-device collective bytes for ONE query through the mesh
        pipeline (buffers, not live tuples — what a static-shape dataflow
        puts on the wire; the serve-time restatement of Eq. 24)."""
        k = self.mesh_k
        if k <= 1:
            return 0.0
        cap = cls.bucket_cap or max(cls.caps) // k
        n = cls.n_inputs
        a2a = n * (k - 1) * cap * TUPLE_BYTES     # key shuffle send buffers
        if cls.serve_mode == "psum":
            merge = len(SumParts._fields) * 4 * (k - 1)
        else:
            # gather merge: all_gathers of [S] slot arrays — strata keys +
            # per-side counts (prepare), 7 stat fields (sample), per-side
            # sums (exact)
            merge = ((1 + n) + 7 + n) * cls.max_strata * 4 * (k - 1)
        return float(a2a + merge)

    def _run_batch(self, cls: ShapeClass, batch: list[JoinRequest]) -> None:
        """One engine step — single fused dispatch per stage; with a mesh,
        each dispatch spans all devices through the shard_map pipeline."""
        B, rels_b, words_b, seeds, fseeds, num_blocks = \
            self._batch_inputs(cls, batch)
        builders = self._stage_builders(cls, num_blocks)
        # stage-timing scratch for the tracer ({} only while tracing, so the
        # untraced path keeps its exact laziness — no extra blocking)
        stages = {} if self.tracer.enabled else None

        prepare, fresh = self._executable("prepare", cls, B,
                                          builders["prepare"])
        if fresh:
            # warm the executable off the clock: d_filter feeds the latency
            # cost function (§3.2), which models repeated query execution —
            # charging one-off trace+compile seconds would zero out every
            # latency budget on the first batch of a shape class.
            tc = time.perf_counter()
            jax.block_until_ready(
                prepare(rels_b, words_b, fseeds).strata.counts)
            if stages is not None:
                stages["compile"] = (tc, time.perf_counter() - tc,
                                     {"stage": "prepare"})
        t0 = time.perf_counter()
        prep = prepare(rels_b, words_b, fseeds)
        jax.block_until_ready(prep.strata.counts)
        d_filter = time.perf_counter() - t0
        self.diagnostics.filter_s += d_filter
        if stages is not None:
            stages["prepare"] = (t0, d_filter, {})

        population = np.asarray(jax.device_get(prep.population))
        skeys = np.asarray(jax.device_get(prep.strata.keys))

        def slice_i(i):
            return jax.tree_util.tree_map(lambda x: x[i], prep.strata)

        sampled_idx, exact_idx, b_rows = self._decide_b_rows(
            cls, batch, B, population, skeys, slice_i, d_filter)

        # -- fused device dispatches (per stage, whole batch) ---------------
        value = err = cnt = dof = stats = e_est = e_cnt = None
        if sampled_idx:
            sample, _ = self._executable("sample", cls, B,
                                         builders["sample"])
            ts = time.perf_counter()
            value, err, cnt, dof, stats = sample(*builders["sample_args"](
                prep, jnp.stack(b_rows), seeds + jnp.uint32(1)))
            if stages is not None:
                jax.block_until_ready(value)
                stages["sample"] = (ts, time.perf_counter() - ts,
                                    {"queries": len(sampled_idx)})
        if exact_idx:
            exact, _ = self._executable("exact", cls, B, builders["exact"])
            ts = time.perf_counter()
            e_est, e_cnt = exact(*builders["exact_args"](prep))
            if stages is not None:
                jax.block_until_ready(e_est)
                stages["exact"] = (ts, time.perf_counter() - ts,
                                   {"queries": len(exact_idx)})

        # kernel classes run the single-device pipeline even on a mesh
        # server (plain PrepareOut: no shuffle buckets, nothing dropped)
        meshless = self.mesh is None or cls.use_kernels
        if cls.use_kernels:
            self.diagnostics.kernel_queries += len(batch)
        dropped = None if meshless else np.asarray(
            jax.device_get(prep.bucket_overflow), np.float64)
        self._finish_batch(
            batch, strata_slice=slice_i, live_counts=prep.live_counts,
            total_counts=prep.total_counts,
            fbytes=num_blocks * bloom.WORDS_PER_BLOCK * 4, d_filter=d_filter,
            exact_idx=exact_idx, e_est=e_est, e_cnt=e_cnt, value=value,
            err=err, cnt=cnt, dof=dof, stats=stats, skeys=skeys,
            dropped=dropped)

        fbytes = num_blocks * bloom.WORDS_PER_BLOCK * 4
        self.diagnostics.filter_exchange_bytes_model += \
            len(batch) * float(filter_exchange_bytes(cls.n_inputs, fbytes))
        if not meshless:
            # measured per-device shuffle volume (the paper's data-movement
            # reduction, observable from the server); pad slots excluded
            n_real = len(batch)
            self.diagnostics.dist_shuffled_tuple_bytes += float(
                np.asarray(jax.device_get(
                    prep.shuffled_tuple_bytes))[:n_real].sum())
            self.diagnostics.per_device_shuffled_bytes += np.asarray(
                jax.device_get(prep.device_shuffled_bytes))[:n_real].sum(
                    axis=0)
            # capacity-plan feedback: rows dropped beyond the bucket plan
            # (always 0 under the lossless exact-parity default)
            self.diagnostics.dist_dropped_tuples += float(
                dropped[:n_real].sum())
            self.diagnostics.per_device_dropped_tuples += np.asarray(
                jax.device_get(prep.device_dropped),
                np.float64)[:n_real].sum(axis=0)
            self.diagnostics.dist_wire_bytes_model += \
                n_real * self._wire_bytes_model(cls)
        if stages is not None:
            self._stage_trace = stages
            self._recon_batch = self._recon_records(cls, batch, prep,
                                                    fbytes, meshless)

    def _recon_records(self, cls: ShapeClass, batch: list[JoinRequest],
                       prep, fbytes: int, meshless: bool) -> dict:
        """Per-query byte-reconciliation records (traced steps only): each
        modeled cost paired with its metered counterpart, keyed by request
        identity for ``_trace_step``.  The extra device_gets here run only
        under tracing — the untraced hot path is unchanged."""
        n_real, n, k = len(batch), cls.n_inputs, self.mesh_k
        live = np.asarray(jax.device_get(prep.live_counts))[:n_real]
        tup = dev = None
        if not meshless:
            tup = np.asarray(jax.device_get(
                prep.shuffled_tuple_bytes))[:n_real]
            dev = np.asarray(jax.device_get(
                prep.device_shuffled_bytes))[:n_real]
        path, wire = self._path_of(cls), self._wire_bytes_model(cls)
        fe_model = float(filter_exchange_bytes(n, fbytes))
        out = {}
        for i, req in enumerate(batch):
            live_model = float(live[i].sum()) * TUPLE_BYTES
            # live-tuple bytes: §3.1's filtered-shuffle volume vs the
            # metered per-query tuple bytes actually moved (mesh only —
            # single-device and kernel queries move no wire tuples)
            pairs = [recon_pair("live_tuple_bytes", live_model,
                                None if tup is None else float(tup[i]))]
            # per-query filter exchange is modeled-only here: the measured
            # counterpart is cumulative and amortized across the word cache
            # (see the server-level pair in reconciliation_report)
            pairs.append(recon_pair("filter_exchange_bytes", fe_model, None))
            if not meshless:
                # static collective-buffer model vs live tuple bytes: the
                # gap is the dense dataflow's buffer slack
                pairs.append(recon_pair("dist_wire_bytes_model", wire,
                                        float(tup[i])))
            if req._bytes_model is not None:
                # compile-time plan-node model vs this execution's serve-
                # time restatement of the same §3.1 cost
                pairs.append(recon_pair(
                    "node_bytes_model",
                    float(req._bytes_model["bytes_pushdown"]),
                    live_model + fe_model))
            rec = {"query_id": req.query_id, "path": path,
                   "stream": req.stream, "window_id": req.window_id,
                   "plan": req.plan, "plan_node": req.plan_node,
                   "pairs": pairs}
            if dev is not None:
                rec["per_device"] = {"modeled": [wire / k] * k,
                                     "measured": [float(x) for x in dev[i]]}
            out[id(req)] = rec
        return out

    def reconciliation_report(self) -> dict:
        """Modeled-vs-metered byte report: per-query records (traced
        queries), per-path aggregates, and the cumulative server-level
        pairs that exist with tracing off too."""
        d = self.diagnostics
        server_pairs = [
            recon_pair("filter_exchange_bytes", d.filter_exchange_bytes_model,
                       d.filter_exchange_bytes_measured
                       if self.mesh is not None else None),
            recon_pair("dist_wire_bytes_model", d.dist_wire_bytes_model,
                       d.dist_shuffled_tuple_bytes
                       if self.mesh is not None else None),
            # host gathers of the kernel-on-mesh route are unmodeled cost:
            # modeled 0, so any metered bytes surface as pure model error
            recon_pair("kernel_gather_bytes", 0.0,
                       d.kernel_gather_bytes or None),
        ]
        return _recon_report(self.tracer.recon, server_pairs)

    def query_trace(self, query_id: str) -> list:
        """Span forest of every traced execution of ``query_id`` (each
        request instance roots its own ``query`` span)."""
        return span_tree(e for e in self.tracer.events
                         if e["args"].get("query_id") == query_id)
