"""Batched multi-tenant ApproxJoin serving engine.

The LM ``Server`` (runtime/serve.py) batches token decodes across slots; the
``JoinServer`` does the same for ApproxJoin queries.  A :class:`JoinRequest`
carries relations (or a named dataset handle), a :class:`QueryBudget`, the
aggregate/expression, and a tenant ``query_id``.  The engine:

* **buckets** every relation to a power-of-two capacity
  (:func:`repro.core.relation.bucket_to_pow2`) so queries fall into a small
  number of *shape classes*;
* keeps a **compiled-executable cache** keyed by
  ``(stage, shape_class, batch)`` — repeat tenants never recompile;
* **batches same-shape-class queries with vmap** across the
  filter-build/probe/sort/strata and sample/estimate stages, so one engine
  step is one fused device dispatch per stage regardless of how many tenants
  share it;
* shares one :class:`SigmaRegistry` and :class:`CostModel` across tenants, so
  a repeated ``query_id`` gets the paper's §3.2-II adaptive sample sizing for
  free — and tenants never see each other's sigmas (the registry is keyed by
  ``query_id``).

Results are bit-identical to a direct :func:`repro.core.join.approx_join`
call on the same (bucketed) relations with the same seed: both paths compose
the same stage functions from ``core/join.py``, and ``jit(vmap(stage))`` on
this backend reproduces the eager per-example arithmetic exactly (asserted in
``tests/test_join_serve.py``).

Per-query dynamic decisions (exact-affordable?  per-stratum ``b_i`` from the
budget + sigma feedback) stay on the host, exactly as in ``approx_join`` —
the driver role.  Sigma feedback lands *between engine steps*: requests with
the same ``query_id`` co-batched into one step all see the registry state at
dispatch time, where a sequential driver would thread each execution's
feedback into the next.  ``use_kernels`` queries are served through the Pallas path
per-query (Pallas calls are not batched under vmap here); they still share
the sigma registry and are tracked in the executable cache.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom
from repro.core.budget import QueryBudget
from repro.core.cost import CostModel, SigmaRegistry
from repro.core.join import (EXPRS, TUPLE_BYTES, JoinDiagnostics, JoinResult,
                             approx_join, decide_sample_sizes, exact_stage,
                             measured_sigma, prepare_stage, sample_stage)
from repro.core.relation import Relation, bucket_capacity, bucket_to_pow2

DEFAULT_B_MAX = 2048
AGGS = ("sum", "count", "avg", "stdev")


class ShapeClass(NamedTuple):
    """Static compilation signature of a query (the executable-cache key)."""

    caps: tuple[int, ...]    # per-side bucketed capacities
    n_inputs: int
    max_strata: int
    b_max: int
    expr: str
    agg: str
    dedup: bool
    use_kernels: bool
    fp_rate: float
    confidence: float


@dataclass
class JoinRequest:
    """One tenant query: relations (or dataset handle) + budget + query id."""

    rels: Optional[Sequence[Relation]] = None
    dataset: Optional[str] = None
    budget: QueryBudget = QueryBudget()
    agg: str = "sum"
    expr: str = "sum"
    query_id: str = "q0"
    seed: int = 0
    fp_rate: float = 0.01
    max_strata: Optional[int] = None
    b_max: Optional[int] = DEFAULT_B_MAX
    dedup: bool = False
    use_kernels: bool = False
    # filled by the server
    result: Optional[JoinResult] = None
    done: bool = False
    queue_latency_s: float = 0.0
    _class: Optional[ShapeClass] = field(default=None, repr=False)
    _submit_t: float = field(default=0.0, repr=False)


@dataclass
class ServerDiagnostics:
    """Server-level counters (cumulative since construction)."""

    queries: int = 0
    steps: int = 0
    cache_hits: int = 0
    compiles: int = 0               # executable-cache misses
    exact_queries: int = 0
    sampled_queries: int = 0
    kernel_queries: int = 0
    queue_latency_s: float = 0.0    # summed over finished queries
    filter_s: float = 0.0           # summed batch filter-stage wall time
    shuffled_bytes_saved: float = 0.0
    max_batch: int = 0

    def snapshot(self) -> dict:
        return dict(vars(self))


def shape_class_of(req: JoinRequest) -> ShapeClass:
    caps = tuple(bucket_capacity(r.capacity) for r in req.rels)
    return ShapeClass(caps, len(caps), req.max_strata, req.b_max,
                      req.expr, req.agg, req.dedup, req.use_kernels,
                      req.fp_rate, req.budget.confidence)


def _make_prepare(num_blocks: int, max_strata: int):
    def fn(rels, seed):
        return prepare_stage(rels, num_blocks, max_strata, seed)
    return jax.jit(jax.vmap(fn))


def _make_sample(b_max: int, agg: str, dedup: bool, confidence: float,
                 expr: str):
    f_fn = EXPRS[expr][0]
    def fn(sorted_rels, strata, b_i, seed):
        return sample_stage(sorted_rels, strata, b_i, b_max, seed,
                            agg=agg, dedup=dedup, confidence=confidence,
                            f_fn=f_fn)
    return jax.jit(jax.vmap(fn))


def _make_exact(agg: str, expr: str):
    def fn(sorted_rels, strata):
        return exact_stage(sorted_rels, strata, agg=agg, expr=expr)
    return jax.jit(jax.vmap(fn))


class JoinServer:
    """Slot-based batched ApproxJoin engine (the LM ``Server``, for joins)."""

    def __init__(self, *, batch_slots: int = 4,
                 cost_model: Optional[CostModel] = None,
                 sigma_registry: Optional[SigmaRegistry] = None):
        self.batch_slots = batch_slots
        self.cost_model = cost_model
        self.sigma = SigmaRegistry() if sigma_registry is None \
            else sigma_registry
        self.queue: list[JoinRequest] = []
        self.datasets: dict[str, list[Relation]] = {}
        self._exec_cache: dict = {}
        self.diagnostics = ServerDiagnostics()

    # -- admission ----------------------------------------------------------

    def register_dataset(self, name: str, rels: Sequence[Relation]) -> None:
        """Store a named (bucketed) dataset tenants can join by handle."""
        self.datasets[name] = [bucket_to_pow2(r) for r in rels]

    def submit(self, req: JoinRequest) -> JoinRequest:
        if req.rels is None:
            if req.dataset is None:
                raise ValueError("JoinRequest needs rels or a dataset handle")
            req.rels = self.datasets[req.dataset]
        else:
            req.rels = [bucket_to_pow2(r) for r in req.rels]
        if len(req.rels) < 2:
            raise ValueError("join needs at least two relations")
        if req.expr not in EXPRS:
            raise ValueError(f"unknown expr {req.expr!r}")
        if req.agg not in AGGS:
            raise ValueError(f"unknown agg {req.agg!r}")
        if req.max_strata is None:
            req.max_strata = req.rels[0].capacity
        if req.b_max is None:
            # approx_join's b_max=None adaptive grid sizes the draw capacity
            # from data-dependent peak b_i — incompatible with a pre-keyed
            # executable cache, so refuse rather than silently diverge.
            raise ValueError("JoinServer needs a concrete b_max "
                             f"(e.g. the default {DEFAULT_B_MAX}); the "
                             "adaptive b_max=None grid is driver-side only")
        req._class = shape_class_of(req)
        req._submit_t = time.perf_counter()
        self.queue.append(req)
        return req

    # -- executable cache ---------------------------------------------------

    def _executable(self, stage: str, cls: ShapeClass, variant, builder):
        """Fetch-or-build a compiled executable; ``variant`` is the rest of
        the cache key (batch bucket for vmapped stages, seed for the
        static-seed kernel route).  Returns (fn, freshly_built)."""
        key = (stage, cls, variant)
        fn = self._exec_cache.get(key)
        fresh = fn is None
        if fresh:
            fn = builder()
            self._exec_cache[key] = fn
            self.diagnostics.compiles += 1
        else:
            self.diagnostics.cache_hits += 1
        return fn, fresh

    # -- engine -------------------------------------------------------------

    def step(self) -> int:
        """Serve one batch of same-shape-class queries; returns batch size."""
        if not self.queue:
            return 0
        cls = self.queue[0]._class
        batch = [r for r in self.queue if r._class == cls][:self.batch_slots]
        taken = set(map(id, batch))
        self.queue = [r for r in self.queue if id(r) not in taken]
        self.diagnostics.steps += 1
        self.diagnostics.max_batch = max(self.diagnostics.max_batch,
                                         len(batch))
        if cls.use_kernels:
            for req in batch:
                self._run_kernel(cls, req)
        else:
            self._run_batch(cls, batch)
        for req in batch:
            req.done = True
            req.queue_latency_s = time.perf_counter() - req._submit_t
            self.diagnostics.queue_latency_s += req.queue_latency_s
            self.diagnostics.queries += 1
            d = req.result.diagnostics
            self.diagnostics.shuffled_bytes_saved += float(
                d.shuffled_bytes_repartition - d.shuffled_bytes_filtered)
        return len(batch)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0:
                break

    # -- execution paths ----------------------------------------------------

    def _run_kernel(self, cls: ShapeClass, req: JoinRequest) -> None:
        # Pallas route: per-query execution through approx_join.  The kernel
        # wrappers are jitted with STATIC seeds, so XLA compiles per distinct
        # seed — keying the cache entry on the seed keeps the compile/hit
        # counters honest about that.
        self._executable("kernel", cls, req.seed, lambda: approx_join)
        req.result = approx_join(
            req.rels, req.budget, agg=req.agg, expr=req.expr, seed=req.seed,
            fp_rate=req.fp_rate, max_strata=cls.max_strata, b_max=cls.b_max,
            cost_model=self.cost_model, sigma_registry=self.sigma,
            query_id=req.query_id, dedup=req.dedup, use_kernels=True)
        self.diagnostics.kernel_queries += 1
        if req.result.diagnostics.sampled:
            self.diagnostics.sampled_queries += 1
        else:
            self.diagnostics.exact_queries += 1

    def _run_batch(self, cls: ShapeClass, batch: list[JoinRequest]) -> None:
        B = bucket_capacity(len(batch))                # pow2 batch bucket
        reqs = batch + [batch[-1]] * (B - len(batch))  # pad slots (discarded)
        rels_b = [Relation(jnp.stack([r.rels[s].keys for r in reqs]),
                           jnp.stack([r.rels[s].values for r in reqs]),
                           jnp.stack([r.rels[s].valid for r in reqs]))
                  for s in range(cls.n_inputs)]
        seeds = jnp.asarray([r.seed for r in reqs], jnp.uint32)
        num_blocks = bloom.num_blocks_for(max(cls.caps), cls.fp_rate)

        prepare, fresh = self._executable(
            "prepare", cls, B, partial(_make_prepare, num_blocks,
                                       cls.max_strata))
        if fresh:
            # warm the executable off the clock: d_filter feeds the latency
            # cost function (§3.2), which models repeated query execution —
            # charging one-off trace+compile seconds would zero out every
            # latency budget on the first batch of a shape class.
            jax.block_until_ready(prepare(rels_b, seeds).strata.counts)
        t0 = time.perf_counter()
        prep = prepare(rels_b, seeds)
        jax.block_until_ready(prep.strata.counts)
        d_filter = time.perf_counter() - t0
        self.diagnostics.filter_s += d_filter

        population = np.asarray(jax.device_get(prep.population))
        skeys = np.asarray(jax.device_get(prep.strata.keys))

        def slice_i(i):
            return jax.tree_util.tree_map(lambda x: x[i], prep.strata)

        # -- host decisions: exact-affordable? b_i from budget + sigma ------
        sampled_idx, b_rows = [], []
        zeros_b = jnp.zeros((cls.max_strata,), jnp.float32)
        for i, req in enumerate(batch):
            budget, total_pop = req.budget, float(population[i].sum())
            exact_ok = budget.is_exact or (
                budget.latency_s is not None and self.cost_model is not None
                and float(self.cost_model.beta_compute) * total_pop
                + self.cost_model.epsilon + d_filter <= budget.latency_s
                and budget.error is None)
            if exact_ok:
                b_rows.append(zeros_b)
                continue
            sigma = None
            if budget.error is not None and self.sigma.has(req.query_id):
                sigma = self.sigma.lookup(req.query_id, skeys[i])
            b_rows.append(decide_sample_sizes(
                budget, slice_i(i), self.cost_model, d_filter, sigma,
                budget.confidence))
            sampled_idx.append(i)
        exact_idx = [i for i in range(len(batch)) if i not in sampled_idx]
        b_rows += [zeros_b] * (B - len(batch))

        # -- fused device dispatches (per stage, whole batch) ---------------
        value = err = cnt = dof = stats = None
        if sampled_idx:
            sample, _ = self._executable(
                "sample", cls, B, partial(_make_sample, cls.b_max, cls.agg,
                                          cls.dedup, cls.confidence, cls.expr))
            value, err, cnt, dof, stats = sample(
                prep.sorted_rels, prep.strata, jnp.stack(b_rows),
                seeds + jnp.uint32(1))
        if exact_idx:
            exact, _ = self._executable(
                "exact", cls, B, partial(_make_exact, cls.agg, cls.expr))
            e_est, e_cnt = exact(prep.sorted_rels, prep.strata)

        # -- per-query results + sigma feedback -----------------------------
        fbytes = num_blocks * bloom.WORDS_PER_BLOCK * 4
        n = cls.n_inputs
        for i, req in enumerate(batch):
            strata_i = slice_i(i)
            live_i, tot_i = prep.live_counts[i], prep.total_counts[i]
            diag = dict(
                total_counts=tot_i, live_counts=live_i,
                overlap_fraction=jnp.sum(live_i)
                / jnp.maximum(jnp.sum(tot_i), 1),
                filter_bytes=fbytes,
                shuffled_bytes_filtered=jnp.sum(live_i) * TUPLE_BYTES
                + fbytes * (n + 1),
                shuffled_bytes_repartition=jnp.sum(tot_i) * TUPLE_BYTES,
                num_strata=strata_i.num_strata,
                strata_overflow=strata_i.overflow,
                total_population=jnp.sum(strata_i.population),
                d_filter_s=d_filter)
            if i in exact_idx:
                req.result = JoinResult(
                    e_est[i], jnp.zeros(()), e_cnt[i], jnp.zeros(()),
                    JoinDiagnostics(sample_draws=jnp.zeros(()), sampled=False,
                                    **diag),
                    strata=strata_i)
                self.diagnostics.exact_queries += 1
                continue
            stats_i = jax.tree_util.tree_map(lambda x: x[i], stats)
            req.result = JoinResult(
                value[i], err[i], cnt[i], dof[i],
                JoinDiagnostics(sample_draws=jnp.sum(stats_i.n_sampled),
                                sampled=True, **diag),
                stats=stats_i, strata=strata_i)
            sig = np.asarray(jax.device_get(measured_sigma(stats_i)))
            ok = np.asarray(jax.device_get(
                stats_i.valid & (stats_i.n_sampled > 1)))
            self.sigma.update(req.query_id, skeys[i], sig, ok)
            self.diagnostics.sampled_queries += 1
