"""Fault tolerance: step retries, straggler detection, elastic re-mesh.

What a 1000+-node deployment needs and how this maps here (CPU container =
single process, so failures are *injected* — the tests drive these paths):

* **step retry** — ``guarded_step`` retries a failed step call; data is
  regenerated deterministically from (step, shard) (data/pipeline.py), so a
  retry is bit-identical.  Real XLA device errors surface as exceptions at
  block_until_ready — exactly what we catch.
* **straggler mitigation** — ``StragglerMonitor`` tracks per-host step wall
  times (EWMA); hosts slower than ``threshold x`` the fleet median are
  flagged for eviction.  In a real deployment the flag feeds the re-mesh.
* **elastic re-mesh** — ``elastic_restore``: after membership change, build
  the new mesh, recompute shardings for the SAME logical rules, and restore
  the latest checkpoint onto it (checkpoints are mesh-agnostic).  Training
  resumes at the checkpointed step; the data pipeline needs nothing (stateless).
* **heartbeats** — ``Heartbeat`` timestamps; ``dead_hosts`` after a timeout.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.runtime.checkpoint import latest_step, restore_checkpoint


def guarded_step(step_fn: Callable, state, batch, *, retries: int = 2,
                 on_failure: Optional[Callable] = None):
    """Run a step; on exception, rebuild inputs and retry (bounded)."""
    last = None
    for attempt in range(retries + 1):
        try:
            return step_fn(state, batch)
        except Exception as e:  # noqa: BLE001 — device loss shows up this way
            last = e
            if on_failure is not None:
                on_failure(attempt, e)
    raise RuntimeError(f"step failed after {retries + 1} attempts") from last


@dataclass
class Heartbeat:
    timeout_s: float = 30.0
    last_seen: dict = field(default_factory=dict)

    def beat(self, host: str, now: Optional[float] = None) -> None:
        self.last_seen[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]


@dataclass
class StragglerMonitor:
    threshold: float = 2.0      # x median
    alpha: float = 0.3          # EWMA
    ewma: dict = field(default_factory=dict)

    def record(self, host: str, step_time_s: float) -> None:
        prev = self.ewma.get(host, step_time_s)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time_s

    def stragglers(self) -> list[str]:
        if len(self.ewma) < 2:
            return []
        times = sorted(self.ewma.values())
        median = times[len(times) // 2]
        return [h for h, t in self.ewma.items()
                if t > self.threshold * median]


def elastic_restore(ckpt_dir: str, like_state, *, shardings=None):
    """Resume from the newest checkpoint onto the CURRENT mesh/shardings.

    Returns (state, step, extra) or (like_state, 0, {}) when no checkpoint
    exists (cold start)."""
    step = latest_step(ckpt_dir)
    if step is None:
        return like_state, 0, {}
    state, extra = restore_checkpoint(ckpt_dir, step, like_state,
                                      shardings=shardings)
    return state, step, extra
