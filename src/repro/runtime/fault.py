"""Fault tolerance: step retries, straggler detection, elastic re-mesh.

What a 1000+-node deployment needs and how this maps here (CPU container =
single process, so failures are *injected* — the tests drive these paths):

* **step retry** — ``guarded_step`` retries a failed step call; data is
  regenerated deterministically from (step, shard) (data/pipeline.py), so a
  retry is bit-identical.  Real XLA device errors surface as exceptions at
  block_until_ready — exactly what we catch.
* **straggler mitigation** — ``StragglerMonitor`` tracks per-host step wall
  times (EWMA); hosts slower than ``threshold x`` the fleet median are
  flagged for eviction.  In a real deployment the flag feeds the re-mesh.
* **elastic re-mesh** — ``elastic_restore``: after membership change, build
  the new mesh, recompute shardings for the SAME logical rules, and restore
  the latest checkpoint onto it (checkpoints are mesh-agnostic).  Training
  resumes at the checkpointed step; the data pipeline needs nothing (stateless).
* **heartbeats** — ``Heartbeat`` timestamps; ``dead_hosts`` after a timeout.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.runtime.checkpoint import (latest_step, load_checkpoint,
                                      restore_checkpoint)


class InjectedFault(BaseException):
    """A deliberately injected replica death (``--kill-after`` fault drills).

    Subclasses ``BaseException`` so it sails past ``guarded_step``'s retry
    loop and the engine's own ``except Exception`` guards — an injected kill
    must take the replica down the same way a real process death would, not
    be absorbed by a retry."""


def guarded_step(step_fn: Callable, state, batch, *, retries: int = 2,
                 backoff_s: float = 0.0, on_failure: Optional[Callable] = None):
    """Run a step; on exception, rebuild inputs and retry (bounded).

    ``backoff_s`` > 0 sleeps ``backoff_s * 2**attempt`` between retries
    (exponential), giving a flaky device/filesystem time to recover instead
    of burning all retries in microseconds.  ``on_failure`` is shielded: an
    exception inside the callback is swallowed so it can never mask the real
    step error."""
    last = None
    for attempt in range(retries + 1):
        try:
            return step_fn(state, batch)
        except Exception as e:  # noqa: BLE001 — device loss shows up this way
            last = e
            if on_failure is not None:
                try:
                    on_failure(attempt, e)
                except Exception:  # noqa: BLE001 — never mask the step error
                    pass
            if backoff_s > 0.0 and attempt < retries:
                time.sleep(backoff_s * (2.0 ** attempt))
    raise RuntimeError(f"step failed after {retries + 1} attempts") from last


@dataclass
class Heartbeat:
    timeout_s: float = 30.0
    last_seen: dict = field(default_factory=dict)

    def beat(self, host: str, now: Optional[float] = None) -> None:
        self.last_seen[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]


@dataclass
class StragglerMonitor:
    threshold: float = 2.0      # x median
    alpha: float = 0.3          # EWMA
    ewma: dict = field(default_factory=dict)

    def record(self, host: str, step_time_s: float) -> None:
        prev = self.ewma.get(host, step_time_s)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time_s

    def stragglers(self) -> list[str]:
        if len(self.ewma) < 2:
            return []
        times = sorted(self.ewma.values())
        mid = len(times) // 2
        # true median: average the two middle elements for even-length
        # fleets (times[mid] alone is the upper-middle and over-reports,
        # hiding real stragglers behind an inflated baseline)
        median = times[mid] if len(times) % 2 else \
            0.5 * (times[mid - 1] + times[mid])
        return [h for h, t in self.ewma.items()
                if t > self.threshold * median]


def elastic_restore(ckpt_dir: str, like_state, *, shardings=None):
    """Resume from the newest checkpoint onto the CURRENT mesh/shardings.

    Returns (state, step, extra) or (like_state, 0, {}) when no checkpoint
    exists (cold start)."""
    step = latest_step(ckpt_dir)
    if step is None:
        return like_state, 0, {}
    state, extra = restore_checkpoint(ckpt_dir, step, like_state,
                                      shardings=shardings)
    return state, step, extra


def elastic_restore_engine(ckpt_dir: str, engine) -> Optional[int]:
    """Adopt a replica's newest engine checkpoint into ``engine``.

    The serving analogue of :func:`elastic_restore`: engine snapshots are
    structure-free (queue depth, dataset sizes and session buffers are
    whatever they were at capture), so the restore goes through
    ``load_checkpoint`` + ``engine.restore_state`` — merge semantics, the
    failover successor path.  Returns the restored step, or None when the
    directory holds no complete checkpoint (nothing to adopt)."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    flat, extra = load_checkpoint(ckpt_dir, step)
    engine.restore_state(flat, extra)
    return step
