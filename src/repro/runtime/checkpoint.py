"""Sharded, atomic, mesh-agnostic checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json      {path: {shape, dtype, sha256}, step, ...}
            <leaf-path>.npy    one file per pytree leaf

Guarantees (the fault-tolerance contract, DESIGN.md §6):

* **atomic** — written to ``step_<N>.tmp-<nonce>`` then os.rename'd; a crash
  mid-save never corrupts the latest checkpoint, and ``latest_step`` only
  sees fully renamed directories.
* **verified** — every leaf carries a content hash, checked on restore.
* **elastic / mesh-agnostic** — leaves are stored as full (unsharded) host
  arrays keyed by tree path, so a restore may target ANY mesh shape: the
  caller re-device_puts with whatever NamedShardings the new topology wants.
  (At real pod scale each host would write its shard slice; the manifest
  format already carries shape+dtype so that change is local.)
* **async** — ``save_checkpoint(..., sync=False)`` hands the host arrays to a
  daemon thread; training continues while the previous step serializes.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import time
import uuid
from typing import Optional

import jax
import numpy as np

_SEP = "."

# a .tmp-* dir older than this is a leftover from a crashed writer, not an
# in-flight save — latest_step sweeps it
_STALE_TMP_S = 600.0


class CheckpointCorruptError(Exception):
    """A checkpoint failed integrity validation (checksum/shape/missing leaf).

    Raised instead of ``assert`` so the guard survives ``python -O``."""


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for p in path:
            keys.append(str(getattr(p, "key", getattr(p, "idx", getattr(
                p, "name", p)))))
        flat[_SEP.join(keys)] = leaf
    return flat


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree, *, sync: bool = True,
                    extra: Optional[dict] = None) -> threading.Thread | None:
    """Write the pytree; returns the writer thread when ``sync=False``."""
    host = {k: np.asarray(jax.device_get(v))
            for k, v in _flatten(tree).items()}

    def write():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = f"{final}.tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}, "extra": extra or {}}
        for k, a in host.items():
            fn = k.replace("/", "_") + ".npy"
            np.save(os.path.join(tmp, fn), a)
            manifest["leaves"][k] = {"file": fn, "shape": list(a.shape),
                                     "dtype": str(a.dtype), "sha": _sha(a)}
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(final):  # re-save of same step (retry path)
            shutil.rmtree(final)
        os.rename(tmp, final)

    if sync:
        write()
        return None

    def guarded():
        # a daemon thread's traceback goes to stderr and vanishes — record
        # the failure on the thread object so whoever joins it can surface
        # it (otherwise checkpointing silently stops and the newest
        # checkpoint goes stale without anyone noticing)
        try:
            write()
        except BaseException as e:  # noqa: BLE001 — must not die silently
            th.exception = e

    th = threading.Thread(target=guarded, daemon=True)
    th.exception = None
    th.start()
    return th


def _manifest_ok(step_dir: str) -> bool:
    """True iff the dir holds a readable, parseable manifest.json."""
    try:
        with open(os.path.join(step_dir, "manifest.json")) as fh:
            json.load(fh)
        return True
    except (OSError, ValueError):
        return False


def latest_step(directory: str) -> Optional[int]:
    """Newest *complete* checkpoint step, or None.

    Torn ``step_*`` dirs (no readable manifest — e.g. a writer killed after
    rename was prepared by hand, or a partial copy) are skipped, and stale
    ``.tmp-*`` dirs left by a crashed async writer are swept so they cannot
    accumulate."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        full = os.path.join(directory, d)
        if ".tmp-" in d:
            try:
                if time.time() - os.path.getmtime(full) > _STALE_TMP_S:
                    shutil.rmtree(full, ignore_errors=True)
            except OSError:
                pass
            continue
        m = re.fullmatch(r"step_(\d+)", d)
        if m and _manifest_ok(full):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def _load_leaf(step_dir: str, key: str, meta: dict) -> np.ndarray:
    try:
        a = np.load(os.path.join(step_dir, meta["file"]))
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(f"unreadable leaf {key}: {e}") from e
    if _sha(a) != meta["sha"]:
        raise CheckpointCorruptError(f"checksum mismatch for {key}")
    return a


def load_checkpoint(directory: str, step: int) -> tuple[dict, dict]:
    """Structure-free restore: ``(flat {path: np.ndarray}, extra)``.

    Verifies every leaf's checksum and manifest shape.  Used when the
    restoring side does not know the tree shapes in advance (e.g. adopting a
    dead replica's engine state, whose queue depth and dataset sizes are
    whatever they were at death)."""
    d = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(d, "manifest.json")) as fh:
            manifest = json.load(fh)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"unreadable manifest for step {step}: {e}") from e
    flat = {}
    for k, meta in manifest["leaves"].items():
        a = _load_leaf(d, k, meta)
        if list(a.shape) != list(meta["shape"]):
            raise CheckpointCorruptError(
                f"shape mismatch for {k}: {list(a.shape)} vs {meta['shape']}")
        flat[k] = a
    return flat, manifest.get("extra", {})


def restore_checkpoint(directory: str, step: int, like_tree, *,
                       shardings=None):
    """Restore into the structure of ``like_tree`` (shapes must match).

    ``shardings`` (same structure) re-places leaves onto the current mesh —
    this is the elastic path: the checkpoint does not care what mesh wrote
    it."""
    d = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(d, "manifest.json")) as fh:
            manifest = json.load(fh)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"unreadable manifest for step {step}: {e}") from e
    flat_like = _flatten(like_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k, leaf in flat_like.items():
        if k not in manifest["leaves"]:
            raise CheckpointCorruptError(f"missing leaf {k} in step {step}")
        meta = manifest["leaves"][k]
        a = _load_leaf(d, k, meta)
        if tuple(a.shape) != tuple(leaf.shape):
            raise CheckpointCorruptError(
                f"shape mismatch for {k}: {a.shape} vs {leaf.shape}")
        out[k] = jax.device_put(a, flat_sh.get(k)) if k in flat_sh \
            else jax.device_put(a)
    # rebuild the tree
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    keys = []
    for path, _ in leaves_paths:
        ks = [str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path]
        keys.append(_SEP.join(ks))
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys]), \
        manifest["extra"]
