"""Sharded, atomic, mesh-agnostic checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json      {path: {shape, dtype, sha256}, step, ...}
            <leaf-path>.npy    one file per pytree leaf

Guarantees (the fault-tolerance contract, DESIGN.md §6):

* **atomic** — written to ``step_<N>.tmp-<nonce>`` then os.rename'd; a crash
  mid-save never corrupts the latest checkpoint, and ``latest_step`` only
  sees fully renamed directories.
* **verified** — every leaf carries a content hash, checked on restore.
* **elastic / mesh-agnostic** — leaves are stored as full (unsharded) host
  arrays keyed by tree path, so a restore may target ANY mesh shape: the
  caller re-device_puts with whatever NamedShardings the new topology wants.
  (At real pod scale each host would write its shard slice; the manifest
  format already carries shape+dtype so that change is local.)
* **async** — ``save_checkpoint(..., sync=False)`` hands the host arrays to a
  daemon thread; training continues while the previous step serializes.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import threading
import uuid
from typing import Optional

import jax
import numpy as np

_SEP = "."


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for p in path:
            keys.append(str(getattr(p, "key", getattr(p, "idx", getattr(
                p, "name", p)))))
        flat[_SEP.join(keys)] = leaf
    return flat


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree, *, sync: bool = True,
                    extra: Optional[dict] = None) -> threading.Thread | None:
    """Write the pytree; returns the writer thread when ``sync=False``."""
    host = {k: np.asarray(jax.device_get(v))
            for k, v in _flatten(tree).items()}

    def write():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = f"{final}.tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}, "extra": extra or {}}
        for k, a in host.items():
            fn = k.replace("/", "_") + ".npy"
            np.save(os.path.join(tmp, fn), a)
            manifest["leaves"][k] = {"file": fn, "shape": list(a.shape),
                                     "dtype": str(a.dtype), "sha": _sha(a)}
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(final):  # re-save of same step (retry path)
            import shutil
            shutil.rmtree(final)
        os.rename(tmp, final)

    if sync:
        write()
        return None
    th = threading.Thread(target=write, daemon=True)
    th.start()
    return th


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, *,
                       shardings=None):
    """Restore into the structure of ``like_tree`` (shapes must match).

    ``shardings`` (same structure) re-places leaves onto the current mesh —
    this is the elastic path: the checkpoint does not care what mesh wrote
    it."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as fh:
        manifest = json.load(fh)
    flat_like = _flatten(like_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k, leaf in flat_like.items():
        meta = manifest["leaves"][k]
        a = np.load(os.path.join(d, meta["file"]))
        assert _sha(a) == meta["sha"], f"checksum mismatch for {k}"
        assert tuple(a.shape) == tuple(leaf.shape), \
            f"shape mismatch for {k}: {a.shape} vs {leaf.shape}"
        out[k] = jax.device_put(a, flat_sh.get(k)) if k in flat_sh \
            else jax.device_put(a)
    # rebuild the tree
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    keys = []
    for path, _ in leaves_paths:
        ks = [str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path]
        keys.append(_SEP.join(ks))
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys]), \
        manifest["extra"]
