"""Train-step factory: loss -> grads -> AdamW, with gradient-accumulation
microbatching, block remat (in the trunk), and optional int8 error-feedback
gradient compression on the DP axes.

The returned step is a single jittable function of (state, batch); under a
mesh + logical_rules binding the activation/logit hints apply and the
launcher supplies in/out shardings derived from ``sharding.axes`` — the same
function lowers on 1 CPU device (smoke tests) and on the 512-way production
mesh (dry-run).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               cosine_schedule)


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    ef_error: Optional[dict] = None   # int8-EF residuals (when enabled)


def train_state_init(model: Model, key, *, compress: bool = False
                     ) -> TrainState:
    params = model.init(key)
    ef = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params) \
        if compress else None
    return TrainState(params, adamw_init(params), ef)


def make_train_step(model: Model, *, lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, microbatches: int = 1,
                    compress_axes: Optional[tuple] = None):
    """Returns step(state, batch) -> (state, metrics).

    ``microbatches`` > 1 splits the batch on the leading axis and accumulates
    grads with a lax.scan (sequential, constant memory).  ``compress_axes``
    enables int8-EF gradient compression psum over the named mesh axes (the
    step must then run inside shard_map over those axes; the launcher's
    compressed-DP mode does this).
    """
    lr_fn = cosine_schedule(lr, warmup, total_steps)
    grad_fn = jax.value_and_grad(model.loss, has_aux=True)

    def forward_backward(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        def split(x):
            return x.reshape((microbatches, -1) + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def acc_step(carry, mbatch):
            gacc, macc = carry
            (_, metrics), grads = grad_fn(params, mbatch)
            gacc = jax.tree.map(jnp.add, gacc, grads)
            macc = jax.tree.map(jnp.add, macc, metrics)
            return (gacc, macc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"loss": 0.0, "nll": 0.0, "z_loss": 0.0}
        if model.cfg.ff_kind == "moe":
            m0.update(moe_aux_loss=0.0, moe_overflow=0.0)
        m0 = jax.tree.map(jnp.float32, m0)
        (grads, metrics), _ = jax.lax.scan(acc_step, (g0, m0), mb)
        inv = 1.0 / microbatches
        return (jax.tree.map(lambda g: g * inv, grads),
                jax.tree.map(lambda m: m * inv, metrics))

    def step(state: TrainState, batch) -> tuple:
        grads, metrics = forward_backward(state.params, batch)
        ef = state.ef_error
        if compress_axes is not None:
            from repro.optim.compress import ef_compress_grads
            grads, ef = ef_compress_grads(grads, ef, compress_axes)
        params, opt, om = adamw_update(state.params, grads, state.opt,
                                       lr_fn=lr_fn)
        metrics = {**metrics, **om}
        return TrainState(params, opt, ef), metrics

    return step
