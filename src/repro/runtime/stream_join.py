"""Windowed streaming ApproxJoin: unbounded micro-batches, bounded state.

StreamApprox extended the ApproxJoin dataflow to unbounded streams: online
sampling over micro-batches preserves the paper's error bounds without ever
seeing the whole input.  This module is that subsystem for the serving
engine: a :class:`StreamJoinSession` accepts per-tenant micro-batches of
every join input and serves tumbling- or sliding-window ApproxJoin estimates
— each window carrying the paper's CLT error bound — through a
:class:`StreamJoinServer` (a :class:`~repro.runtime.join_serve.JoinServer`
with per-tenant admission control).

What is incremental, and what licenses it:

* **Filters.**  A window's per-input Bloom filter is the OR of its
  sub-windows' filters (scatter-OR is a set union).  Each arriving
  micro-batch is fingerprinted and its filter words built ONCE through the
  server's filter-word cache; emission ORs the cached words (a cached
  ``wor`` executable) and expiry drops them from the OR — and retires them
  from the cache.  Sliding a window by one sub-window therefore costs
  exactly one new build per input; every surviving sub-window is a cache
  hit, asserted in ``tests/test_stream_join.py``.  Because the OR equals a
  from-scratch build over the window's concatenated rows, the served window
  is **bit-identical** to re-registering the window as a static dataset.
* **Executables.**  Every window of a session lands in one serving shape
  class (sub-windows are fixed-capacity slots, windows pad to one pow2
  bucket), so steady-state streaming incurs **zero recompiles** — the
  ``prepare``/``sample``/``exact`` stage programs plus the streaming
  ``wor``/``sketch`` stages all live in the server's executable cache.
* **Seeds.**  ``JoinRequest.filter_seed`` decouples the filter hash (fixed
  per session, so cached words stay valid across windows) from the sampling
  seed (varies per window, so per-window draws are independent — the
  accuracy gate depends on this).
* **Estimator parts.**  Disjoint windows sample independently, so their
  :class:`~repro.core.estimators.SumParts` ADD — the same merge the psum
  serve path uses across devices, reused here across time:
  :meth:`StreamJoinSession.running_estimate` folds each emitted
  non-overlapping window's parts into a running whole-stream estimate with
  a CLT bound, at O(1) state.
* **Capacity plans.**  On a mesh in ``serve_mode='psum'`` the shuffle
  buckets are re-planned per window from the ROLLING overlap estimate: the
  Bloom-probe live fraction measured by each served window
  (``diagnostics.overlap_fraction``) feeds an EWMA that becomes the next
  window's ``overlap_hint`` — the registration-time planning trick,
  restated for a moving distribution.
* **Kernels.**  ``use_kernels=True`` sessions serve their windows through
  the engine's batched Pallas path: sub-window filter words build once
  through the kernel hash (bit-identical to the jnp build, so the word
  cache is shared), the window's OR-merge feeds the stacked
  ``[B, num_blocks, 8]`` filter probe directly, and the decoupled
  filter/sampling seeds are runtime kernel operands — the session's single
  shape class stays zero-recompile at steady state, now at kernel speed.
* **Sketch.**  A merge-able per-stratum reservoir
  (:class:`~repro.core.sampling.Reservoir`) folds every micro-batch's
  values in bounded memory — stream-level per-stratum value moments for
  monitoring and sizing, independent of any window.

Admission (the ROADMAP's **streaming admission** item) lives in
:class:`StreamJoinServer`: each session may have at most ``window_slots``
windows queued — beyond that the OLDEST queued window is shed (marked, never
served, counted in ``StreamDiagnostics.windows_shed``) so a backed-up tenant
degrades to fresh windows instead of unbounded queue growth.  Scheduling is
the base server's deadline-aware policy: when the queue backs up,
latency-budget windows are served before error-budget ones.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import bloom
from repro.core.budget import QueryBudget
from repro.core.estimators import Estimate, SumParts, clt_finish, clt_sum_parts
from repro.core.relation import Relation, bucket_capacity, fingerprint, pad_to
from repro.core.sampling import (Reservoir, reservoir_empty, reservoir_extend,
                                 reservoir_moments)
from repro.core.window import SubWindow, WindowBuffer, WindowSpec
from repro.runtime.join_serve import DEFAULT_B_MAX, JoinRequest, JoinServer
from repro.runtime.telemetry import MetricsRegistry, latency_pcts


def _make_window_or(n_subs: int):
    def fn(words):  # [n_subs, num_blocks, W] -> [num_blocks, W]
        out = words[0]
        for i in range(1, n_subs):
            out = out | words[i]
        return out
    return jax.jit(fn)


def _make_sketch():
    return jax.jit(reservoir_extend)


def _make_window_assemble(n_subs: int, n_sides: int, cap: int):
    """One fused executable for window assembly: concat every side's
    sub-window fields and pad to the window's capacity bucket (48 host-side
    concatenates otherwise — measurable at streaming rates)."""
    def fn(flat):
        rels = []
        for side in range(n_sides):
            cols = []
            for f, fill in ((0, jnp.uint32(0)), (1, jnp.float32(0)),
                            (2, False)):
                parts = [flat[3 * (side * n_subs + m) + f]
                         for m in range(n_subs)]
                col = jnp.concatenate(parts)
                pad = cap - col.shape[0]
                if pad:
                    col = jnp.concatenate(
                        [col, jnp.full((pad,), fill, col.dtype)])
                cols.append(col)
            rels.append(Relation(*cols))
        return rels
    return jax.jit(fn)


# StreamDiagnostics scalar counters:
#   admission_dropped_rows — micro-batch rows beyond the sub-window slot cap
#   windows_shed — dropped by per-tenant admission, never served
#   windows_served — served windows (the window-latency ring's population)
#   retired_filter_words — expired sub-window words evicted from the cache
_STREAM_SCALAR_FIELDS = ("sessions", "sub_windows", "admission_dropped_rows",
                         "windows_emitted", "windows_served", "windows_shed",
                         "retired_filter_words")


class StreamDiagnostics:
    """Streaming-side counters (the join counters stay in the base
    ``ServerDiagnostics`` — one serving engine, one set of cache meters).

    Backed by the same :class:`~repro.runtime.telemetry.MetricsRegistry` as
    the owning server's ``ServerDiagnostics`` (metric names carry a
    ``stream_`` prefix), and ``snapshot()`` uses the same percentile
    helper/schema (``window_latency_p50_s``/``_p95_s``/``_max_s``) — so
    dashboards and the trajectory gate row-match stream and batch metrics
    uniformly, and one Prometheus scrape covers both.
    """

    _SCALARS = frozenset(_STREAM_SCALAR_FIELDS)

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = MetricsRegistry() if registry is None else registry
        for f in _STREAM_SCALAR_FIELDS:
            self.registry.counter("stream_" + f)
        # bounded ring of per-window ingest->complete latencies
        self._lat = self.registry.histogram("stream_window_latencies")

    def __getattr__(self, name):
        d = object.__getattribute__(self, "__dict__")
        reg = d.get("registry")
        if reg is not None and name in self._SCALARS:
            return reg.counter("stream_" + name).value
        raise AttributeError(name)

    def __setattr__(self, name, value):
        if name in self._SCALARS:
            self.registry.counter("stream_" + name).value = value
        else:
            object.__setattr__(self, name, value)

    def note_window_latency(self, e2e_s: float, cap: int) -> None:
        """Record one served window's ingest->complete latency."""
        self.windows_served += 1
        self._lat.cap = cap
        self._lat.observe(e2e_s)

    def scalars(self) -> dict:
        """The scalar counters as a plain dict (the crash-safe meta form)."""
        return {f: getattr(self, f) for f in _STREAM_SCALAR_FIELDS}

    def snapshot(self) -> dict:
        """Read-only, idempotent view (same contract and percentile schema
        as ``ServerDiagnostics.snapshot``)."""
        d = self.scalars()
        d.update(latency_pcts(self._lat.samples, "window_latency"))
        return d


class StreamJoinSession:
    """One tenant's windowed streaming join (construct via
    :meth:`StreamJoinServer.open_stream`).

    ``push`` admits one micro-batch per join input, emits any windows that
    became due as queries on the server's queue, and returns them; call
    ``server.run()`` (or ``step()``) to serve, then :meth:`drain` for the
    finished windows in completion order.
    """

    def __init__(self, server: "StreamJoinServer", name: str,
                 spec: WindowSpec, *, n_sides: int = 2,
                 budget: QueryBudget = QueryBudget(),
                 agg: str = "sum", expr: str = "sum", dedup: bool = False,
                 seed: int = 0, fp_rate: float = 0.01,
                 max_strata: Optional[int] = None,
                 b_max: Optional[int] = DEFAULT_B_MAX,
                 serve_mode: Optional[str] = None,
                 use_kernels: bool = False,
                 sketch_strata: int = 64, sketch_cap: int = 64,
                 overlap_alpha: float = 0.5):
        self.server = server
        self.name = name
        self.spec = spec.validate()
        self.n_sides = n_sides
        self.budget = budget
        self.agg, self.expr, self.dedup = agg, expr, dedup
        # kernel mode: windows serve through the engine's batched Pallas
        # path — sub-window words still build once through the filter cache
        # (via the kernel hash — bit-identical words, shared entries) and
        # the window's OR-merge feeds the stacked-filter probe directly;
        # the decoupled filter_seed/sampling seeds are runtime operands, so
        # steady-state streaming stays zero-recompile in kernel mode too
        self.use_kernels = use_kernels
        self.seed = seed
        self.filter_seed = seed
        self.fp_rate = fp_rate
        self.b_max = b_max
        self.serve_mode = serve_mode
        # every window of the session shares one shape class: fixed
        # sub-window slots, window capacity = one pow2 bucket
        self.sub_cap = bucket_capacity(spec.sub_rows, minimum=server.mesh_k)
        self.window_cap = bucket_capacity(spec.size * self.sub_cap,
                                          minimum=server.mesh_k)
        self.max_strata = self.window_cap if max_strata is None else max_strata
        self.num_blocks = bloom.num_blocks_for(self.window_cap, fp_rate)
        self.buffer = WindowBuffer(spec)
        self.query_id = f"{name}/stream"
        self.pending: list[JoinRequest] = []
        self.results: list[JoinRequest] = []
        # rolling Bloom-probe overlap (None until the first window lands ->
        # the first psum plan is the lossless overlap-1.0 one)
        self.overlap_alpha = overlap_alpha
        self.overlap_ewma: Optional[float] = None
        # running whole-stream accumulation of disjoint windows' parts
        self._running = (0.0, 0.0, 0.0, 0.0, 0.0)
        self._acc_end = 0
        self.accumulated_windows = 0
        # bounded per-stratum value reservoirs, one per input
        self.sketch_strata, self.sketch_cap = sketch_strata, sketch_cap
        self.sketch = [reservoir_empty(sketch_strata, sketch_cap)
                       for _ in range(n_sides)] if sketch_cap else None

    # -- ingestion ----------------------------------------------------------

    def _admit_micro_batch(self, r: Relation) -> Relation:
        """Bound one micro-batch to its sub-window slot (rows beyond the cap
        are dropped and counted — bounded-memory admission)."""
        cap = self.sub_cap
        if r.capacity > cap:
            dropped = int(jax.device_get(
                jnp.sum(r.valid[cap:].astype(jnp.int32))))
            self.server.stream_diagnostics.admission_dropped_rows += dropped
            r = Relation(r.keys[:cap], r.values[:cap], r.valid[:cap])
        elif r.capacity < cap:
            r = pad_to(r, cap)
        if self.server.mesh is not None:
            from repro.core.relation import shard_to_mesh
            r = shard_to_mesh(r, self.server.mesh, self.server.join_axes)
        return r

    def push(self, rels: Sequence[Relation]) -> list[JoinRequest]:
        """Admit one micro-batch per side; returns the windows that became
        due (already submitted to the server, not yet served)."""
        if len(rels) != self.n_sides:
            raise ValueError(f"expected {self.n_sides} inputs, got "
                             f"{len(rels)}")
        tick = self.buffer.arrived
        admitted = [self._admit_micro_batch(r) for r in rels]
        if self.sketch is not None:
            fn, _ = self.server._executable(
                "sketch", (self.sketch_strata, self.sketch_cap, self.sub_cap),
                None, _make_sketch)
            for side, r in enumerate(admitted):
                self.sketch[side] = fn(self.sketch[side], r.keys, r.values,
                                       r.valid, jnp.uint32(self.filter_seed),
                                       jnp.uint32(tick))
        sub = SubWindow(tick, tuple(admitted),
                        tuple(fingerprint(r) for r in admitted))
        due, expired = self.buffer.push(sub)
        self.server.stream_diagnostics.sub_windows += 1
        out = [self._emit(w, subs) for w, subs in due]
        # retire AFTER emission: a sub-window can expire in the same push
        # that emits its last window, and that window still needs its words
        self._retire(expired)
        return out

    def _retire(self, expired: Sequence[SubWindow]) -> None:
        """Evict expired sub-window filter words.

        The filter-word cache is server-global, so the keep-set must span
        EVERY session's live sub-windows: two sessions consuming the same
        upstream micro-batches under the same seed share cache entries, and
        one session expiring must not evict words the other still needs
        (that would silently re-pay the full-window rebuild the subsystem
        exists to avoid).
        """
        keep = {fp for sess in self.server.sessions.values()
                for s in sess.buffer.live for fp in s.fps}
        for sub in expired:
            for fp in sub.fps:
                if fp in keep:
                    continue
                key = (fp, self.num_blocks, self.filter_seed)
                if self.server._filter_words.pop(key, None) is not None:
                    self.server.stream_diagnostics.retired_filter_words += 1

    # -- emission -----------------------------------------------------------

    def _window_words(self, subs: Sequence[SubWindow]) -> list:
        """Per-side window filter words: OR of the cached sub-window builds
        (new sub-windows build, survivors hit the cache — the incremental
        contract the slide test asserts)."""
        srv = self.server
        words = []
        for side in range(self.n_sides):
            sub_words = [srv._words_for(s.rels[side], s.fps[side],
                                        self.num_blocks, self.filter_seed,
                                        use_kernels=self.use_kernels)
                         for s in subs]
            if len(sub_words) == 1:
                words.append(sub_words[0])
            else:
                or_fn, _ = srv._executable(
                    "wor", (len(sub_words), self.num_blocks), None,
                    partial(_make_window_or, len(sub_words)))
                words.append(or_fn(jnp.stack(sub_words)))
        return words

    def _window_rels(self, subs: Sequence[SubWindow]) -> list[Relation]:
        """:func:`~repro.core.window.window_relations` as one cached fused
        executable (same result, one dispatch instead of ~6 per side)."""
        asm, _ = self.server._executable(
            "wasm", (len(subs), self.n_sides, self.sub_cap, self.window_cap),
            None, partial(_make_window_assemble, len(subs), self.n_sides,
                          self.window_cap))
        flat = tuple(x for side in range(self.n_sides)
                     for s in subs for x in s.rels[side])
        return asm(flat)

    def _emit(self, w: int, subs: Sequence[SubWindow]) -> JoinRequest:
        self._drain_finished()
        req = JoinRequest(
            rels=self._window_rels(subs),
            budget=self.budget, agg=self.agg, expr=self.expr,
            query_id=self.query_id, seed=self.seed + 1 + w,
            filter_seed=self.filter_seed, fp_rate=self.fp_rate,
            max_strata=self.max_strata, b_max=self.b_max, dedup=self.dedup,
            use_kernels=self.use_kernels, serve_mode=self.serve_mode,
            overlap_hint=self.overlap_ewma, stream=self.name, window_id=w)
        req._words = self._window_words(subs)
        self.server._submit_window(self, req)
        self.pending.append(req)
        self.server.stream_diagnostics.windows_emitted += 1
        return req

    # -- results ------------------------------------------------------------

    def _drain_finished(self) -> None:
        still = []
        for req in self.pending:
            if req.shed:
                continue                       # counted at shed time
            if not req.done:
                still.append(req)
                continue
            self.results.append(req)
            if self.server.mesh is not None:
                # the rolling overlap only feeds the mesh psum bucket plan;
                # off-mesh there is no consumer, so skip the host sync
                obs = float(jax.device_get(
                    req.result.diagnostics.overlap_fraction))
                if math.isfinite(obs):
                    self.overlap_ewma = obs if self.overlap_ewma is None \
                        else (self.overlap_alpha * obs
                              + (1.0 - self.overlap_alpha)
                              * self.overlap_ewma)
            self._accumulate(req)
        self.pending = still

    def drain(self) -> list[JoinRequest]:
        """Finished (served) window requests since the last drain."""
        self._drain_finished()
        out, self.results = self.results, []
        return out

    def _accumulate(self, req: JoinRequest) -> None:
        """Fold a non-overlapping window's estimator parts into the running
        whole-stream estimate (disjoint windows sample independently, so
        their SumParts ADD — the psum merge, across time).  SUM only; shed
        windows leave a counted gap."""
        if self.agg != "sum" or self.dedup:
            return
        start, end = self.spec.start(req.window_id), self.spec.end(
            req.window_id)
        if start < self._acc_end:
            return                              # overlaps accumulated span
        res = req.result
        if res.stats is not None:
            p = clt_sum_parts(res.stats)
            parts = tuple(float(x) for x in jax.device_get(
                (p.tau, p.var, p.n_draws, p.m_strata, p.count)))
        else:                                   # exact window: zero variance
            parts = (float(res.estimate), 0.0, 0.0, 0.0, float(res.count))
        self._running = tuple(a + b for a, b in zip(self._running, parts))
        self._acc_end = end
        self.accumulated_windows += 1

    def running_estimate(self,
                         confidence: Optional[float] = None
                         ) -> Optional[Estimate]:
        """CLT estimate of the stream-total SUM over every accumulated
        (disjoint) window, O(1) state.  None before the first window."""
        if not self.accumulated_windows:
            return None
        return clt_finish(SumParts(*self._running),
                          self.budget.confidence if confidence is None
                          else confidence)

    def sketch_moments(self, side: int):
        """(n, mean, var) per sketch stratum of input ``side`` — the
        bounded-memory stream-level value moments from the reservoir."""
        assert self.sketch is not None, "session built with sketch_cap=0"
        return reservoir_moments(self.sketch[side])


class StreamJoinServer(JoinServer):
    """A JoinServer that owns streaming sessions and their admission.

    ``window_slots`` bounds each session's queued-but-unserved windows;
    emitting past the bound sheds the session's OLDEST queued window
    (freshness over completeness — the shed window is marked and counted,
    never silently lost).  Everything else — executable cache, filter-word
    cache, sigma registry, mesh routing, deadline-aware scheduling, sigma
    pipelining — is the base engine, shared with static queries on the same
    server.
    """

    def __init__(self, *, window_slots: int = 8, **kw):
        super().__init__(**kw)
        self.window_slots = window_slots
        self.sessions: dict[str, StreamJoinSession] = {}
        # one registry across server + stream diagnostics: a single
        # snapshot/Prometheus scrape covers the whole serving surface
        self.stream_diagnostics = StreamDiagnostics(
            registry=self.diagnostics.registry)

    def open_stream(self, name: str, spec: WindowSpec,
                    **kw) -> StreamJoinSession:
        if name in self.sessions:
            raise ValueError(f"stream {name!r} already open")
        session = StreamJoinSession(self, name, spec, **kw)
        self.sessions[name] = session
        self.stream_diagnostics.sessions += 1
        return session

    def _submit_window(self, session: StreamJoinSession,
                       req: JoinRequest) -> None:
        queued = [r for r in self.queue if r.stream == session.name]
        while len(queued) >= self.window_slots:
            victim = queued.pop(0)
            # drop by identity: the victim is rarely at the queue head in a
            # multi-tenant queue, and requests are identities, not values
            self.queue = [r for r in self.queue if r is not victim]
            victim.shed = True
            self.stream_diagnostics.windows_shed += 1
            self.tracer.instant(
                "shed", cat="admission", tid=self.trace_name,
                query_id=victim.query_id, stream=victim.stream,
                window=victim.window_id, qspan=victim._span_id)
            # a shed window is terminal: fire the completion hook so an
            # async caller's future resolves (with .shed set) instead of
            # hanging forever on a window that will never be served
            self._notify_done(victim)
        self.submit(req)

    def _notify_done(self, req: JoinRequest) -> None:
        if req.stream is not None and req.done and not req.shed:
            self.stream_diagnostics.note_window_latency(
                req.e2e_latency_s, self.latency_samples)
        super()._notify_done(req)

    # -- crash safety: snapshot / restore -----------------------------------

    def snapshot_state(self) -> tuple[dict, dict]:
        """Engine snapshot + every streaming session's live state.

        Per session: window-buffer bookkeeping (``arrived``/``emitted``) and
        live sub-windows (relations + fingerprints), per-side reservoir
        sketches, the cross-window running ``SumParts`` accumulation, the
        rolling overlap EWMA, and the full session configuration — enough
        for :meth:`restore_state` to rebuild a session whose FUTURE windows
        (ids, seeds, emission points) are bit-identical to the uninterrupted
        session's.  Finished-but-undrained windows are folded into the
        accumulation first (exactly what the next ``push`` would do); their
        request objects are not checkpointed — completion futures already
        resolved when they were served."""
        for sess in self.sessions.values():
            sess._drain_finished()
        flat, meta = super().snapshot_state()
        sess_meta = []
        for si, (name, s) in enumerate(self.sessions.items()):
            for j, sub in enumerate(s.buffer.live):
                for side in range(s.n_sides):
                    self._rel_arrays(flat, f"sess/{si}/live/{j}/{side}",
                                     sub.rels[side])
            if s.sketch is not None:
                for side in range(s.n_sides):
                    res = s.sketch[side]
                    flat[f"sess/{si}/sketch/{side}/priority"] = res.priority
                    flat[f"sess/{si}/sketch/{side}/values"] = res.values
                    flat[f"sess/{si}/sketch/{side}/n_seen"] = res.n_seen
            sess_meta.append({
                "name": name, "spec": list(s.spec), "n_sides": s.n_sides,
                "budget": list(s.budget), "agg": s.agg, "expr": s.expr,
                "dedup": s.dedup, "seed": s.seed,
                "filter_seed": s.filter_seed, "fp_rate": s.fp_rate,
                "max_strata": s.max_strata, "b_max": s.b_max,
                "serve_mode": s.serve_mode, "use_kernels": s.use_kernels,
                "sketch_strata": s.sketch_strata,
                "sketch_cap": s.sketch_cap,
                "overlap_alpha": s.overlap_alpha,
                "overlap_ewma": s.overlap_ewma,
                "running": list(s._running), "acc_end": s._acc_end,
                "accumulated_windows": s.accumulated_windows,
                "arrived": s.buffer.arrived, "emitted": s.buffer.emitted,
                "live": [{"index": sub.index, "fps": list(sub.fps)}
                         for sub in s.buffer.live]})
        meta["sessions"] = sess_meta
        meta["stream_diag"] = self.stream_diagnostics.scalars()
        return flat, meta

    def restore_state(self, flat: dict, meta: dict) -> list[JoinRequest]:
        """Engine restore + session adoption.

        Sessions are rebuilt through :meth:`open_stream` with their saved
        configuration, then their buffers/sketches/accumulators are
        overwritten from the snapshot (sub-window fingerprints come from the
        snapshot, matching the restored filter-word cache keys, so surviving
        sub-windows keep hitting the cache).  Queued window requests
        restored by the base engine re-attach to their sessions' pending
        lists in saved (window-id) order — they were admitted pre-crash, so
        they bypass admission shedding: a failover sheds zero windows."""
        restored = super().restore_state(flat, meta)
        for si, m in enumerate(meta.get("sessions", [])):
            s = self.open_stream(
                m["name"], WindowSpec(*m["spec"]), n_sides=m["n_sides"],
                budget=QueryBudget(*m["budget"]), agg=m["agg"],
                expr=m["expr"], dedup=m["dedup"], seed=m["seed"],
                fp_rate=m["fp_rate"], max_strata=m["max_strata"],
                b_max=m["b_max"], serve_mode=m["serve_mode"],
                use_kernels=m["use_kernels"],
                sketch_strata=m["sketch_strata"],
                sketch_cap=m["sketch_cap"],
                overlap_alpha=m["overlap_alpha"])
            s.filter_seed = m["filter_seed"]
            s.overlap_ewma = m["overlap_ewma"]
            s._running = tuple(m["running"])
            s._acc_end = m["acc_end"]
            s.accumulated_windows = m["accumulated_windows"]
            s.buffer.arrived = m["arrived"]
            s.buffer.emitted = m["emitted"]
            for j, sub_m in enumerate(m["live"]):
                rels = tuple(
                    self._rel_restore(flat, f"sess/{si}/live/{j}/{side}")
                    for side in range(s.n_sides))
                s.buffer.live.append(
                    SubWindow(sub_m["index"], rels, tuple(sub_m["fps"])))
            if s.sketch is not None \
                    and f"sess/{si}/sketch/0/priority" in flat:
                s.sketch = [
                    Reservoir(
                        jnp.asarray(flat[f"sess/{si}/sketch/{d}/priority"]),
                        jnp.asarray(flat[f"sess/{si}/sketch/{d}/values"]),
                        jnp.asarray(flat[f"sess/{si}/sketch/{d}/n_seen"]))
                    for d in range(s.n_sides)]
        for req in restored:
            if req.stream is not None and req.stream in self.sessions:
                self.sessions[req.stream].pending.append(req)
        for f, v in meta.get("stream_diag", {}).items():
            if f == "sessions":
                continue            # open_stream above already counted them
            setattr(self.stream_diagnostics, f,
                    getattr(self.stream_diagnostics, f) + v)
        return restored
