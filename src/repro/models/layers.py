"""Shared neural layers: norms, rotary embedding, attention (GQA/MQA with
every assigned-arch option), dense MLP variants.

Parameters are plain dict pytrees built by ``init_*`` functions (pure in the
rng key, so ``jax.eval_shape`` can build the full-scale dry-run shapes without
allocating).  Compute dtype is bf16 with f32 softmax/norm accumulations;
params are f32 (cast at use — the standard mixed-precision recipe).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, shape, in_axis=0):
    scale = 1.0 / np.sqrt(shape[in_axis])
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return out.astype(x.dtype)


def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# --- rotary position embedding ----------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float) -> jnp.ndarray:
    """x [..., T, H, hd]; positions [..., T] (absolute).  theta==0 -> no-op
    (whisper uses absolute sinusoidal embeddings instead)."""
    if theta == 0.0:
        return x
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., T, half]
    cos = jnp.cos(ang)[..., None, :]                            # [..., T, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --- attention ----------------------------------------------------------------

class KVCache(NamedTuple):
    """Decode cache.  ``k``/``v`` are [B, S, Hk, hd]; for local attention S is
    the window and writes wrap (ring buffer).  ``pos`` is the absolute
    position of the next token, int32 [B]."""

    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray


def init_attention(key, cfg) -> dict:
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H * hd)),
        "wk": _dense_init(ks[1], (d, Hk * hd)),
        "wv": _dense_init(ks[2], (d, Hk * hd)),
        "wo": _dense_init(ks[3], (H * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((Hk * hd,), jnp.float32)
        p["bv"] = jnp.zeros((Hk * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _project_qkv(p, x, cfg, positions):
    B, T, _ = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    c = COMPUTE_DTYPE
    q = (x @ p["wq"].astype(c))
    k = (x @ p["wk"].astype(c))
    v = (x @ p["wv"].astype(c))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(c)
        k = k + p["bk"].astype(c)
        v = v + p["bv"].astype(c)
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, Hk, hd)
    v = v.reshape(B, T, Hk, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg):
    """q [B,T,H,hd], k/v [B,S,Hk,hd], mask [B?,T,S] bool -> [B,T,H*hd]."""
    B, T, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    qg = q.reshape(B, T, Hk, g, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    scores = softcap(scores, cfg.attn_softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bkgts,bskh->btkgh", w, v)
    return out.reshape(B, T, H * hd)


def _chunked_sdpa(q, k, v, pos_q, pos_k, kind: str, cfg,
                  chunk: int) -> jnp.ndarray:
    """Flash-style online-softmax attention: lax.scan over KV chunks.

    Never materializes the [T, S] score matrix — peak extra memory is one
    [B, Hk, g, T, chunk] tile.  This is the pure-JAX statement of flash
    attention (the Mosaic kernel would fuse further on real TPU); bitwise it
    matches dense softmax to ~1e-3 bf16 (tested).
    q [B,T,H,hd]; k/v [B,S,Hk,hd]; pos_q [B,T]; pos_k [B,S]."""
    B, T, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    nc = S // chunk
    qg = q.reshape(B, T, Hk, g, hd)
    kc = k.reshape(B, nc, chunk, Hk, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, Hk, hd).transpose(1, 0, 2, 3, 4)
    pc = pos_k.reshape(B, nc, chunk).transpose(1, 0, 2)
    neg = jnp.float32(-1e30)

    def step(carry, inp):
        m, l, acc = carry                       # [B,Hk,g,T], ..., [...,hd]
        kci, vci, pki = inp
        s = jnp.einsum("btkgh,bskh->bkgts", qg, kci).astype(jnp.float32)
        s = softcap(s / np.sqrt(hd), cfg.attn_softcap)
        i = pos_q[:, None, None, :, None]
        j = pki[:, None, None, None, :]
        if kind == "causal":
            mask = j <= i
        elif kind == "local":
            mask = (j <= i) & (j > i - cfg.window)
        else:
            mask = jnp.ones_like(s, bool)
        s = jnp.where(mask, s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(pexp, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgts,bskh->bkgth", pexp.astype(COMPUTE_DTYPE),
            vci).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hk, g, T), neg)
    l0 = jnp.zeros((B, Hk, g, T), jnp.float32)
    a0 = jnp.zeros((B, Hk, g, T, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, T, H * hd).astype(
        COMPUTE_DTYPE)


def attention_train(p, x, cfg, *, kind: str, positions=None,
                    kv: Optional[tuple] = None) -> jnp.ndarray:
    """Full-sequence attention.  kind: 'causal' | 'local' | 'full' | 'cross'.

    ``kv`` (pre-projected k, v and their positions mask) is used for
    cross-attention (whisper decoder over encoder states)."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T, dtype=jnp.int32)[None, :]
    if kind == "cross":
        assert kv is not None
        k, v = kv
        q = _project_qkv(p, x, cfg, positions)[0]
        mask = jnp.ones((B, T, k.shape[1]), bool)
        out = _sdpa(q, k, v, mask, cfg)
        return out @ p["wo"].astype(COMPUTE_DTYPE)
    q, k, v = _project_qkv(p, x, cfg, positions)
    chunk = cfg.attn_chunk
    if chunk and T % chunk == 0 and T > chunk:
        pos = jnp.broadcast_to(positions, (B, T))
        out = _chunked_sdpa(q, k, v, pos, pos, kind, cfg, chunk)
        return out @ p["wo"].astype(COMPUTE_DTYPE)
    i = positions[:, :, None]
    j = positions[:, None, :]
    if kind == "causal":
        mask = j <= i
    elif kind == "local":
        mask = (j <= i) & (j > i - cfg.window)
    elif kind == "full":
        mask = jnp.ones((B, T, T), bool)
    else:
        raise ValueError(kind)
    out = _sdpa(q, k, v, mask, cfg)
    return out @ p["wo"].astype(COMPUTE_DTYPE)


def cross_kv(p, enc_out, cfg):
    """Pre-project encoder states for decoder cross-attention."""
    B, S, _ = enc_out.shape
    c = COMPUTE_DTYPE
    k = (enc_out @ p["wk"].astype(c)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ p["wv"].astype(c)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return k, v


def attention_decode(p, x, cfg, cache: KVCache, *, kind: str) -> tuple:
    """One-token decode with KV cache.  kind: 'causal' (S = max context) or
    'local' (S = window, ring buffer).  x [B, 1, d]."""
    B = x.shape[0]
    S = cache.k.shape[1]
    pos = cache.pos                                         # [B]
    q, k_new, v_new = _project_qkv(p, x, cfg, pos[:, None])
    if kind == "local":
        slot = pos % S
    else:
        slot = jnp.minimum(pos, S - 1)
    bidx = jnp.arange(B)
    k = cache.k.at[bidx, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[bidx, slot].set(v_new[:, 0].astype(cache.v.dtype))
    sidx = jnp.arange(S, dtype=jnp.int32)[None, :]          # [1, S]
    if kind == "local":
        # absolute position last written into each slot
        p_slot = pos[:, None] - ((pos[:, None] - sidx) % S)
        mask = (p_slot >= 0) & (p_slot <= pos[:, None])
    else:
        mask = sidx <= pos[:, None]
    out = _sdpa(q, k.astype(COMPUTE_DTYPE), v.astype(COMPUTE_DTYPE),
                mask[:, None, :], cfg)
    y = out @ p["wo"].astype(COMPUTE_DTYPE)
    return y, KVCache(k, v, pos + 1)


def init_kv_cache(cfg, batch: int, max_seq: int, kind: str,
                  dtype=COMPUTE_DTYPE) -> KVCache:
    S = cfg.window if kind == "local" else max_seq
    shape = (batch, S, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32))


# --- dense feed-forward -------------------------------------------------------

def init_mlp(key, cfg) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ff_kind in ("swiglu", "geglu"):
        return {"wg": _dense_init(ks[0], (d, ff)),
                "wu": _dense_init(ks[1], (d, ff)),
                "wd": _dense_init(ks[2], (ff, d))}
    return {"wu": _dense_init(ks[0], (d, ff)),
            "bu": jnp.zeros((ff,), jnp.float32),
            "wd": _dense_init(ks[1], (ff, d)),
            "bd": jnp.zeros((d,), jnp.float32)}


def mlp(p, x, cfg) -> jnp.ndarray:
    c = COMPUTE_DTYPE
    if cfg.ff_kind == "swiglu":
        return (jax.nn.silu(x @ p["wg"].astype(c)) *
                (x @ p["wu"].astype(c))) @ p["wd"].astype(c)
    if cfg.ff_kind == "geglu":
        return (jax.nn.gelu(x @ p["wg"].astype(c), approximate=True) *
                (x @ p["wu"].astype(c))) @ p["wd"].astype(c)
    h = jax.nn.gelu(x @ p["wu"].astype(c) + p["bu"].astype(c),
                    approximate=True)
    return h @ p["wd"].astype(c) + p["bd"].astype(c)
