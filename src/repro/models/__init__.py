"""Model zoo: the 10 assigned architectures as one composable trunk.

Every arch is a configuration of the same scanned-block decoder trunk
(``trunk.py``) — mixer pattern (attention / local attention / Mamba / RG-LRU)
x feed-forward type (dense SwiGLU/GeGLU/GELU or MoE) — except whisper, which
composes the same layers into an encoder-decoder (``encdec.py``).
``model.py`` exposes init / loss / decode plus the registry.
"""

from repro.models.config import ARCHS, ArchConfig, get_config
from repro.models.model import Model

import repro.configs  # noqa: E402,F401  (registers the 10 arch configs)

__all__ = ["ARCHS", "ArchConfig", "get_config", "Model"]
