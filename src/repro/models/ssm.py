"""Mamba-1 selective-state-space mixer (falcon-mamba-7b).

Train path: chunked associative scan — ``lax.scan`` over sequence chunks
(carrying the [B, d_inner, d_state] state) with ``lax.associative_scan``
inside each chunk.  The chunk bounds the [B, chunk, d_inner, d_state]
discretized-transition tensor that a naive full-sequence associative scan
would materialize (gigabytes at 4k x 8192 x 16) — this is the TPU adaptation
of Mamba's fused CUDA scan (DESIGN.md §2): HBM traffic is bounded per chunk,
and the scan skeleton exposes sequence parallelism to XLA.

Decode path: O(1) recurrence update + conv ring buffer.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, _dense_init

SCAN_CHUNK = 256


class MambaCache(NamedTuple):
    conv: jnp.ndarray   # [B, d_conv - 1, d_inner] rolling inputs
    h: jnp.ndarray      # [B, d_inner, d_state] SSM state (f32)
    pos: jnp.ndarray    # [B] int32


def _cfgdims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or math.ceil(cfg.d_model / 16)
    return s, d_inner, dt_rank


def init_mamba(key, cfg) -> dict:
    s, d_inner, dt_rank = _cfgdims(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32),
                 (d_inner, 1))
    return {
        "in_proj": _dense_init(ks[0], (cfg.d_model, 2 * d_inner)),
        "conv_w": _dense_init(ks[1], (s.d_conv, d_inner)) * 0.1,
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "x_proj": _dense_init(ks[2], (d_inner, dt_rank + 2 * s.d_state)),
        "dt_proj": _dense_init(ks[3], (dt_rank, d_inner)),
        "dt_bias": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus ~ 0.01
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": _dense_init(ks[4], (d_inner, cfg.d_model)),
    }


def _ssm_inputs(p, xc, cfg):
    """Shared discretization: xc [..., d_inner] -> (dA, dBx, C_ssm)."""
    s, _, dt_rank = _cfgdims(cfg)
    proj = xc @ p["x_proj"].astype(xc.dtype)
    dt, B_ssm, C_ssm = jnp.split(
        proj, [dt_rank, dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus(
        (dt @ p["dt_proj"].astype(xc.dtype)).astype(jnp.float32)
        + p["dt_bias"])                                     # [..., d_inner]
    A = -jnp.exp(p["A_log"])                                 # [d_inner, state]
    dA = jnp.exp(dt[..., None] * A)                          # [..., d_in, st]
    dBx = (dt * xc.astype(jnp.float32))[..., None] \
        * B_ssm.astype(jnp.float32)[..., None, :]            # [..., d_in, st]
    return dA, dBx, C_ssm.astype(jnp.float32)


def _causal_conv(p, x, cfg, prefix=None):
    """Depthwise causal conv over T.  prefix [B, d_conv-1, d_inner] or zeros."""
    s, d_inner, _ = _cfgdims(cfg)
    B, T, _ = x.shape
    if prefix is None:
        prefix = jnp.zeros((B, s.d_conv - 1, d_inner), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)                # [B, T+dc-1, di]
    out = jnp.zeros_like(x, shape=(B, T, d_inner))
    for i in range(s.d_conv):                                # tiny unroll (4)
        out = out + xp[:, i:i + T, :] * p["conv_w"][i].astype(x.dtype)
    return out + p["conv_b"].astype(x.dtype)


def mamba_train(p, x, cfg) -> jnp.ndarray:
    """x [B, T, d_model] -> [B, T, d_model]; T % SCAN_CHUNK == 0 (or T small)."""
    s, d_inner, _ = _cfgdims(cfg)
    B, T, _ = x.shape
    c = COMPUTE_DTYPE
    xz = x @ p["in_proj"].astype(c)
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(p, x_in, cfg))             # [B, T, d_inner]

    chunk = SCAN_CHUNK if T % SCAN_CHUNK == 0 else T
    n_chunks = T // chunk
    xc_c = xc.reshape(B, n_chunks, chunk, d_inner).transpose(1, 0, 2, 3)

    def chunk_step(h, xck):                                  # h [B, d_in, st]
        dA, dBx, C_ssm = _ssm_inputs(p, xck, cfg)            # [B, ch, di, st]

        def combine(a, b):
            return (a[0] * b[0], b[0] * a[1] + b[1])

        pA, pBx = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        hs = pA * h[:, None] + pBx                           # [B, ch, di, st]
        y = jnp.einsum("bcds,bcs->bcd", hs, C_ssm)
        return hs[:, -1], y

    h0 = jnp.zeros((B, d_inner, s.d_state), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, xc_c)               # [nc, B, ch, di]
    y = ys.transpose(1, 0, 2, 3).reshape(B, T, d_inner).astype(c)
    y = y + p["D"].astype(c) * xc
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(c)


def init_mamba_cache(cfg, batch: int) -> MambaCache:
    s, d_inner, _ = _cfgdims(cfg)
    return MambaCache(
        jnp.zeros((batch, s.d_conv - 1, d_inner), COMPUTE_DTYPE),
        jnp.zeros((batch, d_inner, s.d_state), jnp.float32),
        jnp.zeros((batch,), jnp.int32))


def mamba_decode(p, x, cfg, cache: MambaCache):
    """One-token step: x [B, 1, d_model] -> (y [B, 1, d_model], cache)."""
    s, d_inner, _ = _cfgdims(cfg)
    c = COMPUTE_DTYPE
    xz = x[:, 0] @ p["in_proj"].astype(c)
    x_in, z = jnp.split(xz, 2, axis=-1)                      # [B, d_inner]
    window = jnp.concatenate([cache.conv, x_in[:, None]], axis=1)
    xc = jnp.einsum("btd,td->bd", window, p["conv_w"].astype(c)) \
        + p["conv_b"].astype(c)
    xc = jax.nn.silu(xc)
    dA, dBx, C_ssm = _ssm_inputs(p, xc, cfg)                 # [B, di, st]
    h = dA * cache.h + dBx
    y = jnp.einsum("bds,bs->bd", h, C_ssm).astype(c)
    y = y + p["D"].astype(c) * xc
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(c))[:, None]
    return out, MambaCache(window[:, 1:], h, cache.pos + 1)
