"""Whisper-small encoder-decoder (the [audio] arch).

The conv frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed mel frames [B, n_frames, d_input]; a linear projection stands in
for the two convs.  Positions are sinusoidal for both stacks (whisper uses
learned decoder positions; deviation noted in DESIGN.md §5).  Norms are
LayerNorm (with bias), pre-norm arrangement, GELU MLP — per the original.

Encoder: bidirectional attention over frames, scanned blocks.
Decoder: causal self-attention + cross-attention to encoder output, scanned;
decode caches self-KV per layer, cross-KV precomputed once at prefill.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _split_stack(key, n):
    return jax.random.split(key, n)


def init_enc_block(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {"pre_attn": L.layernorm_init(cfg.d_model),
            "attn": L.init_attention(k1, cfg),
            "pre_mlp": L.layernorm_init(cfg.d_model),
            "mlp": L.init_mlp(k2, cfg)}


def init_dec_block(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"pre_self": L.layernorm_init(cfg.d_model),
            "self_attn": L.init_attention(k1, cfg),
            "pre_cross": L.layernorm_init(cfg.d_model),
            "cross_attn": L.init_attention(k2, cfg),
            "pre_mlp": L.layernorm_init(cfg.d_model),
            "mlp": L.init_mlp(k3, cfg)}


def init_encdec(key, cfg) -> dict:
    enc = cfg.encoder
    ks = jax.random.split(key, 4)
    return {
        "frame_proj": L._dense_init(ks[0], (enc.d_input, cfg.d_model)),
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg))(
            _split_stack(ks[1], enc.n_layers)),
        "enc_norm": L.layernorm_init(cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg))(
            _split_stack(ks[2], cfg.n_layers)),
    }


def encode(p, frames, cfg) -> jnp.ndarray:
    """frames [B, F, d_input] -> encoder states [B, F, d]."""
    B, F, _ = frames.shape
    x = (frames.astype(L.COMPUTE_DTYPE) @
         p["frame_proj"].astype(L.COMPUTE_DTYPE))
    x = x + L.sinusoidal_embedding(
        jnp.arange(F, dtype=jnp.int32), cfg.d_model).astype(x.dtype)

    def step(x, bp):
        h = L.layernorm(bp["pre_attn"], x, cfg.norm_eps)
        x = x + L.attention_train(bp["attn"], h, cfg, kind="full")
        h = L.layernorm(bp["pre_mlp"], x, cfg.norm_eps)
        x = x + L.mlp(bp["mlp"], h, cfg)
        return x, None

    fn = jax.checkpoint(step) if cfg.remat == "block" else step
    x, _ = jax.lax.scan(fn, x, p["enc_blocks"])
    return L.layernorm(p["enc_norm"], x, cfg.norm_eps)


def decode_train(p, x, enc_out, cfg, positions) -> jnp.ndarray:
    """Teacher-forced decoder pass: x [B, T, d] token embeddings."""

    def step(x, bp):
        h = L.layernorm(bp["pre_self"], x, cfg.norm_eps)
        x = x + L.attention_train(bp["self_attn"], h, cfg, kind="causal",
                                  positions=positions)
        h = L.layernorm(bp["pre_cross"], x, cfg.norm_eps)
        kv = L.cross_kv(bp["cross_attn"], enc_out, cfg)
        x = x + L.attention_train(bp["cross_attn"], h, cfg, kind="cross",
                                  kv=kv)
        h = L.layernorm(bp["pre_mlp"], x, cfg.norm_eps)
        x = x + L.mlp(bp["mlp"], h, cfg)
        return x, None

    fn = jax.checkpoint(step) if cfg.remat == "block" else step
    x, _ = jax.lax.scan(fn, x, p["dec_blocks"])
    return x


class EncDecCache(NamedTuple):
    self_kv: L.KVCache       # leaves stacked [n_dec_layers, ...]
    cross_k: jnp.ndarray     # [n_dec, B, F, Hk, hd]
    cross_v: jnp.ndarray


def init_encdec_cache(p, enc_out, cfg, batch: int, max_seq: int):
    """Precompute cross-KV from encoder output; allocate self cache."""
    def per_layer(bp):
        return L.cross_kv(bp["cross_attn"], enc_out, cfg)

    ck, cv = jax.vmap(per_layer)(p["dec_blocks"])
    one = L.init_kv_cache(cfg, batch, max_seq, "causal")
    self_kv = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
    return EncDecCache(self_kv, ck, cv)


def decode_step(p, x, cfg, cache: EncDecCache) -> tuple:
    """One-token decoder step: x [B, 1, d] -> (x, new cache)."""

    def step(x, inp):
        bp, skv, ck, cv = inp
        h = L.layernorm(bp["pre_self"], x, cfg.norm_eps)
        mx, nkv = L.attention_decode(bp["self_attn"], h, cfg, skv,
                                     kind="causal")
        x = x + mx
        h = L.layernorm(bp["pre_cross"], x, cfg.norm_eps)
        x = x + L.attention_train(bp["cross_attn"], h, cfg, kind="cross",
                                  kv=(ck, cv))
        h = L.layernorm(bp["pre_mlp"], x, cfg.norm_eps)
        x = x + L.mlp(bp["mlp"], h, cfg)
        return x, nkv

    x, new_self = jax.lax.scan(
        step, x, (p["dec_blocks"], cache.self_kv, cache.cross_k,
                  cache.cross_v))
    return x, EncDecCache(new_self, cache.cross_k, cache.cross_v)
