"""RG-LRU recurrent mixer (recurrentgemma-2b), per arXiv:2402.19427 §2.4.

Recurrent block: x -> [branch y: linear -> GeLU] x [branch h: linear ->
causal conv(4) -> RG-LRU] -> elementwise product -> out projection.

RG-LRU recurrence (gates use *block-diagonal* projections, width 256 — the
paper's trick to keep the gate cost linear in width):

    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_x x_t)
    a_t = a^(c * r_t)  with  log a = -8 * softplus(Lambda),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train: associative scan over T (the transition tensor is [B, T, lru] — same
footprint as activations, no chunking needed).  Decode: O(1) update.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, _dense_init

_C = 8.0


class RGLRUCache(NamedTuple):
    conv: jnp.ndarray   # [B, d_conv - 1, lru]
    h: jnp.ndarray      # [B, lru] (f32)
    pos: jnp.ndarray    # [B]


def _dims(cfg):
    r = cfg.rglru
    lru = r.lru_width or cfg.d_model
    assert lru % r.block_width == 0
    return r, lru, lru // r.block_width


def init_rglru(key, cfg) -> dict:
    r, lru, nb = _dims(cfg)
    ks = jax.random.split(key, 6)
    bw = r.block_width
    return {
        "in_y": _dense_init(ks[0], (cfg.d_model, lru)),
        "in_x": _dense_init(ks[1], (cfg.d_model, lru)),
        "conv_w": _dense_init(ks[2], (r.d_conv, lru)) * 0.1,
        "conv_b": jnp.zeros((lru,), jnp.float32),
        "wa": _dense_init(ks[3], (nb, bw, bw), in_axis=1),   # block-diagonal
        "wx": _dense_init(ks[4], (nb, bw, bw), in_axis=1),
        "lam": jnp.log(jnp.expm1(   # softplus^-1 so a ~ U(0.9, 0.999)
            -jnp.log(jax.random.uniform(ks[5], (lru,), jnp.float32,
                                        0.9, 0.999)) / 8.0)),
        "out": _dense_init(ks[0], (lru, cfg.d_model)),
    }


def _block_proj(w, x, nb, bw):
    """Block-diagonal projection: x [..., lru] @ blockdiag(w) -> [..., lru]."""
    xs = x.reshape(x.shape[:-1] + (nb, bw))
    return jnp.einsum("...nb,nbc->...nc", xs, w).reshape(x.shape)


def _gates(p, xc, cfg):
    r, lru, nb = _dims(cfg)
    bw = r.block_width
    xf = xc.astype(jnp.float32)
    rt = jax.nn.sigmoid(_block_proj(p["wa"], xf, nb, bw))
    it = jax.nn.sigmoid(_block_proj(p["wx"], xf, nb, bw))
    log_a = -_C * jax.nn.softplus(p["lam"]) * rt          # [..., lru]
    a = jnp.exp(log_a)
    # multiplier sqrt(1 - a^2), stable via log: 0.5*log1p(-exp(2 log_a))
    mult = jnp.exp(0.5 * jnp.log1p(-jnp.exp(2.0 * log_a) + 1e-9))
    bx = mult * it * xf
    return a, bx


def _conv(p, x, cfg, prefix=None):
    r, lru, _ = _dims(cfg)
    B, T, _ = x.shape
    if prefix is None:
        prefix = jnp.zeros((B, r.d_conv - 1, lru), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(r.d_conv):
        out = out + xp[:, i:i + T, :] * p["conv_w"][i].astype(x.dtype)
    return out + p["conv_b"].astype(x.dtype)


def rglru_train(p, x, cfg) -> jnp.ndarray:
    """x [B, T, d_model] -> [B, T, d_model]."""
    c = COMPUTE_DTYPE
    y = jax.nn.gelu(x @ p["in_y"].astype(c), approximate=True)
    xb = x @ p["in_x"].astype(c)
    xc = _conv(p, xb, cfg)
    a, bx = _gates(p, xc, cfg)                             # [B, T, lru] f32

    def combine(u, v):
        return (u[0] * v[0], v[0] * u[1] + v[1])

    _, hs = jax.lax.associative_scan(combine, (a, bx), axis=1)
    out = (hs.astype(c) * y) @ p["out"].astype(c)
    return out


def init_rglru_cache(cfg, batch: int) -> RGLRUCache:
    r, lru, _ = _dims(cfg)
    return RGLRUCache(jnp.zeros((batch, r.d_conv - 1, lru), COMPUTE_DTYPE),
                      jnp.zeros((batch, lru), jnp.float32),
                      jnp.zeros((batch,), jnp.int32))


def rglru_decode(p, x, cfg, cache: RGLRUCache):
    """x [B, 1, d_model] -> (y [B, 1, d_model], cache)."""
    c = COMPUTE_DTYPE
    y = jax.nn.gelu(x[:, 0] @ p["in_y"].astype(c), approximate=True)
    xb = x[:, 0] @ p["in_x"].astype(c)                     # [B, lru]
    window = jnp.concatenate([cache.conv, xb[:, None]], axis=1)
    xc = jnp.einsum("btd,td->bd", window, p["conv_w"].astype(c)) \
        + p["conv_b"].astype(c)
    a, bx = _gates(p, xc, cfg)                             # [B, lru]
    h = a * cache.h + bx
    out = ((h.astype(c) * y) @ p["out"].astype(c))[:, None]
    return out, RGLRUCache(window[:, 1:], h, cache.pos + 1)
