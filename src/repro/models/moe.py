"""Mixture-of-Experts feed-forward (moonshot 64e/top-6+2sh, qwen2-moe
60e/top-4+4sh).

Sort-based capacity dispatch — the SAME static-shape ranking trick as the
join shuffle (core.distributed.bucketize): flatten (token, choice) pairs,
sort by expert, rank within expert runs, drop beyond the static capacity
C = ceil(T * top_k / E * capacity_factor), gather tokens into [E, C, d]
buckets, run the expert FFNs as one batched matmul, scatter-add back with the
router weights.  Capacity overflow is counted and returned (aux) — same
feedback surface as the join's bucket overflow.

Expert weights are sharded over the 'expert' logical axis (EP over the model
mesh axis); the bucket tensor carries a logical ('expert', 'capacity',
'embed') hint so GSPMD keeps dispatch local to the expert shard.  The
beyond-paper §Perf experiment swaps this GSPMD formulation for an explicit
shard_map all_to_all (the paper's "don't shuffle what won't join" insight on
token routing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, _dense_init
from repro.sharding.specs import shard_hint


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    d, ffe, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E)),
        "wg": _dense_init(ks[1], (E, d, ffe), in_axis=1),
        "wu": _dense_init(ks[2], (E, d, ffe), in_axis=1),
        "wd": _dense_init(ks[3], (E, ffe, d), in_axis=1),
    }
    if m.num_shared:
        ff_sh = m.num_shared * ffe
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {"wg": _dense_init(k1, (d, ff_sh)),
                       "wu": _dense_init(k2, (d, ff_sh)),
                       "wd": _dense_init(k3, (ff_sh, d))}
    return p


def moe_ffn(p, x, cfg):
    """x [B, T, d] -> (y [B, T, d], aux dict with load-balance loss)."""
    m = cfg.moe
    B, T, d = x.shape
    E, K = m.num_experts, m.top_k
    N = B * T
    xf = x.reshape(N, d)
    c = COMPUTE_DTYPE

    logits = (xf @ p["router"].astype(c)).astype(jnp.float32)   # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                      # [N, K]
    if m.router_softmax_after_topk:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                                 # [E]
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        jnp.ones((N * K,), jnp.float32)) / (N * K)
    aux_loss = E * jnp.sum(me * ce)

    # --- sort-based dispatch (static shapes) ---
    # decode-sized batches (N*K small) get loss-free capacity: a dropped
    # token in a 1-token decode step is a wrong answer, not a regularizer.
    if N * K <= 4096:
        C = N * K
    else:
        C = max(int(N * K * m.capacity_factor) // E, 1)
    e_flat = top_e.reshape(-1)                                   # [N*K]
    w_flat = top_p.reshape(-1).astype(c)
    t_flat = jnp.arange(N * K, dtype=jnp.int32) // K             # token ids
    order = jnp.argsort(e_flat)                                  # stable
    e_s, w_s, t_s = e_flat[order], w_flat[order], t_flat[order]
    pos = jnp.arange(N * K, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), e_s[1:] != e_s[:-1]])
    rank = pos - jax.lax.cummax(jnp.where(is_start, pos, 0))
    ok = rank < C
    slot = jnp.where(ok, e_s * C + rank, E * C)                  # drop -> E*C
    overflow = jnp.sum(~ok)

    tok_for_slot = jnp.full((E * C + 1,), N, jnp.int32).at[slot].set(
        t_s, mode="drop")[:-1]
    w_for_slot = jnp.zeros((E * C + 1,), c).at[slot].set(
        w_s, mode="drop")[:-1]

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])    # row N = 0
    xs = xpad[tok_for_slot].reshape(E, C, d)                     # [E, C, d]
    xs = shard_hint(xs, ("expert", None, None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["wg"].astype(c))) \
        * jnp.einsum("ecd,edf->ecf", xs, p["wu"].astype(c))
    ys = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(c))        # [E, C, d]
    ys = shard_hint(ys, ("expert", None, None))

    ys_flat = ys.reshape(E * C, d) * w_for_slot[:, None]
    y = jnp.zeros((N + 1, d), c).at[tok_for_slot].add(ys_flat)[:N]

    if m.num_shared:
        sp = p["shared"]
        y = y + (jax.nn.silu(xf @ sp["wg"].astype(c)) *
                 (xf @ sp["wu"].astype(c))) @ sp["wd"].astype(c)
    return y.reshape(B, T, d), {"moe_aux_loss": aux_loss,
                                "moe_overflow": overflow}


# ---------------------------------------------------------------------------
# Beyond-paper §Perf: explicit shard_map EP dispatch.
#
# Under GSPMD the bucket gather (xpad[tok_for_slot] against expert-sharded
# buckets) makes XLA all-gather the full token activations per MoE layer —
# measured at ~250 GB/device/step on qwen2-moe train_4k.  This variant is
# the paper's insight on token routing: tokens never move; each model-rank
# routes the (replicated) token shard to ITS OWN experts only and the sole
# collective is one psum of the partial outputs — the same replicate-and-
# mask pattern as the join's "don't shuffle what won't join".
#
# Experts are zero-padded to a multiple of the 'model' axis (qwen2-moe's 60
# -> 64) with router logits forced to -inf on the padding, so indivisible
# expert counts get EP instead of full replication.
# ---------------------------------------------------------------------------

def _pad_experts(w, E_pad: int):
    E = w.shape[0]
    if E == E_pad:
        return w
    pad = jnp.zeros((E_pad - E,) + w.shape[1:], w.dtype)
    return jnp.concatenate([w, pad], axis=0)


def moe_ffn_ep(p, x, cfg):
    """shard_map expert-parallel MoE over the 'model' mesh axis.

    Needs an active logical_rules binding with a 'model' axis; otherwise
    falls back to the GSPMD formulation."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.specs import current_binding

    bind = current_binding()
    if bind is None or "model" not in bind[0].shape:
        return moe_ffn(p, x, cfg)
    mesh, _ = bind
    tp = mesh.shape["model"]
    m = cfg.moe
    B, T, d = x.shape
    E = m.num_experts
    E_pad = -(-E // tp) * tp
    E_l = E_pad // tp
    K = m.top_k
    ffe = m.d_ff_expert
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    N_l = max(B * T // n_dp, 1)
    C = max(int(N_l * K * m.capacity_factor) // E, K)
    c = COMPUTE_DTYPE

    # Match the body to the weights' STORAGE layout so no weight bytes move
    # at dispatch time (iteration 2 of the qwen2-moe hillclimb: re-padding
    # + resharding stored ffe-sharded weights every step cost 9 all-to-alls):
    #   E % tp == 0 -> block-EP body (each rank owns E/tp whole experts)
    #   else        -> ffe-TP body (each rank owns every expert's ffe/tp
    #                  slice and computes ALL dispatched slots on it)
    # Identical FLOPs and the identical single psum either way.
    if E % tp != 0:
        assert ffe % tp == 0, f"{cfg.name}: neither E={E} nor ffe={ffe} " \
            f"divides tp={tp}"
        return _moe_ffn_ffe_tp(p, x, cfg, mesh, dp_axes, C)

    wg = _pad_experts(p["wg"], E_pad)
    wu = _pad_experts(p["wu"], E_pad)
    wd = _pad_experts(p["wd"], E_pad)

    def body(xb, router, wg_l, wu_l, wd_l):
        Bl, Tl, _ = xb.shape
        Nl = Bl * Tl
        xf = xb.reshape(Nl, d)
        me = jax.lax.axis_index("model")
        logits = (xf @ router.astype(c)).astype(jnp.float32)    # [Nl, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        if m.router_softmax_after_topk:
            top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        me_base = me * E_l
        e_flat = top_e.reshape(-1)
        w_flat = top_p.reshape(-1).astype(c)
        t_flat = jnp.arange(Nl * K, dtype=jnp.int32) // K
        mine = (e_flat >= me_base) & (e_flat < me_base + E_l)
        e_local = jnp.where(mine, e_flat - me_base, E_l)        # drop -> E_l
        order = jnp.argsort(e_local)
        e_s, w_s, t_s = e_local[order], w_flat[order], t_flat[order]
        pos = jnp.arange(Nl * K, dtype=jnp.int32)
        is_start = jnp.concatenate([jnp.ones((1,), bool),
                                    e_s[1:] != e_s[:-1]])
        rank = pos - jax.lax.cummax(jnp.where(is_start, pos, 0))
        ok = (e_s < E_l) & (rank < C)
        slot = jnp.where(ok, e_s * C + rank, E_l * C)
        overflow = jax.lax.psum(
            jnp.sum((e_s < E_l) & (rank >= C)), "model")
        tok_for_slot = jnp.full((E_l * C + 1,), Nl, jnp.int32).at[slot].set(
            t_s, mode="drop")[:-1]
        w_for_slot = jnp.zeros((E_l * C + 1,), c).at[slot].set(
            w_s, mode="drop")[:-1]
        xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])
        xs = xpad[tok_for_slot].reshape(E_l, C, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wg_l.astype(c))) \
            * jnp.einsum("ecd,edf->ecf", xs, wu_l.astype(c))
        ys = jnp.einsum("ecf,efd->ecd", h, wd_l.astype(c))
        ys_flat = ys.reshape(E_l * C, d) * w_for_slot[:, None]
        y = jnp.zeros((Nl + 1, d), c).at[tok_for_slot].add(ys_flat)[:Nl]
        y = jax.lax.psum(y, "model")                            # the ONLY
        # load-balance aux (identical on every model rank; pmean over DP
        # to match the GSPMD global-batch statistics)
        me_p = jnp.mean(probs, axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
            jnp.ones((Nl * K,), jnp.float32)) / (Nl * K)
        aux = E * jnp.sum(me_p * ce)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
            overflow = jax.lax.psum(overflow, dp_axes)
        return (y.reshape(Bl, Tl, d), aux[None],
                overflow[None].astype(jnp.float32))

    # NB: the router stays unpadded — top_k only ever selects real experts,
    # so zero-padded expert slots simply never receive tokens.
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes or None, None, None), P(), P("model"),
                  P("model"), P("model")),
        out_specs=(P(dp_axes or None, None, None), P(), P()),
        check_rep=False)
    y, aux, ovf = fn(x, p["router"], wg, wu, wd)
    y = y.astype(c)
    if m.num_shared:
        sp = p["shared"]
        xf = x.reshape(B * T, d)
        y = y + ((jax.nn.silu(xf @ sp["wg"].astype(c)) *
                  (xf @ sp["wu"].astype(c))) @ sp["wd"].astype(c)
                 ).reshape(B, T, d)
    return y, {"moe_aux_loss": aux[0], "moe_overflow": ovf[0]}


def _moe_ffn_ffe_tp(p, x, cfg, mesh, dp_axes, C):
    """ffe-TP dispatch body (expert count indivisible by the model axis).

    Every model-rank routes the full (replicated) token shard, buckets for
    ALL experts, and runs the expert matmuls over its ffe/tp weight slice —
    partial outputs psum over 'model'.  Weight layout == storage layout, so
    the only collective is the psum."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, T, d = x.shape
    E, K = m.num_experts, m.top_k
    c = COMPUTE_DTYPE

    def body(xb, router, wg_l, wu_l, wd_l):
        Bl, Tl, _ = xb.shape
        Nl = Bl * Tl
        xf = xb.reshape(Nl, d)
        logits = (xf @ router.astype(c)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        if m.router_softmax_after_topk:
            top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        e_flat = top_e.reshape(-1)
        w_flat = top_p.reshape(-1).astype(c)
        t_flat = jnp.arange(Nl * K, dtype=jnp.int32) // K
        order = jnp.argsort(e_flat)
        e_s, w_s, t_s = e_flat[order], w_flat[order], t_flat[order]
        pos = jnp.arange(Nl * K, dtype=jnp.int32)
        is_start = jnp.concatenate([jnp.ones((1,), bool),
                                    e_s[1:] != e_s[:-1]])
        rank = pos - jax.lax.cummax(jnp.where(is_start, pos, 0))
        ok = rank < C
        slot = jnp.where(ok, e_s * C + rank, E * C)
        overflow = jnp.sum(~ok).astype(jnp.float32)
        tok_for_slot = jnp.full((E * C + 1,), Nl, jnp.int32).at[slot].set(
            t_s, mode="drop")[:-1]
        w_for_slot = jnp.zeros((E * C + 1,), c).at[slot].set(
            w_s, mode="drop")[:-1]
        xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])
        xs = xpad[tok_for_slot].reshape(E, C, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wg_l.astype(c))) \
            * jnp.einsum("ecd,edf->ecf", xs, wu_l.astype(c))
        ys = jnp.einsum("ecf,efd->ecd", h, wd_l.astype(c))
        ys_flat = ys.reshape(E * C, d) * w_for_slot[:, None]
        y = jnp.zeros((Nl + 1, d), c).at[tok_for_slot].add(ys_flat)[:Nl]
        y = jax.lax.psum(y, "model")      # partial over the sharded ffe
        me_p = jnp.mean(probs, axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
            jnp.ones((Nl * K,), jnp.float32)) / (Nl * K)
        aux = E * jnp.sum(me_p * ce)
        if dp_axes:  # match the GSPMD global-batch statistics
            aux = jax.lax.pmean(aux, dp_axes)
            overflow = jax.lax.psum(overflow, dp_axes)
        return (y.reshape(Bl, Tl, d), aux[None], overflow[None])

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes or None, None, None), P(),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None)),
        out_specs=(P(dp_axes or None, None, None), P(), P()),
        check_rep=False)
    y, aux, ovf = fn(x, p["router"], p["wg"], p["wu"], p["wd"])
    y = y.astype(c)
    if m.num_shared:
        sp = p["shared"]
        xf = x.reshape(B * T, d)
        y = y + ((jax.nn.silu(xf @ sp["wg"].astype(c)) *
                  (xf @ sp["wu"].astype(c))) @ sp["wd"].astype(c)
                 ).reshape(B, T, d)
    return y, {"moe_aux_loss": aux[0], "moe_overflow": ovf[0]}
