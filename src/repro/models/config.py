"""Architecture configurations — the 10 assigned archs, verbatim from the
assignment table (sources noted per entry; see DESIGN.md §5 for adaptation
notes, e.g. stub modality frontends for [audio]/[vlm]).

The trunk consumes a *layer pattern*: a cycle of mixer kinds applied
round-robin over the depth, scanned as homogeneous blocks (one scan step =
one full pattern period), which keeps HLO size O(pattern) instead of
O(depth) — the 64-layer dry-runs depend on this.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0           # shared experts (always-on), same d_ff
    capacity_factor: float = 1.25
    router_softmax_after_topk: bool = True  # normalize the top-k weights


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2               # d_inner = expand * d_model
    dt_rank: Optional[int] = None  # default ceil(d_model / 16)


@dataclass(frozen=True)
class RGLRUCfg:
    lru_width: Optional[int] = None  # default d_model
    d_conv: int = 4
    block_width: int = 256           # block-diagonal gate projections


@dataclass(frozen=True)
class EncoderCfg:
    n_layers: int
    n_frames: int = 1500          # whisper encoder positions (30 s audio)
    d_input: int = 80             # mel bins (stub frontend projects these)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    mixer_pattern: Tuple[str, ...] = ("attn",)  # cycle: attn|local|mamba|rglru
    ff_kind: str = "swiglu"                 # swiglu | geglu | gelu | moe
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    rglru: Optional[RGLRUCfg] = None
    window: int = 4096                      # local-attention window
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    scale_embed: bool = False               # gemma-style sqrt(d) embed scale
    norm_eps: float = 1e-6
    post_norms: bool = False                # gemma2 post-sublayer norms
    encoder: Optional[EncoderCfg] = None    # whisper
    num_img_tokens: int = 0                 # phi-3-vision stub frontend
    remat: str = "block"                    # none | block (see trunk)
    moe_impl: str = "gspmd"                 # gspmd | ep (shard_map dispatch)
    attn_chunk: Optional[int] = None        # flash-style KV-chunked softmax
                                            # for train/prefill (layers.py)
    rules: Optional[Tuple] = None           # per-arch logical-rule overrides
                                            # as ((logical, mesh_axis), ...)
                                            # — tuple so the config stays
                                            # hashable (e.g. seq->model when
                                            # heads don't divide the axis)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def sub_quadratic(self) -> bool:
        """True when decode memory/compute is O(1)-ish in context length
        (no global-attention mixer anywhere in the pattern)."""
        return all(m in ("mamba", "rglru", "local")
                   for m in self.mixer_pattern)

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small: dict = dict(
            n_layers=len(self.mixer_pattern) * 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // self.n_heads),
            head_dim=16,
            d_ff=128,
            vocab=256,
            window=32,
            num_img_tokens=4 if self.num_img_tokens else 0,
        )
        if self.moe:
            small["moe"] = MoECfg(num_experts=8, top_k=2, d_ff_expert=32,
                                  num_shared=self.moe.num_shared and 1)
        if self.ssm:
            small["ssm"] = SSMCfg(d_state=4, d_conv=4, expand=2, dt_rank=8)
        if self.rglru:
            small["rglru"] = RGLRUCfg(lru_width=64, block_width=16)
        if self.encoder:
            small["encoder"] = EncoderCfg(n_layers=2, n_frames=16, d_input=8)
        small.update(overrides)
        return dataclasses.replace(self, **small)


ARCHS: dict[str, ArchConfig] = {}


def _register(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    return ARCHS[name]


# The 10 assigned architecture instances live in ``repro/configs/<id>.py``
# (one file per arch, per the deliverable layout); importing ``repro.configs``
# registers them here.
