"""The scanned-block decoder trunk shared by 9 of the 10 archs.

A *block* is one period of ``cfg.mixer_pattern`` (e.g. gemma2's
(local, attn), recurrentgemma's (rglru, rglru, local)); the trunk is
``n_layers / period`` identical blocks executed with ``lax.scan`` over
stacked parameters — HLO size stays O(period), which is what lets the
64-layer falcon-mamba dry-run lower in seconds, and remat is applied at block
granularity (``cfg.remat``).

Decode carries a per-pattern-position cache pytree stacked over blocks;
the scan threads (params, cache) pairs and emits the updated cache.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.sharding.specs import shard_hint


def _norm_init(cfg):
    return L.layernorm_init(cfg.d_model) if cfg.family == "audio" \
        else L.rmsnorm_init(cfg.d_model)


def _norm(p, x, cfg):
    return L.layernorm(p, x, cfg.norm_eps) if cfg.family == "audio" \
        else L.rmsnorm(p, x, cfg.norm_eps)


def n_blocks(cfg) -> tuple:
    """(full blocks, tail mixers): depth = full * period + tail.

    A non-zero tail (e.g. recurrentgemma's 26 = 8 x 3 + 2) becomes one extra
    unscanned partial block using pattern[:tail]."""
    period = len(cfg.mixer_pattern)
    return cfg.n_layers // period, cfg.n_layers % period


def init_block(key, cfg, pattern=None) -> dict:
    pattern = pattern or cfg.mixer_pattern
    p = {}
    keys = jax.random.split(key, 2 * len(pattern))
    for i, kind in enumerate(pattern):
        p[f"pre_{i}"] = _norm_init(cfg)
        if kind in ("attn", "local"):
            p[f"mix_{i}"] = L.init_attention(keys[2 * i], cfg)
        elif kind == "mamba":
            p[f"mix_{i}"] = S.init_mamba(keys[2 * i], cfg)
        elif kind == "rglru":
            p[f"mix_{i}"] = R.init_rglru(keys[2 * i], cfg)
        else:
            raise ValueError(kind)
        if cfg.post_norms:
            p[f"postmix_{i}"] = _norm_init(cfg)
        if cfg.ff_kind != "none":
            p[f"ffpre_{i}"] = _norm_init(cfg)
            if cfg.ff_kind == "moe":
                p[f"ff_{i}"] = M.init_moe(keys[2 * i + 1], cfg)
            else:
                p[f"ff_{i}"] = L.init_mlp(keys[2 * i + 1], cfg)
            if cfg.post_norms:
                p[f"postff_{i}"] = _norm_init(cfg)
    return p


def init_trunk(key, cfg) -> dict:
    nb, tail = n_blocks(cfg)
    keys = jax.random.split(key, nb + 1)
    p = {"blocks": jax.vmap(lambda k: init_block(k, cfg))(keys[:nb])}
    if tail:
        p["tail"] = init_block(keys[-1], cfg, cfg.mixer_pattern[:tail])
    return p


def _apply_ff(bp, i, x, cfg, aux):
    h = _norm(bp[f"ffpre_{i}"], x, cfg)
    if cfg.ff_kind == "moe":
        moe_fn = M.moe_ffn_ep if cfg.moe_impl == "ep" else M.moe_ffn
        ff, a = moe_fn(bp[f"ff_{i}"], h, cfg)
        aux = {k: aux.get(k, 0.0) + v for k, v in a.items()}
    else:
        ff = L.mlp(bp[f"ff_{i}"], h, cfg)
    if cfg.post_norms:
        ff = _norm(bp[f"postff_{i}"], ff, cfg)
    return x + ff, aux


def block_train(bp, x, cfg, positions, pattern=None) -> tuple:
    aux: dict = {}
    pattern = pattern or cfg.mixer_pattern
    for i, kind in enumerate(pattern):
        h = _norm(bp[f"pre_{i}"], x, cfg)
        h = shard_hint(h, ("batch", "seq", "embed"))
        if kind == "attn":
            mx = L.attention_train(bp[f"mix_{i}"], h, cfg, kind="causal",
                                   positions=positions)
        elif kind == "local":
            mx = L.attention_train(bp[f"mix_{i}"], h, cfg, kind="local",
                                   positions=positions)
        elif kind == "mamba":
            mx = S.mamba_train(bp[f"mix_{i}"], h, cfg)
        else:
            mx = R.rglru_train(bp[f"mix_{i}"], h, cfg)
        if cfg.post_norms:
            mx = _norm(bp[f"postmix_{i}"], mx, cfg)
        x = x + mx
        if cfg.ff_kind != "none":
            x, aux = _apply_ff(bp, i, x, cfg, aux)
    return x, aux


def trunk_train(tp, x, cfg, positions) -> tuple:
    """x [B, T, d] -> (x, aux).  Scan over stacked blocks with block remat."""
    fn = block_train
    if cfg.remat == "block":
        fn = jax.checkpoint(block_train, static_argnums=(2,))

    aux0 = {"moe_aux_loss": jnp.zeros((), jnp.float32),
            "moe_overflow": jnp.zeros((), jnp.float32)} \
        if cfg.ff_kind == "moe" else {}

    def step(carry, bp):
        x, aux = carry
        x, a = fn(bp, x, cfg, positions)
        aux = {k: aux[k] + a.get(k, 0) for k in aux}
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(step, (x, aux0), tp["blocks"])
    if "tail" in tp:
        _, tail_len = n_blocks(cfg)
        x, a = block_train(tp["tail"], x, cfg, positions,
                           cfg.mixer_pattern[:tail_len])
        aux = {k: aux[k] + a.get(k, 0) for k in aux}
    return x, aux


# --- decode -------------------------------------------------------------------

def init_block_cache(cfg, batch: int, max_seq: int, pattern=None) -> dict:
    cache = {}
    pattern = pattern or cfg.mixer_pattern
    for i, kind in enumerate(pattern):
        if kind in ("attn", "local"):
            cache[f"c_{i}"] = L.init_kv_cache(cfg, batch, max_seq, kind)
        elif kind == "mamba":
            cache[f"c_{i}"] = S.init_mamba_cache(cfg, batch)
        else:
            cache[f"c_{i}"] = R.init_rglru_cache(cfg, batch)
    return cache


def init_trunk_cache(cfg, batch: int, max_seq: int) -> dict:
    """Cache pytree: scanned part has a leading n_blocks axis per leaf."""
    one = init_block_cache(cfg, batch, max_seq)
    nb, tail = n_blocks(cfg)
    cache = {"blocks": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (nb,) + a.shape).copy(), one)}
    if tail:
        cache["tail"] = init_block_cache(cfg, batch, max_seq,
                                         cfg.mixer_pattern[:tail])
    return cache


def block_decode(bp, x, cfg, cache: dict, pattern=None) -> tuple:
    new_cache = {}
    pattern = pattern or cfg.mixer_pattern
    for i, kind in enumerate(pattern):
        h = _norm(bp[f"pre_{i}"], x, cfg)
        if kind in ("attn", "local"):
            mx, nc = L.attention_decode(bp[f"mix_{i}"], h, cfg,
                                        cache[f"c_{i}"], kind=kind)
        elif kind == "mamba":
            mx, nc = S.mamba_decode(bp[f"mix_{i}"], h, cfg, cache[f"c_{i}"])
        else:
            mx, nc = R.rglru_decode(bp[f"mix_{i}"], h, cfg, cache[f"c_{i}"])
        new_cache[f"c_{i}"] = nc
        if cfg.post_norms:
            mx = _norm(bp[f"postmix_{i}"], mx, cfg)
        x = x + mx
        if cfg.ff_kind != "none":
            x, _ = _apply_ff(bp, i, x, cfg, {})
    return x, new_cache


def trunk_decode(tp, x, cfg, cache) -> tuple:
    """One-token step through all blocks; returns (x, new_cache)."""

    def step(x, inp):
        bp, cs = inp
        x, ncs = block_decode(bp, x, cfg, cs)
        return x, ncs

    x, new_blocks = jax.lax.scan(step, x, (tp["blocks"], cache["blocks"]))
    new_cache = {"blocks": new_blocks}
    if "tail" in tp:
        _, tail_len = n_blocks(cfg)
        x, nt = block_decode(tp["tail"], x, cfg, cache["tail"],
                             cfg.mixer_pattern[:tail_len])
        new_cache["tail"] = nt
    return x, new_cache
