"""Top-level Model API: init / loss / prefill / decode for every arch.

All functions are pure and eval_shape-able — the multi-pod dry-run builds
parameter and cache ShapeDtypeStructs through ``jax.eval_shape(model.init)``
and never allocates full-scale tensors.

Loss is next-token cross-entropy in f32 with z-loss, computed on
vocab-sharded logits (logical ('batch','seq','vocab')) so the 256 K-vocab
archs never materialize replicated logits; MoE aux loss folds in when present
(weights per the usual production recipes).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import trunk as TR
from repro.models.config import ArchConfig
from repro.sharding.specs import shard_hint

Z_LOSS_WEIGHT = 1e-4
MOE_AUX_WEIGHT = 1e-2
CLIP_DIM = 1024  # phi-3-vision stub frontend: projected CLIP patch features


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # --- parameters ---------------------------------------------------------

    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p: dict = {"embed": jax.random.normal(
            ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02}
        if cfg.is_encdec:
            p["encdec"] = ED.init_encdec(ks[1], cfg)
        else:
            p["trunk"] = TR.init_trunk(ks[1], cfg)
        p["final_norm"] = (L.layernorm_init(cfg.d_model)
                           if cfg.family == "audio"
                           else L.rmsnorm_init(cfg.d_model))
        if not cfg.tie_embeddings:
            p["head"] = L._dense_init(ks[2], (cfg.d_model, cfg.vocab))
        if cfg.num_img_tokens:
            p["img_proj"] = L._dense_init(ks[3], (CLIP_DIM, cfg.d_model))
        return p

    # --- shared pieces --------------------------------------------------------

    def _embed(self, p, tokens):
        cfg = self.cfg
        x = p["embed"][tokens].astype(L.COMPUTE_DTYPE)
        if cfg.scale_embed:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, L.COMPUTE_DTYPE))
        return x

    def _final_norm(self, p, x):
        cfg = self.cfg
        return (L.layernorm(p["final_norm"], x, cfg.norm_eps)
                if cfg.family == "audio"
                else L.rmsnorm(p["final_norm"], x, cfg.norm_eps))

    def _logits(self, p, x):
        cfg = self.cfg
        head = (p["embed"].T if cfg.tie_embeddings else p["head"])
        logits = x @ head.astype(L.COMPUTE_DTYPE)
        logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        return shard_hint(logits, ("batch", "seq", "vocab"))

    # --- forward (train / prefill) -------------------------------------------

    def forward(self, p, batch: dict) -> tuple:
        """-> (logits over token positions [B, T, V], aux dict)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = self._embed(p, tokens)
        aux: dict = {}
        if cfg.is_encdec:
            enc_out = ED.encode(p["encdec"], batch["frames"], cfg)
            pos = jnp.arange(T, dtype=jnp.int32)[None, :]
            x = x + L.sinusoidal_embedding(pos[0], cfg.d_model
                                           ).astype(x.dtype)[None]
            x = ED.decode_train(p["encdec"], x, enc_out, cfg, pos)
        else:
            P_img = 0
            if cfg.num_img_tokens:
                img = batch["img_embeds"].astype(L.COMPUTE_DTYPE)
                x = jnp.concatenate(
                    [img @ p["img_proj"].astype(L.COMPUTE_DTYPE), x], axis=1)
                P_img = cfg.num_img_tokens
            pos = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
            pos = jnp.broadcast_to(pos, (B, x.shape[1]))
            x, aux = TR.trunk_train(p["trunk"], x, cfg, pos)
            if P_img:
                x = x[:, P_img:]
        x = self._final_norm(p, x)
        return self._logits(p, x), aux

    def loss(self, p, batch: dict) -> tuple:
        """-> (scalar loss, metrics dict)."""
        logits, aux = self.forward(p, batch)
        targets = batch["targets"]
        logz = jax.nn.logsumexp(logits, axis=-1)              # [B, T] f32
        gold = jnp.take_along_axis(logits, targets[..., None],
                                   axis=-1)[..., 0]
        nll = jnp.mean(logz - gold)
        zloss = Z_LOSS_WEIGHT * jnp.mean(logz ** 2)
        total = nll + zloss
        metrics = {"nll": nll, "z_loss": zloss}
        if "moe_aux_loss" in aux:
            total = total + MOE_AUX_WEIGHT * aux["moe_aux_loss"]
            metrics["moe_aux_loss"] = aux["moe_aux_loss"]
            metrics["moe_overflow"] = aux["moe_overflow"]
        metrics["loss"] = total
        return total, metrics

    # --- serving --------------------------------------------------------------

    def init_cache(self, p: Optional[dict], batch: int, max_seq: int,
                   frames: Optional[jnp.ndarray] = None):
        """Decode cache.  Whisper needs (params, frames) for cross-KV."""
        cfg = self.cfg
        if cfg.is_encdec:
            assert p is not None and frames is not None
            enc_out = ED.encode(p["encdec"], frames, cfg)
            return ED.init_encdec_cache(p["encdec"], enc_out, cfg, batch,
                                        max_seq)
        return TR.init_trunk_cache(cfg, batch, max_seq + cfg.num_img_tokens)

    def cache_shape(self, batch: int, max_seq: int):
        """ShapeDtypeStructs of the cache (dry-run input specs)."""
        cfg = self.cfg
        if cfg.is_encdec:
            enc = cfg.encoder
            return jax.eval_shape(
                lambda key: self.init_cache(
                    self.init(key), batch, max_seq,
                    jnp.zeros((batch, enc.n_frames, enc.d_input),
                              L.COMPUTE_DTYPE)),
                jax.random.key(0))
        return jax.eval_shape(
            lambda: self.init_cache(None, batch, max_seq))

    def decode_step(self, p, tokens, cache) -> tuple:
        """tokens int32 [B] -> (logits f32 [B, V], new cache)."""
        cfg = self.cfg
        x = self._embed(p, tokens[:, None])                   # [B, 1, d]
        if cfg.is_encdec:
            pos = cache.self_kv.pos[0]                        # [B]
            x = x + L.sinusoidal_embedding(pos[:, None],
                                           cfg.d_model).astype(x.dtype)
            x, cache = ED.decode_step(p["encdec"], x, cfg, cache)
        else:
            x, cache = TR.trunk_decode(p["trunk"], x, cfg, cache)
        x = self._final_norm(p, x)
        logits = self._logits(p, x)[:, 0]
        return logits, cache
