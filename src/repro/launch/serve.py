"""Serving driver: reduced model + slot-based batched decode loop.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import Model
from repro.runtime.serve import Request, Server


def run(arch: str, *, requests: int = 8, max_new: int = 16,
        slots: int = 4, max_seq: int = 256, temperature: float = 0.8,
        seed: int = 0) -> dict:
    cfg = ARCHS[arch].reduced(vocab=512)
    if cfg.is_encdec:
        raise SystemExit("serve driver targets decoder LMs; whisper decode "
                         "is exercised in tests/test_models.py")
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    server = Server(model, params, batch_slots=slots, max_seq=max_seq,
                    seed=seed)
    rng = np.random.default_rng(seed)
    reqs = [Request(prompt=list(rng.integers(2, cfg.vocab, size=8)),
                    max_new=max_new, temperature=temperature)
            for _ in range(requests)]
    for r in reqs:
        server.submit(r)
    t0 = time.time()
    server.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"[serve] {requests} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    for i, r in enumerate(reqs[:3]):
        print(f"  req{i}: {r.out[:12]}{'...' if len(r.out) > 12 else ''}")
    return {"tokens": toks, "seconds": dt}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    run(args.arch, requests=args.requests, max_new=args.max_new,
        slots=args.slots)


if __name__ == "__main__":
    main()
