"""Training driver: config -> mesh -> data pipeline -> guarded steps ->
checkpoints, with elastic restore at start.

Runs the full production codepath at whatever scale the host offers: the
same train_step that lowers on the 512-chip dry-run runs here on 1-8 CPU
devices with a reduced config (--reduced), a few hundred steps in minutes.
``examples/train_lm.py`` drives this for the ~100M-param end-to-end run.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 50
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS
from repro.data.pipeline import lm_batch
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.models.model import CLIP_DIM
from repro.runtime.checkpoint import save_checkpoint
from repro.runtime.fault import (StragglerMonitor, elastic_restore,
                                 guarded_step)
from repro.runtime.train import make_train_step, train_state_init
from repro.sharding.specs import logical_rules


def make_batch_fn(cfg, batch: int, seq: int, seed: int = 0):
    """Deterministic per-(step, shard) batch generator (fault-tolerant)."""
    import jax.numpy as jnp

    def fn(step: int) -> dict:
        b = lm_batch(step, 0, batch=batch, seq=seq, vocab=cfg.vocab,
                     seed=seed, structured=True)
        if cfg.num_img_tokens:
            b["img_embeds"] = jnp.zeros((batch, cfg.num_img_tokens,
                                         CLIP_DIM), jnp.float32)
        if cfg.is_encdec:
            e = cfg.encoder
            b["frames"] = jnp.zeros((batch, e.n_frames, e.d_input),
                                    jnp.float32)
        return b

    return fn


def run(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 128,
        reduced: bool = True, lr: float = 3e-4, microbatches: int = 1,
        ckpt_dir: str | None = None, ckpt_every: int = 50,
        log_every: int = 10, dp: int = 1, tp: int = 1,
        seed: int = 0) -> dict:
    cfg = ARCHS[arch]
    if reduced:
        cfg = cfg.reduced(vocab=512, d_model=128, d_ff=256,
                          n_layers=len(cfg.mixer_pattern) * 2)
    model = Model(cfg)
    mesh = make_host_mesh(dp, tp)
    step_fn = make_train_step(model, lr=lr, total_steps=steps,
                              warmup=max(steps // 20, 5),
                              microbatches=microbatches)
    batch_fn = make_batch_fn(cfg, batch, seq, seed)
    monitor = StragglerMonitor()

    with logical_rules(mesh):
        jitted = jax.jit(step_fn, donate_argnums=(0,))
        state = train_state_init(model, jax.random.key(seed))
        start = 0
        if ckpt_dir:
            state, start, _ = elastic_restore(ckpt_dir, state)
            if start:
                print(f"[train] resumed from step {start}")
        metrics = {}
        losses = []
        for step in range(start, steps):
            t0 = time.time()
            state, metrics = guarded_step(jitted, state, batch_fn(step))
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            monitor.record("host0", dt)
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step:5d} loss={losses[-1]:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={dt*1e3:.0f}ms")
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                save_checkpoint(ckpt_dir, step + 1, state, sync=False)
        if ckpt_dir:
            save_checkpoint(ckpt_dir, steps, state, sync=True)
    return {"final_loss": losses[-1], "first_loss": losses[0],
            "losses": losses, "metrics": {k: float(v)
                                          for k, v in metrics.items()}}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    args = ap.parse_args()
    out = run(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
              reduced=args.reduced, lr=args.lr,
              microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
              ckpt_every=args.ckpt_every, dp=args.dp, tp=args.tp)
    print(f"[train] done: loss {out['first_loss']:.4f} -> "
          f"{out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
