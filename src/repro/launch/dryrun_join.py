import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Dry-run + roofline of the PAPER'S OWN OPERATOR at production scale.

Lowers the distributed ApproxJoin pipeline (filter -> shuffle -> sample ->
estimate) over the full 256/512-chip mesh with ShapeDtypeStruct relations
(no allocation), and reports the same three roofline terms as the LM cells
plus the collective census — the compiled-artifact validation of the
paper's Eq. 24 communication claims at cluster scale.

  PYTHONPATH=src python -m repro.launch.dryrun_join [--multi-pod]
      [--log2-rows 26] [--mode exact|sample] [--no-filter]
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import bloom
from repro.core.distributed import make_distributed_join, planned_bucket_cap
from repro.core.relation import Relation
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh


def run_join_cell(mesh, *, log2_rows: int, mode: str, filter_stage: bool,
                  sample_fraction: float = 0.1, fp_rate: float = 0.01,
                  overlap_hint: float = 1.0, verbose: bool = True) -> dict:
    """overlap_hint < 1 enables filter-informed capacity planning (§Perf
    paper-side iteration): the driver sizes the shuffle buckets from the
    Bloom-estimated live fraction (2x slack + small-bucket concentration
    guard, ``core.distributed.planned_bucket_cap`` — the same planner the
    JoinServer's psum serve mode uses) instead of the full input — on a
    static-shape dataflow this is HOW the filter's shuffle saving reaches
    the wire; overflow feeds the recompile-bigger elastic loop."""
    axes = tuple(mesh.shape)                   # the join uses every axis
    chips = int(np.prod(list(mesh.shape.values())))
    n_global = 1 << log2_rows
    local = n_global // chips
    bucket_cap = planned_bucket_cap(local, chips, overlap_hint, floor=16)
    max_strata = min(chips * bucket_cap, 1 << 16)
    num_blocks = bloom.num_blocks_for(local, fp_rate)  # per-shard filter

    # merge='psum' keeps the paper's partial-aggregate merge (the Eq. 24
    # collective census this dry-run validates); the default gather merge is
    # for bit-parity with the single-device pipeline at serving scale.
    run = make_distributed_join(
        mesh, n_rels=2, join_axes=axes, mode=mode,
        filter_stage=filter_stage, sample_fraction=sample_fraction,
        bucket_cap=bucket_cap, max_strata=max_strata, b_max=512,
        num_blocks=num_blocks, merge="psum")

    sh = NamedSharding(mesh, P(axes))
    rel = Relation(
        jax.ShapeDtypeStruct((n_global,), jnp.uint32, sharding=sh),
        jax.ShapeDtypeStruct((n_global,), jnp.float32, sharding=sh),
        jax.ShapeDtypeStruct((n_global,), jnp.bool_, sharding=sh))
    lowered = run.lower([rel, rel], 0.0)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    roof = RL.analyze(compiled, hlo, chips=chips, model_flops=0.0,
                      default_group=chips)
    mem = compiled.memory_analysis()
    rec = {
        "operator": f"approxjoin[{mode}"
                    f"{'' if filter_stage else ',nofilter'}]",
        "mesh": dict(mesh.shape), "chips": chips,
        "rows_per_relation": n_global,
        "bloom_blocks_per_shard": num_blocks,
        "compute_s": roof.compute_s, "memory_s": roof.memory_s,
        "collective_s": roof.collective_s, "dominant": roof.dominant,
        "coll_bytes_per_device": roof.coll_bytes,
        "collective_ops": roof.collectives,
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    if verbose:
        print(f"  {rec['operator']:28s} chips={chips} "
              f"terms=({roof.compute_s:.2e},{roof.memory_s:.2e},"
              f"{roof.collective_s:.2e})s dominant={roof.dominant} "
              f"colls={roof.collectives}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--log2-rows", type=int, default=26)
    ap.add_argument("--out", default="experiments/dryrun_join.json")
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"== join dry-run on mesh {dict(mesh.shape)} ==")
    records = []
    for mode, filt in (("exact", True), ("exact", False), ("sample", True)):
        records.append(run_join_cell(mesh, log2_rows=args.log2_rows,
                                     mode=mode, filter_stage=filt))
    # §Perf paper-side iteration: filter-informed capacity planning —
    # buckets sized from the Bloom-estimated 1% overlap instead of |R|
    rec = run_join_cell(mesh, log2_rows=args.log2_rows, mode="sample",
                        filter_stage=True, overlap_hint=0.01)
    rec["operator"] = "approxjoin[sample,cap-planned]"
    records.append(rec)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(records, fh, indent=1)
    # the paper's headline, at the compiled-artifact level: with static
    # shapes the saving only reaches the wire once capacities are planned
    # from the filter's overlap estimate
    planned, unplanned = records[3], records[2]
    ratio = unplanned["coll_bytes_per_device"] / max(
        planned["coll_bytes_per_device"], 1)
    print(f"collective bytes, naive-capacity / filter-planned-capacity = "
          f"{ratio:.1f}x at 1% overlap")


if __name__ == "__main__":
    main()
