"""JoinServer driver: multi-tenant batched ApproxJoin serving.

Builds synthetic tenant datasets in several capacity shape classes,
registers them as named handles, submits an interleaved query stream
(error-budget, latency-budget, and exact tenants), and prints throughput
plus the server's executable-cache / batching / filter-cache diagnostics.

Usage:
  PYTHONPATH=src python -m repro.launch.join_serve --tenants 4 \
      --queries-per-tenant 8 --slots 4

  # distributed: one batched step spans all mesh devices
  PYTHONPATH=src python -m repro.launch.join_serve --mesh 8

  # always-on async tier: event-loop replicas, continuous batching,
  # tenant sharding + work stealing behind one front door
  PYTHONPATH=src python -m repro.launch.join_serve --async --replicas 2

``--mesh N`` re-execs under ``--xla_force_host_platform_device_count`` when
the process has fewer than N devices (the flag must be set before jax
initializes), then serves through the shard_map pipeline.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from repro.core.budget import QueryBudget
from repro.core.cost import CostModel
from repro.data.synthetic import overlapping_relations
from repro.runtime.async_serve import AsyncJoinFrontDoor
from repro.runtime.join_serve import JoinRequest, JoinServer


def run(*, tenants: int = 4, queries_per_tenant: int = 8, slots: int = 4,
        base_n: int = 1 << 12, seed: int = 0, mesh_devices: int = 0,
        serve_mode: str = "exact-parity") -> dict:
    mesh = None
    if mesh_devices:
        import jax
        import numpy as np
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()[:mesh_devices]), ("data",))
    server = JoinServer(batch_slots=slots,
                        cost_model=CostModel(beta_compute=1e-7, epsilon=1e-3),
                        mesh=mesh, serve_mode=serve_mode)
    budgets = [QueryBudget(error=0.5), QueryBudget(latency_s=0.5),
               QueryBudget()]
    for t in range(tenants):
        n = base_n << (t % 2)          # two capacity shape classes
        rels = overlapping_relations([n, n], 0.1, seed=seed + t)
        server.register_dataset(f"tenant{t}", rels)

    reqs = []
    for q in range(queries_per_tenant):
        for t in range(tenants):       # interleave tenants (worst case)
            reqs.append(server.submit(JoinRequest(
                dataset=f"tenant{t}", budget=budgets[t % len(budgets)],
                query_id=f"tenant{t}/agg", seed=seed + q,
                max_strata=2048, b_max=512)))
    t0 = time.perf_counter()
    server.run()
    dt = time.perf_counter() - t0

    d = server.diagnostics
    qps = d.queries / max(dt, 1e-9)
    where = f"mesh[{mesh_devices}]" if mesh_devices else "single-device"
    print(f"[join-serve] {d.queries} queries from {tenants} tenants in "
          f"{dt:.2f}s ({qps:.1f} q/s) on {where}")
    print(f"  steps={d.steps} max_batch={d.max_batch} "
          f"compiles={d.compiles} cache_hits={d.cache_hits}")
    print(f"  exact={d.exact_queries} sampled={d.sampled_queries} "
          f"mean_queue_latency={d.queue_latency_s / max(d.queries, 1):.3f}s")
    print(f"  filter_builds={d.filter_builds} "
          f"filter_cache_hits={d.filter_cache_hits} "
          f"shuffled_bytes_saved={d.shuffled_bytes_saved:.0f}")
    if mesh_devices:
        per_dev = [f"{b:.0f}" for b in d.per_device_shuffled_bytes]
        print(f"  dist_shuffled_tuple_bytes={d.dist_shuffled_tuple_bytes:.0f}"
              f" per_device={per_dev}")
        print(f"  serve_mode={serve_mode} "
              f"wire_bytes_model={d.dist_wire_bytes_model:.0f} "
              f"dropped_tuples={d.dist_dropped_tuples:.0f}")
    for r in reqs[:3]:
        print(f"  {r.query_id}: estimate={float(r.result.estimate):.1f} "
              f"+-{float(r.result.error_bound):.1f} "
              f"sampled={bool(r.result.diagnostics.sampled)}")
    return {"queries": d.queries, "seconds": dt, "qps": qps,
            **d.snapshot()}


def run_async(*, tenants: int = 4, queries_per_tenant: int = 8,
              slots: int = 4, base_n: int = 1 << 12, seed: int = 0,
              replicas: int = 2, mesh_devices: int = 0,
              serve_mode: str = "exact-parity") -> dict:
    """The same tenant workload through the always-on async tier: replica
    event loops with continuous batching behind a work-stealing front door
    (``runtime/async_serve.py``); submissions return futures immediately."""
    def factory(i: int) -> JoinServer:
        mesh = None
        if mesh_devices:
            import jax
            import numpy as np
            from jax.sharding import Mesh
            mesh = Mesh(np.array(jax.devices()[:mesh_devices]), ("data",))
        return JoinServer(batch_slots=slots,
                          cost_model=CostModel(beta_compute=1e-7,
                                               epsilon=1e-3),
                          mesh=mesh, serve_mode=serve_mode)

    budgets = [QueryBudget(error=0.5), QueryBudget(latency_s=0.5),
               QueryBudget()]
    with AsyncJoinFrontDoor(replicas=replicas, engine_factory=factory) as fd:
        for t in range(tenants):
            n = base_n << (t % 2)      # two capacity shape classes
            rels = overlapping_relations([n, n], 0.1, seed=seed + t)
            fd.register_dataset(f"tenant{t}", rels)
        t0 = time.perf_counter()
        futs = []
        for q in range(queries_per_tenant):
            for t in range(tenants):   # interleave tenants (worst case)
                futs.append(fd.submit(JoinRequest(
                    dataset=f"tenant{t}", budget=budgets[t % len(budgets)],
                    query_id=f"tenant{t}/agg", seed=seed + q,
                    max_strata=2048, b_max=512)))
        reqs = [f.result(timeout=600) for f in futs]
        dt = time.perf_counter() - t0
        snap = fd.snapshot()

    qps = len(reqs) / max(dt, 1e-9)
    where = f"mesh[{mesh_devices}]" if mesh_devices else "single-device"
    print(f"[join-serve --async] {len(reqs)} queries from {tenants} tenants "
          f"in {dt:.2f}s ({qps:.1f} q/s) on {where} x{replicas} replicas "
          f"steals={snap['steals']}")
    for name, rd in snap["replicas"].items():
        print(f"  {name}: queries={rd['queries']} steps={rd['steps']} "
              f"max_batch={rd['max_batch']} backfilled={rd['backfilled']} "
              f"stolen_in={rd['stolen_in']} "
              f"queue_p95={rd['queue_latency_p95_s']:.3f}s "
              f"e2e_p95={rd['e2e_latency_p95_s']:.3f}s")
    for r in reqs[:3]:
        print(f"  {r.query_id}: estimate={float(r.result.estimate):.1f} "
              f"+-{float(r.result.error_bound):.1f} "
              f"sampled={bool(r.result.diagnostics.sampled)}")
    return {"queries": len(reqs), "seconds": dt, "qps": qps, **snap}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--queries-per-tenant", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--base-n", type=int, default=1 << 12)
    ap.add_argument("--mesh", type=int, default=0,
                    help="serve distributed over N devices (0 = off)")
    ap.add_argument("--serve-mode", default="exact-parity",
                    choices=["exact-parity", "psum"],
                    help="mesh merge strategy: bit-parity gather vs "
                         "capacity-planned psum")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="serve through the async tier (event-loop "
                         "replicas + front door) instead of the step loop")
    ap.add_argument("--replicas", type=int, default=2,
                    help="front-door replica event loops (with --async)")
    args = ap.parse_args()
    if args.mesh:
        import jax
        if jax.device_count() < args.mesh:
            # the device-count flag must precede jax init: re-exec
            env = dict(os.environ)
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                                "--xla_force_host_platform_device_count="
                                f"{args.mesh}").strip()
            # the flag only multiplies CPU devices: pin the child to the cpu
            # platform or (on a GPU host) it would see 1 device and re-exec
            # forever
            env.setdefault("JAX_PLATFORMS", "cpu")
            raise SystemExit(subprocess.call(
                [sys.executable, "-m", "repro.launch.join_serve",
                 *sys.argv[1:]], env=env))
    if args.async_:
        run_async(tenants=args.tenants,
                  queries_per_tenant=args.queries_per_tenant,
                  slots=args.slots, base_n=args.base_n,
                  replicas=args.replicas, mesh_devices=args.mesh,
                  serve_mode=args.serve_mode)
    else:
        run(tenants=args.tenants,
            queries_per_tenant=args.queries_per_tenant,
            slots=args.slots, base_n=args.base_n, mesh_devices=args.mesh,
            serve_mode=args.serve_mode)


if __name__ == "__main__":
    main()
