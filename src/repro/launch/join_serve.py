"""JoinServer driver: multi-tenant batched ApproxJoin serving.

Builds synthetic tenant datasets in several capacity shape classes,
registers them as named handles, submits an interleaved query stream
(error-budget, latency-budget, and exact tenants), and prints throughput
plus the server's executable-cache / batching diagnostics.

Usage:
  PYTHONPATH=src python -m repro.launch.join_serve --tenants 4 \
      --queries-per-tenant 8 --slots 4
"""

from __future__ import annotations

import argparse
import time

from repro.core.budget import QueryBudget
from repro.core.cost import CostModel
from repro.data.synthetic import overlapping_relations
from repro.runtime.join_serve import JoinRequest, JoinServer


def run(*, tenants: int = 4, queries_per_tenant: int = 8, slots: int = 4,
        base_n: int = 1 << 12, seed: int = 0) -> dict:
    server = JoinServer(batch_slots=slots,
                        cost_model=CostModel(beta_compute=1e-7, epsilon=1e-3))
    budgets = [QueryBudget(error=0.5), QueryBudget(latency_s=0.5),
               QueryBudget()]
    for t in range(tenants):
        n = base_n << (t % 2)          # two capacity shape classes
        rels = overlapping_relations([n, n], 0.1, seed=seed + t)
        server.register_dataset(f"tenant{t}", rels)

    reqs = []
    for q in range(queries_per_tenant):
        for t in range(tenants):       # interleave tenants (worst case)
            reqs.append(server.submit(JoinRequest(
                dataset=f"tenant{t}", budget=budgets[t % len(budgets)],
                query_id=f"tenant{t}/agg", seed=seed + q,
                max_strata=2048, b_max=512)))
    t0 = time.perf_counter()
    server.run()
    dt = time.perf_counter() - t0

    d = server.diagnostics
    qps = d.queries / max(dt, 1e-9)
    print(f"[join-serve] {d.queries} queries from {tenants} tenants in "
          f"{dt:.2f}s ({qps:.1f} q/s)")
    print(f"  steps={d.steps} max_batch={d.max_batch} "
          f"compiles={d.compiles} cache_hits={d.cache_hits}")
    print(f"  exact={d.exact_queries} sampled={d.sampled_queries} "
          f"mean_queue_latency={d.queue_latency_s / max(d.queries, 1):.3f}s")
    print(f"  shuffled_bytes_saved={d.shuffled_bytes_saved:.0f}")
    for r in reqs[:3]:
        print(f"  {r.query_id}: estimate={float(r.result.estimate):.1f} "
              f"+-{float(r.result.error_bound):.1f} "
              f"sampled={bool(r.result.diagnostics.sampled)}")
    return {"queries": d.queries, "seconds": dt, "qps": qps,
            **d.snapshot()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--queries-per-tenant", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--base-n", type=int, default=1 << 12)
    args = ap.parse_args()
    run(tenants=args.tenants, queries_per_tenant=args.queries_per_tenant,
        slots=args.slots, base_n=args.base_n)


if __name__ == "__main__":
    main()
