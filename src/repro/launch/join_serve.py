"""JoinServer driver: multi-tenant batched ApproxJoin serving.

Builds synthetic tenant datasets in several capacity shape classes,
registers them as named handles, submits an interleaved query stream
(error-budget, latency-budget, and exact tenants), and prints throughput
plus the server's executable-cache / batching / filter-cache diagnostics.

Usage:
  PYTHONPATH=src python -m repro.launch.join_serve --tenants 4 \
      --queries-per-tenant 8 --slots 4

  # distributed: one batched step spans all mesh devices
  PYTHONPATH=src python -m repro.launch.join_serve --mesh 8

  # always-on async tier: event-loop replicas, continuous batching,
  # tenant sharding + work stealing behind one front door
  PYTHONPATH=src python -m repro.launch.join_serve --async --replicas 2

``--mesh N`` re-execs under ``--xla_force_host_platform_device_count`` when
the process has fewer than N devices (the flag must be set before jax
initializes), then serves through the shard_map pipeline.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from repro.core.budget import QueryBudget
from repro.core.cost import CostModel
from repro.data.synthetic import overlapping_relations
from repro.runtime.async_serve import AsyncJoinFrontDoor
from repro.runtime.join_serve import JoinRequest, JoinServer
from repro.runtime.telemetry import (Tracer, dump_chrome_trace,
                                     format_reconciliation,
                                     reconciliation_report)


def run(*, tenants: int = 4, queries_per_tenant: int = 8, slots: int = 4,
        base_n: int = 1 << 12, seed: int = 0, mesh_devices: int = 0,
        serve_mode: str = "exact-parity",
        trace_out: str | None = None) -> dict:
    mesh = None
    if mesh_devices:
        import jax
        import numpy as np
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()[:mesh_devices]), ("data",))
    tracer = Tracer(enabled=True) if trace_out else None
    server = JoinServer(batch_slots=slots,
                        cost_model=CostModel(beta_compute=1e-7, epsilon=1e-3),
                        mesh=mesh, serve_mode=serve_mode, tracer=tracer)
    budgets = [QueryBudget(error=0.5), QueryBudget(latency_s=0.5),
               QueryBudget()]
    for t in range(tenants):
        n = base_n << (t % 2)          # two capacity shape classes
        rels = overlapping_relations([n, n], 0.1, seed=seed + t)
        server.register_dataset(f"tenant{t}", rels)

    reqs = []
    for q in range(queries_per_tenant):
        for t in range(tenants):       # interleave tenants (worst case)
            reqs.append(server.submit(JoinRequest(
                dataset=f"tenant{t}", budget=budgets[t % len(budgets)],
                query_id=f"tenant{t}/agg", seed=seed + q,
                max_strata=2048, b_max=512)))
    t0 = time.perf_counter()
    server.run()
    dt = time.perf_counter() - t0

    d = server.diagnostics
    qps = d.queries / max(dt, 1e-9)
    where = f"mesh[{mesh_devices}]" if mesh_devices else "single-device"
    print(f"[join-serve] {d.queries} queries from {tenants} tenants in "
          f"{dt:.2f}s ({qps:.1f} q/s) on {where}")
    print(f"  steps={d.steps} max_batch={d.max_batch} "
          f"compiles={d.compiles} cache_hits={d.cache_hits}")
    print(f"  exact={d.exact_queries} sampled={d.sampled_queries} "
          f"mean_queue_latency={d.queue_latency_s / max(d.queries, 1):.3f}s")
    print(f"  filter_builds={d.filter_builds} "
          f"filter_cache_hits={d.filter_cache_hits} "
          f"shuffled_bytes_saved={d.shuffled_bytes_saved:.0f}")
    if mesh_devices:
        per_dev = [f"{b:.0f}" for b in d.per_device_shuffled_bytes]
        print(f"  dist_shuffled_tuple_bytes={d.dist_shuffled_tuple_bytes:.0f}"
              f" per_device={per_dev}")
        print(f"  serve_mode={serve_mode} "
              f"wire_bytes_model={d.dist_wire_bytes_model:.0f} "
              f"dropped_tuples={d.dist_dropped_tuples:.0f}")
    for r in reqs[:3]:
        print(f"  {r.query_id}: estimate={float(r.result.estimate):.1f} "
              f"+-{float(r.result.error_bound):.1f} "
              f"sampled={bool(r.result.diagnostics.sampled)}")
    if trace_out:
        recon = server.reconciliation_report()
        n_ev = dump_chrome_trace(tracer, trace_out, reconciliation=recon)
        print(f"  trace: {n_ev} events -> {trace_out} (open in "
              "ui.perfetto.dev or chrome://tracing)")
        print(format_reconciliation(recon))
    return {"queries": d.queries, "seconds": dt, "qps": qps,
            **d.snapshot()}


def run_async(*, tenants: int = 4, queries_per_tenant: int = 8,
              slots: int = 4, base_n: int = 1 << 12, seed: int = 0,
              replicas: int = 2, mesh_devices: int = 0,
              serve_mode: str = "exact-parity",
              checkpoint_dir: str | None = None,
              kill_after: int = 0,
              trace_out: str | None = None) -> dict:
    """The same tenant workload through the always-on async tier: replica
    event loops with continuous batching behind a work-stealing front door
    (``runtime/async_serve.py``); submissions return futures immediately.

    ``checkpoint_dir`` turns on per-replica engine checkpointing;
    ``kill_after`` N > 0 additionally runs the fault drill — replica0 dies
    (``InjectedFault``) after N served steps, the front door fails it over,
    and a successor adopts its tenants from the newest checkpoint.  Futures
    that were in flight on the dead replica fail with the injected fault
    (counted below); their requests are re-served from the checkpoint by
    the successor."""
    def factory(i: int) -> JoinServer:
        mesh = None
        if mesh_devices:
            import jax
            import numpy as np
            from jax.sharding import Mesh
            mesh = Mesh(np.array(jax.devices()[:mesh_devices]), ("data",))
        return JoinServer(batch_slots=slots,
                          cost_model=CostModel(beta_compute=1e-7,
                                               epsilon=1e-3),
                          mesh=mesh, serve_mode=serve_mode)

    budgets = [QueryBudget(error=0.5), QueryBudget(latency_s=0.5),
               QueryBudget()]
    tracer = Tracer(enabled=True) if trace_out else None
    with AsyncJoinFrontDoor(replicas=replicas, engine_factory=factory,
                            checkpoint_dir=checkpoint_dir,
                            tracer=tracer) as fd:
        for t in range(tenants):
            n = base_n << (t % 2)      # two capacity shape classes
            rels = overlapping_relations([n, n], 0.1, seed=seed + t)
            fd.register_dataset(f"tenant{t}", rels)
        t0 = time.perf_counter()
        if kill_after:
            # arm before submitting: the drill must fire mid-workload, not
            # race a drained queue (work stealing can empty replica0 fast)
            fd.replicas[0].kill_after(kill_after)
        futs = []
        for q in range(queries_per_tenant):
            for t in range(tenants):   # interleave tenants (worst case)
                futs.append(fd.submit(JoinRequest(
                    dataset=f"tenant{t}", budget=budgets[t % len(budgets)],
                    query_id=f"tenant{t}/agg", seed=seed + q,
                    max_strata=2048, b_max=512)))
        reqs, killed = [], 0
        for f in futs:
            try:
                reqs.append(f.result(timeout=600))
            except BaseException:  # noqa: BLE001 — the injected fault
                killed += 1
        if kill_after:
            fd.maybe_failover()
            # re-served-from-checkpoint requests carry no caller futures:
            # wait for the successor to drain its adopted queue
            deadline = time.monotonic() + 600
            while any(r.backlog() for r in fd.replicas
                      if r.error is None) and time.monotonic() < deadline:
                time.sleep(0.01)
        dt = time.perf_counter() - t0
        snap = fd.snapshot()

    qps = len(reqs) / max(dt, 1e-9)
    where = f"mesh[{mesh_devices}]" if mesh_devices else "single-device"
    print(f"[join-serve --async] {len(reqs)} queries from {tenants} tenants "
          f"in {dt:.2f}s ({qps:.1f} q/s) on {where} x{replicas} replicas "
          f"steals={snap['steals']}")
    if kill_after:
        print(f"  fault drill: killed replica0 after {kill_after} steps; "
              f"failovers={snap['failovers']} futures_failed={killed} "
              f"(re-served from checkpoint by the successor)")
    for name, rd in snap["replicas"].items():
        print(f"  {name}: queries={rd['queries']} steps={rd['steps']} "
              f"max_batch={rd['max_batch']} backfilled={rd['backfilled']} "
              f"stolen_in={rd['stolen_in']} "
              f"queue_p95={rd['queue_latency_p95_s']:.3f}s "
              f"e2e_p95={rd['e2e_latency_p95_s']:.3f}s")
    for r in reqs[:3]:
        print(f"  {r.query_id}: estimate={float(r.result.estimate):.1f} "
              f"+-{float(r.result.error_bound):.1f} "
              f"sampled={bool(r.result.diagnostics.sampled)}")
    if trace_out:
        # fleet-level report: the shared tracer holds every replica's
        # per-query recon records; server-level byte pairs are per-engine,
        # so the fleet dump aggregates queries only
        recon = reconciliation_report(tracer.recon)
        n_ev = dump_chrome_trace(tracer, trace_out, reconciliation=recon)
        print(f"  trace: {n_ev} events -> {trace_out} (open in "
              "ui.perfetto.dev or chrome://tracing)")
        print(format_reconciliation(recon))
    return {"queries": len(reqs), "seconds": dt, "qps": qps, **snap}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--queries-per-tenant", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--base-n", type=int, default=1 << 12)
    ap.add_argument("--mesh", type=int, default=0,
                    help="serve distributed over N devices (0 = off)")
    ap.add_argument("--serve-mode", default="exact-parity",
                    choices=["exact-parity", "psum"],
                    help="mesh merge strategy: bit-parity gather vs "
                         "capacity-planned psum")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="serve through the async tier (event-loop "
                         "replicas + front door) instead of the step loop")
    ap.add_argument("--replicas", type=int, default=2,
                    help="front-door replica event loops (with --async)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="per-replica engine checkpointing directory "
                         "(with --async): crash-safe serving state")
    ap.add_argument("--kill-after", type=int, default=0,
                    help="fault drill (with --async + --checkpoint-dir): "
                         "kill replica0 after N served steps and fail its "
                         "tenants over to a successor")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-query span trees and write a Chrome "
                         "trace-event JSON (perfetto-viewable) plus a "
                         "modeled-vs-measured byte reconciliation report; "
                         "summarize with repro.launch.trace_dump")
    args = ap.parse_args()
    if args.kill_after and not (args.async_ and args.checkpoint_dir):
        ap.error("--kill-after needs --async and --checkpoint-dir")
    if args.mesh:
        import jax
        if jax.device_count() < args.mesh:
            # the device-count flag must precede jax init: re-exec
            env = dict(os.environ)
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                                "--xla_force_host_platform_device_count="
                                f"{args.mesh}").strip()
            # the flag only multiplies CPU devices: pin the child to the cpu
            # platform or (on a GPU host) it would see 1 device and re-exec
            # forever
            env.setdefault("JAX_PLATFORMS", "cpu")
            raise SystemExit(subprocess.call(
                [sys.executable, "-m", "repro.launch.join_serve",
                 *sys.argv[1:]], env=env))
    if args.async_:
        run_async(tenants=args.tenants,
                  queries_per_tenant=args.queries_per_tenant,
                  slots=args.slots, base_n=args.base_n,
                  replicas=args.replicas, mesh_devices=args.mesh,
                  serve_mode=args.serve_mode,
                  checkpoint_dir=args.checkpoint_dir,
                  kill_after=args.kill_after, trace_out=args.trace_out)
    else:
        run(tenants=args.tenants,
            queries_per_tenant=args.queries_per_tenant,
            slots=args.slots, base_n=args.base_n, mesh_devices=args.mesh,
            serve_mode=args.serve_mode, trace_out=args.trace_out)


if __name__ == "__main__":
    main()
