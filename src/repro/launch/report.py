"""Aggregate dry-run records into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.launch.report \
      --baseline experiments/dryrun --optimized experiments/dryrun_optimized

Baseline records predate the MAC->FLOP accounting fix; their compute term is
doubled here (the optimized records already carry the corrected convention).
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str, mac_fix: bool) -> dict:
    out = {}
    for fn in glob.glob(os.path.join(dirname, "*.json")):
        r = json.load(open(fn))
        key = (r["arch"], r["shape"],
               "multi" if "pod" in r["mesh"] else "single")
        if r["status"] != "ok":
            out[key] = r
            continue
        if mac_fix:
            r["roofline"]["compute_s"] *= 2.0
            rf = r["roofline"]
            terms = {"compute": rf["compute_s"], "memory": rf["memory_s"],
                     "collective": rf["collective_s"]}
            rf["dominant"] = max(terms, key=terms.get)
        out[key] = r
    return out


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return f"| {r['arch']} | {r['shape']} | skipped | — | — | — | — | — |"
    rf = r["roofline"]
    step = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    frac = rf["compute_s"] / step if step else 0.0
    return (f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} "
            f"| {rf['memory_s']:.3e} | {rf['collective_s']:.3e} "
            f"| {rf['dominant']} | {frac:.2f} "
            f"| {rf['useful_fraction']:.2f} |")


HEADER = ("| arch | shape | compute (s) | memory (s) | collective (s) "
          "| dominant | roofline frac | useful frac |\n"
          "|---|---|---|---|---|---|---|---|")


def table(records: dict, mesh: str) -> str:
    rows = [HEADER]
    for key in sorted(records):
        if key[2] != mesh:
            continue
        rows.append(fmt_row(records[key]))
    return "\n".join(rows)


def deltas(base: dict, opt: dict) -> str:
    rows = ["| arch | shape | dominant term before -> after | speedup |",
            "|---|---|---|---|"]
    for key in sorted(base):
        if key[2] != "single":
            continue
        b, o = base.get(key), opt.get(key)
        if not b or not o or b["status"] != "ok" or o["status"] != "ok":
            continue
        bstep = max(b["roofline"]["compute_s"], b["roofline"]["memory_s"],
                    b["roofline"]["collective_s"])
        ostep = max(o["roofline"]["compute_s"], o["roofline"]["memory_s"],
                    o["roofline"]["collective_s"])
        if bstep / max(ostep, 1e-12) < 1.05 and ostep / max(bstep, 1e-12) < 1.05:
            continue
        rows.append(f"| {key[0]} | {key[1]} | {bstep:.3e} -> {ostep:.3e} "
                    f"| {bstep / max(ostep, 1e-12):.2f}x |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="experiments/dryrun")
    ap.add_argument("--optimized", default="experiments/dryrun_optimized")
    args = ap.parse_args()
    base = load(args.baseline, mac_fix=True)
    print("## Baseline roofline — single-pod (16x16), per-device terms\n")
    print(table(base, "single"))
    if os.path.isdir(args.optimized):
        opt = load(args.optimized, mac_fix=False)
        print("\n## Optimized roofline — single-pod\n")
        print(table(opt, "single"))
        print("\n## Dominant-term speedups (baseline -> optimized)\n")
        print(deltas(base, opt))
        print("\n## Optimized roofline — multi-pod (2x16x16)\n")
        print(table(opt, "multi"))


if __name__ == "__main__":
    main()
