"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds, per the assignment:

    compute    = HLO_FLOPs   / (chips * 197 TFLOP/s bf16)
    memory     = HLO_bytes   / (chips * 819 GB/s HBM)
    collective = coll_bytes  / (chips * 50 GB/s/link ICI)

``compiled.cost_analysis()`` on an SPMD-partitioned module reports the
PER-DEVICE program, so the terms divide by per-chip rates directly.

Collective bytes are not in cost_analysis: ``collective_bytes`` parses the
(per-device) HLO text, resolves each collective's operand shapes through a
name->shape table built from the def lines, and applies ring-cost
multipliers: all-gather (k-1)/k x out, all-reduce 2 (k-1)/k x size,
reduce-scatter (k-1)/k x in, all-to-all (k-1)/k x size, collective-permute
1 x size (k = replica-group size parsed per op).
"""

from __future__ import annotations

import re
from typing import NamedTuple

import numpy as np

PEAK_FLOPS = 197e12       # TPU v5e bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples by summing)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    """Replica-group size from replica_groups={{0,1,..},{..}} or [N,M]<=..."""
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return max(int(m.group(2)), 1)
    return default


class CollectiveStats(NamedTuple):
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def collective_bytes(hlo_text: str, default_group: int = 2
                     ) -> CollectiveStats:
    """Ring-model bytes moved per device, by collective kind."""
    defs: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            defs[m.group(1).lstrip("%")] = m.group(2)
    by_bytes: dict = {k: 0.0 for k in _COLLECTIVES}
    by_count: dict = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        kind = next((k for k in _COLLECTIVES
                     if re.search(rf"\b{k}(?:-start)?\(", rhs)), None)
        if kind is None:
            continue
        k = _group_size(rhs, default_group)
        out_b = _shape_bytes(rhs.split("(")[0])
        # operand bytes via the def table
        args = re.findall(r"%?([\w.\-]+)", rhs.split("(", 1)[1])
        in_b = sum(_shape_bytes(defs[a].split("(")[0])
                   for a in args if a in defs)
        size = max(out_b, in_b)
        if kind == "all-gather":
            bytes_moved = out_b * (k - 1) / k
        elif kind == "all-reduce":
            bytes_moved = 2 * size * (k - 1) / k
        elif kind == "reduce-scatter":
            bytes_moved = in_b * (k - 1) / k if in_b else out_b * (k - 1)
        elif kind == "all-to-all":
            bytes_moved = size * (k - 1) / k
        else:  # collective-permute
            bytes_moved = size
        by_bytes[kind] += bytes_moved
        by_count[kind] += 1
    return CollectiveStats(by_bytes, by_count)


class Roofline(NamedTuple):
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float              # per-device HLO flops
    hbm_bytes: float          # per-device HLO bytes accessed
    coll_bytes: float         # per-device collective bytes (ring model)
    collectives: dict         # count per kind
    model_flops: float        # 6ND-style useful flops (global)
    useful_fraction: float    # model_flops / (flops * chips)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)


# XLA's CPU cost analysis reports a dot's "flops" as M*N*K (MACs); the
# roofline convention (and the 197 TF peak) counts multiply+add = 2 flops.
# Calibrated against 6ND on the dense archs (useful_fraction ~ 2.1 before,
# ~1.05 after; see EXPERIMENTS.md §Roofline).
MAC_TO_FLOP = 2.0


def analyze(compiled, hlo_text: str, *, chips: int, model_flops: float,
            default_group: int = 2) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some backends return [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) * MAC_TO_FLOP
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text, default_group)
    cb = coll.total_bytes
    useful = model_flops / max(flops * chips, 1.0)
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=cb / ICI_BW,
        flops=flops, hbm_bytes=hbm, coll_bytes=cb,
        collectives={k: v for k, v in coll.count_by_kind.items() if v},
        model_flops=model_flops,
        useful_fraction=useful,
    )


def ep_moe_correction(cfg, cell_kind: str, batch: int, seq: int,
                      chips: int, tp: int) -> tuple:
    """Analytic (flops, hbm bytes) PER DEVICE for shard_map EP MoE layers.

    XLA's cost_analysis does not descend into shard_map call bodies, so the
    expert matmuls vanish from 'flops'/'bytes accessed' when moe_impl='ep'.
    We add them back from first principles:
      * dispatched slots/device = E_pad * C / tp (block-EP) — identical to
        E * C * (ffe/tp)/ffe (ffe-TP);
      * 3 matmuls (wg, wu, wd) x 2 flops, x4 for train (fwd + 2x bwd +
        remat re-fwd), x1 otherwise;
      * HBM: expert weight bytes/device re-read per pass + bucket tensors
        (xs, h, ys at bf16) twice each (write + read).
    """
    m = cfg.moe
    E, K, ffe, d = m.num_experts, m.top_k, m.d_ff_expert, cfg.d_model
    E_pad = -(-E // tp) * tp
    n_dp = max(chips // tp, 1)
    n_tok_local = max(batch * seq // n_dp, 1) if cell_kind != "decode" \
        else max(batch // n_dp, 1)
    C = max(int(n_tok_local * K * m.capacity_factor) // E, K)
    layers = cfg.n_layers
    passes = 4.0 if cell_kind == "train" else 1.0
    slot_flops = 3 * 2 * (E_pad * C // tp) * d * ffe
    flops = layers * passes * slot_flops
    w_bytes = 3 * E * d * ffe * 4 / tp          # f32 master weights
    bucket_bytes = 3 * (E_pad * C // tp) * max(d, ffe) * 2 * 2
    hbm = layers * passes * (w_bytes + bucket_bytes)
    return float(flops), float(hbm)


def model_flops_for(cfg, n_params: int, n_active: int, cell_kind: str,
                    batch: int, seq: int) -> float:
    """6ND (train) / 2ND (prefill) / 2N per token (decode), active params."""
    if cell_kind == "train":
        return 6.0 * n_active * batch * seq
    if cell_kind == "prefill":
        return 2.0 * n_active * batch * seq
    return 2.0 * n_active * batch      # decode: one token per sequence


def count_params(shapes_tree, cfg) -> tuple:
    """(total, active) param counts from a ShapeDtypeStruct tree.

    Active = total with expert stacks scaled by (top_k + shared)/E (MoE) —
    the paper-standard N_active for 6ND.
    """
    import jax

    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes_tree)[0]:
        names = [str(getattr(p, "key", "")) for p in path]
        n = int(np.prod(leaf.shape))
        total += n
        if cfg.moe and any(x.startswith("ff_") for x in names) \
                and "shared" not in names and leaf.ndim >= 3:
            frac = cfg.moe.top_k / cfg.moe.num_experts
            active += int(n * frac)
        else:
            active += n
    return total, active
