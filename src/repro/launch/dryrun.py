import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x applicable input shape x mesh) cell:
  * build full-scale parameter / optimizer / cache ShapeDtypeStructs via
    jax.eval_shape (no allocation),
  * jit the cell's step (train_step / prefill forward / serve decode_step)
    with in/out shardings derived from the logical-axis rules,
  * .lower(...).compile() against the production mesh,
  * record memory_analysis() + cost_analysis() + the roofline terms.

Meshes: single-pod (16, 16) ('data', 'model') and multi-pod (2, 16, 16)
('pod', 'data', 'model').  The XLA_FLAGS line above MUST run before any
other import so the CPU platform exposes 512 placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k --multi-pod --out experiments/dryrun
"""

import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import ARCHS, SHAPES, applicable
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.models.config import ArchConfig
from repro.models.model import CLIP_DIM
from repro.runtime.train import TrainState, make_train_step
from repro.sharding.axes import cache_axes, param_axes
from repro.sharding.specs import (DEFAULT_RULES, logical_rules, param_specs,
                                  spec_for)


def batch_specs(cfg: ArchConfig, kind: str, seq: int, batch: int) -> dict:
    """ShapeDtypeStructs for every model input of this cell (deliverable:
    input_specs())."""
    toks = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    specs = {"tokens": toks}
    if kind == "train":
        specs["targets"] = toks
    if cfg.num_img_tokens and kind != "decode":
        specs["img_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_img_tokens, CLIP_DIM), jnp.float32)
    if cfg.is_encdec and kind != "decode":
        e = cfg.encoder
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, e.n_frames, e.d_input), jnp.float32)
    return specs


def _sharded(shapes_tree, axes_tree, mesh, rules=None):
    shardings = param_specs(axes_tree, shapes_tree, mesh, rules)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings), shardings


def _batch_sharded(specs: dict, mesh) -> dict:
    out = {}
    for k, s in specs.items():
        names = ("batch",) + (None,) * (len(s.shape) - 1)
        sh = NamedSharding(mesh, spec_for(names, s.shape, mesh,
                                          DEFAULT_RULES))
        out[k] = jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
    return out


ZERO1_RULES = {"embed": "data"}  # m/v d_model dims shard over DP (ZeRO-1)
ZERO1_ENABLED = [False]          # set by --zero1 (module-level, not a cfg)


def _train_artifacts(model, mesh, zero1: bool = False):
    """Full-scale TrainState shapes + matching logical-axes tree.

    Optimizer slots (m, v) reuse the parameter axes verbatim — sharded
    optimizer state for free (DESIGN.md §6).  ``zero1`` additionally maps
    the (otherwise replicated) 'embed' logical axis of the m/v slots onto
    the 'data' mesh axis — ZeRO-1: every weight dim already sharded over
    'model' keeps that, and the d_model dim shards 16-ways over DP, cutting
    optimizer bytes ~16x per device at the cost of gather/scatter around
    the update (which XLA schedules; measured in §Perf E)."""
    from repro.optim.adamw import AdamWState
    from repro.runtime.train import train_state_init

    state_shapes = jax.eval_shape(
        functools.partial(train_state_init, model), jax.random.key(0))
    p_axes = param_axes(state_shapes.params, model.cfg)
    st_axes = TrainState(p_axes, AdamWState((), p_axes, p_axes), None)
    return state_shapes, st_axes


def build_cell(arch: str, shape_name: str, mesh):
    """(jitted fn, example args as sharded ShapeDtypeStructs)."""
    cfg = ARCHS[arch]
    cell = SHAPES[shape_name]
    model = Model(cfg)

    if cell.kind == "train":
        state_shapes, st_axes = _train_artifacts(model, mesh)
        state_sds, state_sh = _sharded(state_shapes, st_axes, mesh)
        if ZERO1_ENABLED[0]:
            _, opt_sh = _sharded(
                state_shapes.opt, st_axes.opt, mesh, rules=ZERO1_RULES)
            state_sh = state_sh._replace(opt=opt_sh)
            state_sds = state_sds._replace(opt=jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                state_shapes.opt, opt_sh))
        batch_sds = _batch_sharded(
            batch_specs(cfg, "train", cell.seq, cell.batch), mesh)
        step = make_train_step(model)
        fn = jax.jit(step,
                     in_shardings=(state_sh,
                                   {k: v.sharding for k, v in
                                    batch_sds.items()}),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
        return fn, (state_sds, batch_sds)

    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_axes = param_axes(params_shapes, cfg)
    params_sds, params_sh = _sharded(params_shapes, p_axes, mesh)

    if cell.kind == "prefill":
        batch_sds = _batch_sharded(
            batch_specs(cfg, "prefill", cell.seq, cell.batch), mesh)
        # logits stay vocab-sharded on the way out: an unconstrained output
        # made XLA all-gather the [B, 32k, V] tensor (40 GB for qwen2-0.5b)
        # - found in the prefill_32k hillclimb (EXPERIMENTS.md §Perf)
        logits_sh = NamedSharding(mesh, spec_for(
            ("batch", None, "vocab"),
            (cell.batch, cell.seq, cfg.vocab), mesh, DEFAULT_RULES))
        fn = jax.jit(lambda p, b: model.forward(p, b)[0],
                     in_shardings=(params_sh,
                                   {k: v.sharding for k, v in
                                    batch_sds.items()}),
                     out_shardings=logits_sh)
        return fn, (params_sds, batch_sds)

    # decode
    cache_shapes = model.cache_shape(cell.batch, cell.seq)
    c_axes = cache_axes(cache_shapes)
    cache_sds, cache_sh = _sharded(cache_shapes, c_axes, mesh)
    tok_sh = NamedSharding(mesh, spec_for(("batch",), (cell.batch,), mesh,
                                          DEFAULT_RULES))
    tok_sds = jax.ShapeDtypeStruct((cell.batch,), jnp.int32,
                                   sharding=tok_sh)
    fn = jax.jit(model.decode_step,
                 in_shardings=(params_sh, tok_sh, cache_sh),
                 out_shardings=(None, cache_sh),
                 donate_argnums=(2,))
    return fn, (params_sds, tok_sds, cache_sds)


def run_cell(arch: str, shape_name: str, mesh, *, verbose=True,
             rules=None, cfg_overrides=None) -> dict:
    import dataclasses
    cfg = ARCHS[arch]
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
        ARCHS[arch] = cfg  # build_cell reads the registry
    cell = SHAPES[shape_name]
    chips = int(np.prod(list(mesh.shape.values())))
    multi_pod = "pod" in mesh.shape
    rec = {"arch": arch, "shape": shape_name,
           "mesh": dict(mesh.shape), "chips": chips}
    if rules:
        rec["rules_override"] = dict(rules)
    ok, why = applicable(cfg, shape_name)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    t0 = time.time()
    merged_rules = {**dict(cfg.rules or ()), **(rules or {})} or None
    try:
        with logical_rules(mesh, merged_rules):
            fn, args = build_cell(arch, shape_name, mesh)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        rec["lower_compile_s"] = round(time.time() - t0, 1)
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        # roofline
        model = Model(cfg)
        params_shapes = jax.eval_shape(model.init, jax.random.key(0))
        n_total, n_active = RL.count_params(params_shapes, cfg)
        mf = RL.model_flops_for(cfg, n_total, n_active, cell.kind,
                                cell.batch, cell.seq)
        hlo = compiled.as_text()
        roof = RL.analyze(compiled, hlo, chips=chips, model_flops=mf,
                          default_group=chips)
        if cfg.ff_kind == "moe" and cfg.moe_impl == "ep":
            # cost_analysis can't see inside shard_map bodies: add the
            # expert-layer flops/bytes analytically (roofline.py)
            tp = mesh.shape.get("model", 1)
            df, dh = RL.ep_moe_correction(cfg, cell.kind, cell.batch,
                                          cell.seq, chips, tp)
            rec["ep_correction"] = {"flops_per_device": df,
                                    "hbm_bytes_per_device": dh}
            roof = roof._replace(
                flops=roof.flops + df, hbm_bytes=roof.hbm_bytes + dh,
                compute_s=(roof.flops + df) / RL.PEAK_FLOPS,
                memory_s=(roof.hbm_bytes + dh) / RL.HBM_BW,
                useful_fraction=mf / max((roof.flops + df) * chips, 1.0))
        rec["roofline"] = {
            "compute_s": roof.compute_s, "memory_s": roof.memory_s,
            "collective_s": roof.collective_s, "dominant": roof.dominant,
            "flops_per_device": roof.flops,
            "hbm_bytes_per_device": roof.hbm_bytes,
            "coll_bytes_per_device": roof.coll_bytes,
            "collective_ops": roof.collectives,
            "model_flops": mf, "useful_fraction": roof.useful_fraction,
            "n_params": n_total, "n_active": n_active,
        }
        rec["status"] = "ok"
        if verbose:
            print(f"  OK   {arch:24s} {shape_name:12s} "
                  f"{'multi' if multi_pod else 'single'}-pod  "
                  f"compile={rec['lower_compile_s']}s "
                  f"dominant={roof.dominant} "
                  f"terms=({roof.compute_s:.3e},{roof.memory_s:.3e},"
                  f"{roof.collective_s:.3e})s")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"  FAIL {arch:24s} {shape_name:12s}: {rec['error'][:120]}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--rule", action="append", default=[],
                    help="logical-axis rule override, e.g. seq=model")
    ap.add_argument("--set", action="append", default=[], dest="sets",
                    help="ArchConfig override, e.g. moe_impl=ep")
    ap.add_argument("--zero1", action="store_true",
                    help="shard optimizer slots over the data axis")
    args = ap.parse_args()
    def _coerce(v):
        return int(v) if v.isdigit() else v
    cfg_overrides = {k: _coerce(v) for k, v in
                     (kv.split("=", 1) for kv in args.sets)} or None
    ZERO1_ENABLED[0] = args.zero1
    overrides = dict(r.split("=", 1) for r in args.rule) or None
    if overrides:
        overrides = {k: (None if v == "none" else v)
                     for k, v in overrides.items()}

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [True, False] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    records = []
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        tag = "multipod" if mp else "singlepod"
        print(f"== mesh {dict(mesh.shape)} ==")
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh, rules=overrides,
                               cfg_overrides=cfg_overrides)
                records.append(rec)
                fn = os.path.join(args.out,
                                  f"{arch}__{shape}__{tag}.json")
                with open(fn, "w") as fh:
                    json.dump(rec, fh, indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_fail = sum(r["status"] == "failed" for r in records)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_fail} failed ==")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
