"""StreamJoin driver: windowed streaming ApproxJoin over synthetic streams.

Opens one streaming session per tenant (mixed error- and latency-budget),
feeds per-tenant micro-batch streams, serves every window that becomes due
and prints per-window estimates plus the streaming/serving diagnostics
(incremental filter reuse, admission shedding, queue-latency percentiles,
running whole-stream estimate).

Usage:
  PYTHONPATH=src python -m repro.launch.join_stream --size 4 --slide 1 \
      --sub-rows 2048 --pushes 12

  # distributed: window stages span all mesh devices
  PYTHONPATH=src python -m repro.launch.join_stream --mesh 8 --serve-mode psum

``--mesh N`` re-execs under ``--xla_force_host_platform_device_count`` when
the process has fewer than N devices (the flag must precede jax init).
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from repro.core.budget import QueryBudget
from repro.core.cost import CostModel
from repro.core.window import WindowSpec
from repro.data.synthetic import overlapping_relations
from repro.runtime.stream_join import StreamJoinServer


def run(*, tenants: int = 2, pushes: int = 12, size: int = 4, slide: int = 1,
        sub_rows: int = 2048, seed: int = 0, mesh_devices: int = 0,
        serve_mode: str = "exact-parity", window_slots: int = 8) -> dict:
    mesh = None
    if mesh_devices:
        import jax
        import numpy as np
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()[:mesh_devices]), ("data",))
    server = StreamJoinServer(batch_slots=max(tenants, 1), mesh=mesh,
                              serve_mode=serve_mode,
                              window_slots=window_slots,
                              cost_model=CostModel(beta_compute=1e-7,
                                                   epsilon=1e-3))
    budgets = [QueryBudget(error=0.5), QueryBudget(latency_s=0.5)]
    sessions = [server.open_stream(
        f"tenant{t}", WindowSpec(size, slide, sub_rows),
        budget=budgets[t % len(budgets)], max_strata=2048, b_max=512,
        seed=seed + t) for t in range(tenants)]

    t0 = time.perf_counter()
    for i in range(pushes):
        for t, sess in enumerate(sessions):
            sess.push(overlapping_relations(
                [sub_rows] * 2, 0.1, seed=seed + 1000 * (t + 1) + i))
        server.run()
    dt = time.perf_counter() - t0

    d = server.diagnostics
    s = server.stream_diagnostics
    where = f"mesh[{mesh_devices}]" if mesh_devices else "single-device"
    print(f"[join-stream] {s.sub_windows} micro-batches -> "
          f"{s.windows_emitted} windows from {tenants} tenants in {dt:.2f}s "
          f"on {where} ({serve_mode})")
    print(f"  filter_builds={d.filter_builds} "
          f"filter_cache_hits={d.filter_cache_hits} "
          f"retired={s.retired_filter_words} shed={s.windows_shed}")
    snap = d.snapshot()
    print(f"  compiles={d.compiles} cache_hits={d.cache_hits} "
          f"queue_latency p50/p95/max = "
          f"{snap['queue_latency_p50_s']:.3f}/"
          f"{snap['queue_latency_p95_s']:.3f}/"
          f"{snap['queue_latency_max_s']:.3f} s")
    for sess in sessions:
        done = sess.drain()
        for r in done[-2:]:
            print(f"  {sess.name} w{r.window_id}: "
                  f"estimate={float(r.result.estimate):.1f} "
                  f"+-{float(r.result.error_bound):.1f} "
                  f"sampled={bool(r.result.diagnostics.sampled)}")
        running = sess.running_estimate()
        if running is not None:
            print(f"  {sess.name} running ({sess.accumulated_windows} "
                  f"disjoint windows): {float(running.estimate):.1f} "
                  f"+-{float(running.error_bound):.1f}")
    return {"windows": s.windows_emitted, "seconds": dt,
            **d.snapshot(), **s.snapshot()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--pushes", type=int, default=12)
    ap.add_argument("--size", type=int, default=4,
                    help="sub-windows per window")
    ap.add_argument("--slide", type=int, default=1,
                    help="sub-windows per emission (== size: tumbling)")
    ap.add_argument("--sub-rows", type=int, default=1 << 11)
    ap.add_argument("--window-slots", type=int, default=8,
                    help="max queued windows per tenant before shedding")
    ap.add_argument("--mesh", type=int, default=0,
                    help="serve distributed over N devices (0 = off)")
    ap.add_argument("--serve-mode", default="exact-parity",
                    choices=["exact-parity", "psum"])
    args = ap.parse_args()
    if args.mesh:
        import jax
        if jax.device_count() < args.mesh:
            env = dict(os.environ)
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                                "--xla_force_host_platform_device_count="
                                f"{args.mesh}").strip()
            env.setdefault("JAX_PLATFORMS", "cpu")
            raise SystemExit(subprocess.call(
                [sys.executable, "-m", "repro.launch.join_stream",
                 *sys.argv[1:]], env=env))
    run(tenants=args.tenants, pushes=args.pushes, size=args.size,
        slide=args.slide, sub_rows=args.sub_rows,
        window_slots=args.window_slots, mesh_devices=args.mesh,
        serve_mode=args.serve_mode)


if __name__ == "__main__":
    main()
