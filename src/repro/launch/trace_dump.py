"""Summarize a Chrome trace-event JSON written by ``--trace-out``.

The trace file is viewable as-is in ui.perfetto.dev / chrome://tracing;
this CLI is the terminal-side reader: it validates the schema, then prints
per-category event counts, the longest spans, any plan span hierarchies
(recorded by the engine's "plan" instants), and the embedded
modeled-vs-measured byte reconciliation report.

Usage:
  PYTHONPATH=src python -m repro.launch.join_serve --trace-out /tmp/t.json
  PYTHONPATH=src python -m repro.launch.trace_dump /tmp/t.json
  PYTHONPATH=src python -m repro.launch.trace_dump /tmp/t.json \
      --validate-only
"""

from __future__ import annotations

import argparse
import json
from collections import Counter

from repro.runtime.telemetry import format_reconciliation, \
    validate_chrome_trace


def summarize(obj: dict, *, top: int = 10) -> str:
    """Render a validated chrome-trace object as a terminal summary."""
    evs = [e for e in obj["traceEvents"] if e.get("ph") != "M"]
    lines = [f"{len(evs)} events "
             f"({sum(1 for e in evs if e['ph'] == 'X')} spans, "
             f"{sum(1 for e in evs if e['ph'] == 'i')} instants)"]
    by_cat = Counter(e.get("cat", "?") for e in evs)
    lines.append("by category: " + ", ".join(
        f"{c}={n}" for c, n in by_cat.most_common()))
    lanes = {(e.get("pid"), e.get("tid")) for e in evs}
    lines.append(f"lanes: {len(lanes)}")

    spans = sorted((e for e in evs if e["ph"] == "X" and e.get("dur")),
                   key=lambda e: -e["dur"])
    if spans:
        lines.append(f"longest spans (top {min(top, len(spans))}):")
        for e in spans[:top]:
            qid = e.get("args", {}).get("query_id", "")
            tag = f"  [{qid}]" if qid else ""
            lines.append(f"  {e['dur'] / 1e3:10.3f} ms  {e['cat']}/"
                         f"{e['name']}{tag}")

    plans = [e for e in evs
             if e["name"] == "plan" and "hierarchy" in e.get("args", {})]
    for e in plans:
        args = e["args"]
        lines.append(f"plan {args.get('plan', '?')}:")
        for node, refs in args["hierarchy"].items():
            dep = f" <- {', '.join(refs)}" if refs else " (leaf inputs only)"
            lines.append(f"  {node}{dep}")

    recon = obj.get("reconciliation")
    if recon:
        lines.append("byte reconciliation (modeled vs measured):")
        lines.append(format_reconciliation(recon))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="validate + summarize a --trace-out chrome trace file")
    ap.add_argument("path", help="trace JSON written by --trace-out")
    ap.add_argument("--validate-only", action="store_true",
                    help="only validate the schema; print the event count")
    ap.add_argument("--top", type=int, default=10,
                    help="longest spans to list (default 10)")
    args = ap.parse_args()
    with open(args.path) as fh:
        obj = json.load(fh)
    n = validate_chrome_trace(obj)
    if args.validate_only:
        print(f"{args.path}: valid chrome trace, {n} events")
        return
    print(summarize(obj, top=args.top))


if __name__ == "__main__":
    main()
