"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state: jax locks the device count on first backend init, and only
``dryrun.py`` (which sets XLA_FLAGS before any import) may ask for 512
placeholder devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod (16, 16) = 256 chips, or 2 pods x 256 = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(dp: int = 1, tp: int = 1):
    """Small mesh over whatever devices exist (tests / reduced training)."""
    n = len(jax.devices())
    dp = min(dp, n)
    tp = min(tp, max(n // dp, 1))
    return jax.make_mesh((dp, tp), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The DP axes present in this mesh (pod included when multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
