"""Parameter pytree -> logical-axes pytree (path/shape based).

Used by the launcher to derive ``in_shardings``/``out_shardings`` for every
parameter (and optimizer slot) from the DEFAULT_RULES table.  Rules are
*fused-dim* style: wq's [d, H*hd] output dim shards over 'model' whenever the
fused dim divides the axis, even if H alone does not — XLA re-shards the
reshape inside attention (DESIGN.md §6; the divisibility fallback in
spec_for replicates anything that does not divide).
"""

from __future__ import annotations

from typing import Tuple

import jax

# last-key -> logical axes (without any leading scan/stack dims)
_BY_NAME: dict = {
    "embed": ("vocab", "embed"),
    "head": ("embed", "vocab"),
    "img_proj": (None, "embed"),
    "frame_proj": (None, "embed"),
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"),
    "wo": ("heads", "embed"),
    "bq": ("heads",),
    "bk": ("kv_heads",),
    "bv": ("kv_heads",),
    "wg": ("embed", "ff"),
    "wu": ("embed", "ff"),
    "wd": ("ff", "embed"),
    "bu": ("ff",),
    "bd": ("embed",),
    "router": ("embed", None),
    # mamba
    "in_proj": ("embed", "d_inner"),
    "conv_w": (None, "d_inner"),
    "conv_b": ("d_inner",),
    "x_proj": ("d_inner", None),
    "dt_proj": (None, "d_inner"),
    "dt_bias": ("d_inner",),
    "A_log": ("d_inner", None),
    "D": ("d_inner",),
    "out_proj": ("d_inner", "embed"),
    # rg-lru
    "in_y": ("embed", "lru"),
    "in_x": ("embed", "lru"),
    "wa": ("lru_blocks", None, None),
    "wx": ("lru_blocks", None, None),
    "lam": ("lru",),
    "out": ("lru", "embed"),
    # norms / misc: replicate
    "scale": (None,),
    "bias": (None,),
}

# keys under which the experts' 3D weights live (expert-sharded, EP)
_MOE_WEIGHTS = ("wg", "wu", "wd")


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def param_axes(params, cfg=None) -> dict:
    """Pytree of logical-axis tuples matching ``params``' structure.

    With ``cfg`` the attention/expert dims carry their semantic quantum
    (head count / expert count): a dim only shards when whole heads or
    experts land per shard, else it replicates (specs.spec_for).  Expert
    stacks declare a fallback: shard the expert dim when the count divides,
    otherwise shard the per-expert FFN dim on the same mesh axis (qwen2-moe's
    60 experts over 16 -> TP inside experts instead of full replication)."""
    by_name = dict(_BY_NAME)
    if cfg is not None:
        H, Hk = ("heads", cfg.n_heads), ("kv_heads", cfg.n_kv_heads)
        by_name.update(wq=("embed", H), wo=(H, "embed"),
                       wk=("embed", Hk), wv=("embed", Hk),
                       bq=(H,), bk=(Hk,), bv=(Hk,))
    E = ("expert", cfg.moe.num_experts) if (cfg and cfg.moe) else "expert"

    def one(path, leaf) -> Tuple:
        names = _path_names(path)
        last = names[-1] if names else ""
        # MoE expert stacks: ff_* / {wg,wu,wd} with 3 trailing dims
        if last in _MOE_WEIGHTS and any(n.startswith("ff_") for n in names) \
                and "shared" not in names and leaf.ndim >= 3:
            base: Tuple = (E, "ff", None) if last == "wd" \
                else (E, None, "ff")
        elif last in by_name:
            base = by_name[last]
        else:
            base = (None,) * leaf.ndim
        # leading stacked-block axes (trunk scan / enc/dec stacks)
        extra = leaf.ndim - len(base)
        if extra > 0:
            base = ("layers",) * extra + base
        elif extra < 0:
            base = base[-leaf.ndim:] if leaf.ndim else ()
        return tuple(base)

    return jax.tree_util.tree_map_with_path(one, params)


# decode-cache logical axes.  Every cache leaf carries a leading stacked-
# layers dim (trunk scan / enc-dec stacks); the trailing dims map by name.
_CACHE_BY_NAME: dict = {
    "k": ("batch", "kv_seq", None, None),      # [B, S, Hk, hd]
    "v": ("batch", "kv_seq", None, None),
    "conv": ("batch", None, "d_inner"),        # [B, dc-1, width]
    "h": ("batch", "d_inner", None),           # mamba [B, di, st] / rglru [B, lru]
    "cross_k": ("batch", None, None, None),    # [B, F, Hk, hd]
    "cross_v": ("batch", None, None, None),
    "pos": ("batch",),
}


def cache_axes(cache) -> dict:
    def one(path, leaf):
        names = _path_names(path)
        last = names[-1] if names else ""
        base = _CACHE_BY_NAME.get(last, (None,) * (leaf.ndim - 1))
        base = base[: leaf.ndim - 1]
        return ("layers",) + tuple(base)

    return jax.tree_util.tree_map_with_path(one, cache)
