"""Logical-axis sharding: MaxText-style name -> mesh-axis rules, made
divisibility-aware so awkward dims (14 heads, 51865 vocab, 60 experts) fall
back to replication instead of failing to lower (DESIGN.md §6).

Model code tags tensors with *logical* axis names via ``shard_hint``; the
launcher binds (mesh, rules) with ``logical_rules`` and every hint becomes a
``with_sharding_constraint``.  Outside a binding the hints are no-ops, so
unit tests run on one device untouched.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes). None = replicate.
DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),     # DP over pod x data
    "seq": None,
    "kv_seq": "model",            # decode KV cache length (flash-decode SP)
    "embed": None,
    "ff": "model",                # TP: MLP hidden
    "heads": "model",             # TP: attention q heads (fused H*hd dim)
    "kv_heads": "model",          # TP: kv heads (falls back when indivisible)
    "vocab": "model",             # TP: embedding/unembedding
    "expert": "model",            # EP: expert-sharded MoE weights
    "d_inner": "model",           # Mamba inner width
    "lru": "model",               # RG-LRU width
    "layers": None,               # scanned-block leading axis
    None: None,
}

_ctx = threading.local()


@contextmanager
def logical_rules(mesh: Mesh, rules: Optional[dict] = None):
    """Bind (mesh, rules) for shard_hint / param_specs in this thread."""
    prev = getattr(_ctx, "bind", None)
    _ctx.bind = (mesh, {**DEFAULT_RULES, **(rules or {})})
    try:
        yield
    finally:
        _ctx.bind = prev


def current_binding():
    return getattr(_ctx, "bind", None)


def _mesh_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape.get(a, 1)
        return n
    return mesh.shape.get(axis, 1)


def spec_for(names: Sequence, shape: Sequence[int],
             mesh: Mesh, rules: dict) -> P:
    """PartitionSpec from logical names, dropping indivisible shardings.

    An entry may be ``(name, quantum)``: the dim holds ``quantum`` semantic
    units (attention heads, experts) and only shards when whole units land
    per shard — e.g. qwen2-0.5b's fused q dim (14 heads x 64) is divisible
    by 16 *bytes-wise* but sharding it would split heads across shards and
    force per-layer resharding, so it replicates instead (found via the
    prefill_32k collective blow-up; EXPERIMENTS.md §Perf)."""
    parts = []
    for name, dim in zip(names, shape):
        quantum = None
        if isinstance(name, tuple):
            name, quantum = name
        axis = rules.get(name)
        if axis is not None and isinstance(axis, tuple):
            axis = tuple(a for a in axis if a in mesh.shape)
            axis = axis or None
        if axis is not None and not isinstance(axis, tuple) \
                and axis not in mesh.shape:
            axis = None
        size = _mesh_size(mesh, axis)
        ok = (axis is not None and dim > 0 and dim % size == 0
              and (quantum is None or quantum % size == 0))
        parts.append(axis if ok else None)
    # a mesh axis may appear once per spec: keep the first (highest-priority)
    # use, replicate the rest — lets axes express fallbacks like "shard the
    # expert dim if divisible, else the expert-FFN dim" on the same axis.
    seen: set = set()
    out = []
    for p in parts:
        flat = p if isinstance(p, tuple) else (p,)
        if p is not None and any(a in seen for a in flat):
            out.append(None)
        else:
            out.append(p)
            seen.update(a for a in flat if a is not None)
    return P(*out)


def shard_hint(x, names: Sequence[Optional[str]]):
    """with_sharding_constraint if a (mesh, rules) binding is active."""
    bind = current_binding()
    if bind is None:
        return x
    mesh, rules = bind
    spec = spec_for(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def is_axes_leaf(x) -> bool:
    """An axes-tree leaf: tuple of str | None | (str, int quantum)."""
    return isinstance(x, tuple) and all(
        isinstance(n, (str, type(None)))
        or (isinstance(n, tuple) and len(n) == 2 and isinstance(n[0], str))
        for n in x)


def param_specs(axes_tree, shapes_tree, mesh: Mesh,
                rules: Optional[dict] = None):
    """Map a pytree of logical-axis tuples + matching shapes to
    NamedShardings (for jit in_shardings / out_shardings)."""
    rules = {**DEFAULT_RULES, **(rules or {})}

    def one(names, leaf):
        return NamedSharding(mesh, spec_for(names, leaf.shape, mesh, rules))

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes_leaf)
