from repro.sharding.specs import (DEFAULT_RULES, logical_rules, param_specs,
                                  shard_hint, spec_for)

__all__ = ["DEFAULT_RULES", "logical_rules", "param_specs", "shard_hint",
           "spec_for"]
