"""Static-shape relations.

A :class:`Relation` is the TPU-native stand-in for an RDD of key/value pairs:
dense ``keys``/``values`` arrays plus a ``valid`` mask (JAX needs static
shapes, so "fewer rows" is expressed by masking, and every pipeline stage is a
dense pass — the same constraint the paper faces on HDFS, where random access
is off the table).

Values are a single float column; the aggregation queries the paper targets
(SUM / COUNT / AVG / STDEV over an expression of the joined values, §2) only
need one numeric column per side.  Multi-column payloads ride along as extra
Relations with the same keys.
"""

from __future__ import annotations

import hashlib
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class Relation(NamedTuple):
    """A (possibly sharded) key/value relation with a validity mask."""

    keys: jnp.ndarray    # uint32 [N]
    values: jnp.ndarray  # float32 [N]
    valid: jnp.ndarray   # bool    [N]

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    def count(self) -> jnp.ndarray:
        return jnp.sum(self.valid.astype(jnp.int32))

    def masked_keys(self, fill: int = 0xFFFFFFFF) -> jnp.ndarray:
        """Keys with invalid slots replaced by ``fill`` (sorts to the end)."""
        return jnp.where(self.valid, self.keys, jnp.uint32(fill))


def relation(keys, values=None, valid=None) -> Relation:
    """Build a Relation from array-likes, filling defaults."""
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    if values is None:
        values = jnp.zeros(keys.shape, jnp.float32)
    values = jnp.asarray(values, dtype=jnp.float32)
    if valid is None:
        valid = jnp.ones(keys.shape, bool)
    valid = jnp.asarray(valid, dtype=bool)
    assert keys.shape == values.shape == valid.shape and keys.ndim == 1
    return Relation(keys, values, valid)


def pad_to(rel: Relation, capacity: int) -> Relation:
    """Pad a relation with invalid rows up to ``capacity``."""
    n = rel.capacity
    if n == capacity:
        return rel
    assert n < capacity, f"cannot shrink relation {n} -> {capacity}"
    pad = capacity - n
    return Relation(
        jnp.concatenate([rel.keys, jnp.zeros((pad,), jnp.uint32)]),
        jnp.concatenate([rel.values, jnp.zeros((pad,), jnp.float32)]),
        jnp.concatenate([rel.valid, jnp.zeros((pad,), bool)]),
    )


def bucket_capacity(n: int, minimum: int = 1) -> int:
    """Round a row count up to the next power of two (shape-class bucketing).

    Serving batches queries whose relations share a capacity bucket, so the
    compiled executable count is logarithmic in the capacity range rather
    than linear in the number of distinct input sizes.  ``minimum`` floors
    the bucket (a mesh-sharded relation needs capacity divisible by the
    device count; any power of two >= k is).
    """
    return max(1 << max(int(n) - 1, 0).bit_length(), int(minimum))


def bucket_to_pow2(rel: Relation, minimum: int = 1) -> Relation:
    """Pad a relation with invalid rows up to its power-of-two bucket."""
    return pad_to(rel, bucket_capacity(rel.capacity, minimum))


def fingerprint(rel: Relation) -> str:
    """Content id of a relation's key set (keys + validity mask).

    Keyed on exactly what a Bloom filter build consumes, so two relations
    with the same keys/validity share cached filter words regardless of
    their value columns (the JoinServer's per-dataset filter cache).
    """
    h = hashlib.sha1()
    h.update(np.asarray(jax.device_get(rel.keys)).tobytes())
    h.update(np.packbits(np.asarray(jax.device_get(rel.valid))).tobytes())
    return h.hexdigest()


def shard_to_mesh(rel: Relation, mesh, axes: Sequence[str]) -> Relation:
    """Place a relation's rows sharded over ``axes`` of ``mesh``."""
    from jax.sharding import NamedSharding, PartitionSpec
    sh = NamedSharding(mesh, PartitionSpec(tuple(axes)))
    return Relation(*(jax.device_put(x, sh) for x in rel))


def sort_by_key(rel: Relation) -> Relation:
    """Sort valid rows by key; invalid rows go last (stable)."""
    order = jnp.argsort(rel.masked_keys())
    return Relation(rel.keys[order], rel.values[order], rel.valid[order])


def concatenate(rels: list[Relation]) -> Relation:
    return Relation(
        jnp.concatenate([r.keys for r in rels]),
        jnp.concatenate([r.values for r in rels]),
        jnp.concatenate([r.valid for r in rels]),
    )


def shard_rows(rel: Relation, num_shards: int) -> Relation:
    """Reshape [N] -> [num_shards, N/num_shards] for shard_map feeding."""
    assert rel.capacity % num_shards == 0
    f = lambda x: x.reshape(num_shards, -1)
    return Relation(f(rel.keys), f(rel.values), f(rel.valid))


def to_numpy(rel: Relation):
    """(keys, values) of the valid rows as host numpy arrays (test helper)."""
    k = np.asarray(jax.device_get(rel.keys))
    v = np.asarray(jax.device_get(rel.values))
    m = np.asarray(jax.device_get(rel.valid))
    return k[m], v[m]
