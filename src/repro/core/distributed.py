"""Distributed ApproxJoin over a JAX device mesh (shard_map).

This is the paper's Spark dataflow (Fig. 7) mapped onto SPMD collectives
(DESIGN.md §2):

  stage                     Spark                       here
  ------------------------- --------------------------- ----------------------
  partition filters          Map at each worker          local bloom.build
  dataset filter             treeReduce OR to driver     all_gather + OR fold
                                                         (hierarchical: intra-
                                                         pod first, then pods)
  join filter + broadcast    driver AND + broadcast      local AND (replicated)
  probe + discard            filter() on workers         local probe -> mask
  cogroup shuffle            hash shuffle                bucketize + all_to_all
  sampleDuringJoin           per-key edge sampling       vectorized sampler
  merge partial results      collect at driver           gather + key-sort, or
                                                         psum of SumParts

The pipeline is factored into per-stage functions mirroring
``core/join.py``'s ``prepare/exact/sample/estimate`` split, so the serving
engine (``runtime/join_serve.py``) can cache per-stage executables for the
distributed path exactly as it does for the single-device path.

Two merge strategies:

* ``merge='gather'`` (default): per-device strata/stats are all_gathered,
  key-sorted into the canonical single-device ``[S]`` slot layout, and
  finished with the *same* arithmetic as ``core/join.py`` — results are
  **bit-identical** to the single-device pipeline at any mesh size (the
  shuffle routes every key to exactly one device, the received rows arrive in
  source-major = original-row order, and the sampler keys its PRNG on the
  join key, so every per-stratum quantity is reproduced exactly; asserted in
  ``tests/test_join_serve_distributed.py``).

* ``merge='psum'``: the paper's dataflow — per-device estimator parts ADD
  across devices (strata are device-complete after the shuffle) and the merge
  is a single psum.  Cheapest collectives (used by the cluster-scale
  roofline dry-runs); results agree with single-device up to float
  reassociation.

Everything is static-shape: the shuffle uses capacity-bounded buckets
(overflow is counted and surfaced — the feedback path for elastic re-runs).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import bloom
from repro.core.budget import QueryBudget
from repro.core.cost import CostModel, fraction_for_latency
from repro.core.estimators import (HTParts, StratumStats, clt_avg_from,
                                   clt_count, clt_finish, clt_stdev_from,
                                   clt_sum_parts, ht_finish, ht_sum_parts,
                                   second_moment_stats, SumParts)
from repro.core.hashing import hash2, u32
from repro.core.join import (EXPRS, TUPLE_BYTES, estimate_stage,
                             exact_stage_from_sums, _pilot_sizes)
from repro.core.relation import Relation, sort_by_key
from repro.core.sampling import (SENTINEL, SampleResult, Strata, build_strata,
                                 exact_count, exact_sum_of_products,
                                 exact_sum_of_sums, per_stratum_value_sums,
                                 sample_edges)


class DistJoinResult(NamedTuple):
    estimate: jnp.ndarray
    error_bound: jnp.ndarray
    count: jnp.ndarray
    dof: jnp.ndarray
    # meters (replicated scalars)
    shuffled_tuple_bytes: jnp.ndarray   # live tuples that crossed devices
    filter_bytes: jnp.ndarray           # filter all_gather volume (model)
    live_total: jnp.ndarray
    input_total: jnp.ndarray
    overlap_fraction: jnp.ndarray
    bucket_overflow: jnp.ndarray
    strata_overflow: jnp.ndarray
    total_population: jnp.ndarray
    sample_draws: jnp.ndarray
    device_shuffled_bytes: jnp.ndarray  # [k] per-device sent-tuple bytes
    device_dropped: jnp.ndarray         # [k] per-device bucket-dropped tuples


def planned_bucket_cap(local_rows: int, k: int, overlap: float, *,
                       slack: float = 2.0, floor: int = 8) -> int:
    """Capacity-planned shuffle bucket size from a live-fraction estimate.

    The filter's shuffle saving only reaches the wire of a static-shape
    dataflow if the all_to_all buffers shrink with it: size the per-(source,
    dest) bucket for the *expected live* rows — ``local_rows * overlap / k``
    with ``slack``x headroom — instead of the lossless worst case
    (``local_rows``).  Small buckets get a ``3 sqrt(2 mean)`` concentration
    guard instead: keys place hash-randomly but rows arrive in per-key
    clumps, so the per-bucket load is compound-Poisson with variance ~
    ``2 mean``, and a plain multiplicative slack under-provisions exactly
    when buckets are a handful of rows (at production bucket sizes the
    guard is the smaller term and the plan stays ``slack * mean``).
    Overflow beyond the plan is counted, never silent — the feedback path
    for recompile-bigger elastic re-runs.
    """
    mean = local_rows * overlap / max(k, 1)
    guard = max((slack - 1.0) * mean, 3.0 * math.sqrt(max(2.0 * mean, 0.0)))
    return max(int(mean + guard), floor)


def axis_size(a: str):
    """Size of a mapped mesh axis.  ``jax.lax.axis_size`` only exists in
    newer JAX; ``psum(1, axis)`` is the classic constant-folding idiom."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)


def combined_axis_index(axes: Sequence[str]) -> jnp.ndarray:
    """Linear device index over possibly-multiple mesh axes (major first)."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


def or_reduce(words: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """OR-merge partition filters across the mesh (Alg. 1 reduce phase).

    Hierarchical: reduce over the innermost (fast, intra-pod ICI) axis first,
    then the outer (inter-pod DCN) axis — only one |BF| message crosses the
    slow link per pod, the treeReduce insight restated for a torus.
    """
    for a in reversed(list(axes)):
        gathered = jax.lax.all_gather(words, a)  # [k_a, nb, W]
        words = functools.reduce(jnp.bitwise_or,
                                 [gathered[i] for i in range(gathered.shape[0])])
    return words


def bucketize(rel: Relation, dest: jnp.ndarray, k: int, cap: int):
    """Scatter live rows into k capacity-bounded send buckets.

    Returns (keys [k, cap], values [k, cap], valid [k, cap], overflow []).
    Rows are ranked within their destination by sort; rows beyond ``cap`` are
    dropped and counted (static shapes; same trick as MoE capacity).
    """
    n = rel.capacity
    d = jnp.where(rel.valid, dest, k)                      # invalid -> k
    order = jnp.argsort(d)                                 # stable
    ds = d[order]
    pos = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), ds[1:] != ds[:-1]])
    run_start = jax.lax.cummax(jnp.where(is_start, pos, 0))
    slot = pos - run_start
    ok = (ds < k) & (slot < cap)
    flat = jnp.where(ok, ds * cap + slot, k * cap)
    keys = jnp.zeros((k * cap + 1,), jnp.uint32).at[flat].set(
        rel.keys[order], mode="drop")[:-1].reshape(k, cap)
    vals = jnp.zeros((k * cap + 1,), jnp.float32).at[flat].set(
        rel.values[order], mode="drop")[:-1].reshape(k, cap)
    valid = jnp.zeros((k * cap + 1,), bool).at[flat].set(
        ok, mode="drop")[:-1].reshape(k, cap)
    overflow = jnp.sum(((ds < k) & (slot >= cap)).astype(jnp.int32))
    return keys, vals, valid, overflow


def shuffle_by_key(rel: Relation, k: int, cap: int, axes: Sequence[str],
                   seed: int):
    """Hash-partition a sharded relation so each key lands on one device.

    The received buffer is source-major and bucketize keeps original row
    order within a bucket, so for any key the received rows arrive in
    ascending original-global-row order — a stable local sort by key then
    reproduces the single-device sorted segment content exactly (the
    bit-parity invariant the gather merge relies on).
    """
    dest = (hash2(rel.keys, seed) % u32(k)).astype(jnp.int32)
    me = combined_axis_index(axes)
    sent = rel.valid & (dest != me)
    keys, vals, valid, overflow = bucketize(rel, dest, k, cap)
    # Factor the bucket dim as (size(a0), size(a1), ..., cap) and exchange
    # each factor along ITS mesh axis — the composition is the all_to_all
    # over the combined (major-first) device index.  Exchanging always on
    # the leading dim would route the later axes by SOURCE index (bug).
    sizes = [axis_size(a) for a in axes]
    recv = []
    for x in (keys, vals, valid):
        x = x.reshape(*sizes, cap)
        for i, a in enumerate(axes):
            x = jax.lax.all_to_all(x, a, split_axis=i, concat_axis=i,
                                   tiled=True)
        recv.append(x.reshape(-1, cap))
    out = Relation(recv[0].reshape(-1), recv[1].reshape(-1),
                   recv[2].reshape(-1))
    return out, jnp.sum(sent.astype(jnp.int32)), overflow


# ---------------------------------------------------------------------------
# Gather merge: rebuild the canonical single-device [S] slot layout from the
# per-device strata.  Every key lives on exactly one device after the
# shuffle, so sorting the gathered slots by key and truncating to S yields
# the same keys, in the same order, as a single-device build_strata — and
# any per-stratum quantity computed on the owning device drops into the
# same slot it would occupy on a single device.
# ---------------------------------------------------------------------------

def gather_concat(x: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """all_gather over possibly-multiple axes, concatenated on dim 0."""
    for a in reversed(list(axes)):
        x = jax.lax.all_gather(x, a, tiled=True)
    return x


def merge_by_key(local_keys: jnp.ndarray, fields: Sequence[jnp.ndarray],
                 axes: Sequence[str], max_strata: int):
    """Key-sort per-device [S]-leading slot arrays into canonical [S] slots.

    Returns ``(keys [S], merged_fields)``.  Slots beyond ``max_strata``
    (largest keys — the same drop rule as ``build_strata``) are truncated.
    """
    gk = gather_concat(local_keys, axes)          # [k*S]
    order = jnp.argsort(gk)                       # stable; SENTINEL slots last
    keys = gk[order][:max_strata]
    merged = [gather_concat(f, axes)[order][:max_strata] for f in fields]
    return keys, merged


def merge_strata(local: Strata, axes: Sequence[str], max_strata: int) -> Strata:
    """Merged replicated Strata in the canonical single-device layout.

    ``starts`` are zeroed — they index per-device sorted arrays and have no
    global meaning; everything downstream of the merge (host-side sample
    sizing, exact finish, estimators) only needs keys/valid/counts.
    """
    S = max_strata
    n_sides = local.counts.shape[0]
    total = jax.lax.psum(jnp.sum(local.valid.astype(jnp.int32))
                         + local.overflow, tuple(axes))
    keys, counts = merge_by_key(local.keys,
                                [local.counts[i] for i in range(n_sides)],
                                axes, S)
    valid = jnp.arange(S) < jnp.minimum(total, S)
    keys = jnp.where(valid, keys, u32(SENTINEL))
    counts = jnp.stack([jnp.where(valid, c, 0) for c in counts])
    return Strata(keys, valid, jnp.zeros_like(counts), counts,
                  jnp.maximum(total - S, 0))


def merged_to_local(merged_keys: jnp.ndarray, local_strata: Strata,
                    merged_vals: jnp.ndarray,
                    fill=0.0) -> jnp.ndarray:
    """Route a merged-[S] per-stratum array back to this device's slots."""
    S = merged_keys.shape[0]
    pos = jnp.clip(jnp.searchsorted(merged_keys, local_strata.keys), 0, S - 1)
    hit = local_strata.valid & (merged_keys[pos] == local_strata.keys)
    return jnp.where(hit, merged_vals[pos], fill)


# ---------------------------------------------------------------------------
# Per-device stage functions (run inside shard_map), mirroring
# core/join.py's prepare / exact / sample split.
# ---------------------------------------------------------------------------

class DistPrepareOut(NamedTuple):
    """Distributed stages 1-3 output.

    ``sorted_rels``/``local_strata`` are per-device (sharded) working state;
    ``strata``/``population``/counters are replicated and already merged into
    the canonical single-device layout, ready for host-side decisions.
    """

    sorted_rels: list[Relation]         # per-device shuffled + sorted rows
    local_strata: Strata                # per-device [S] slots
    strata: Strata                      # merged canonical [S] (replicated)
    live_counts: jnp.ndarray            # int32 [n] global
    total_counts: jnp.ndarray           # int32 [n] global
    population: jnp.ndarray             # f32 [S] merged
    shuffled_tuple_bytes: jnp.ndarray   # f32 [] global live bytes moved
    device_shuffled_bytes: jnp.ndarray  # f32 [k] per-device bytes sent
    bucket_overflow: jnp.ndarray        # int32 [] global dropped rows
    device_dropped: jnp.ndarray         # int32 [k] per-device dropped rows
    filter_bytes: jnp.ndarray           # f32 [] filter traffic (model)


def dist_prepare_stage(rels: Sequence[Relation], num_blocks: int,
                       max_strata: int, seed, axes: Sequence[str],
                       *, bucket_cap: Optional[int] = None,
                       filter_words: Optional[Sequence[jnp.ndarray]] = None,
                       filter_stage: bool = True,
                       merge: str = "gather") -> DistPrepareOut:
    """Filter build/OR/AND/probe, key shuffle, local sort + group-by, merge.

    ``filter_words`` (one ``[num_blocks, W]`` array per input) skips the
    build+OR — the serving engine passes its per-dataset cached dataset
    filters here so registered datasets pay the build once, not every step.

    ``merge='gather'`` rebuilds the canonical [S] strata (replicated) for
    the bit-parity path.  ``merge='psum'`` skips the gather entirely — the
    ``strata``/``population`` members are then the PER-DEVICE strata (with a
    psum'd overflow), keeping the paper's cheap-collective dataflow intact
    for the roofline dry-runs.
    """
    axes = tuple(axes)
    k = 1
    for a in axes:
        k *= axis_size(a)
    n_rels = len(rels)
    local_n = rels[0].capacity
    total_counts = jax.lax.psum(jnp.stack([r.count() for r in rels]), axes)

    if filter_stage:
        if filter_words is None:
            filter_words = [
                or_reduce(bloom.build(r.keys, r.valid, num_blocks, seed).words,
                          axes) for r in rels]
        jf = bloom.intersect_all(
            [bloom.BloomFilter(w, seed) for w in filter_words])
        rels = [Relation(r.keys, r.values,
                         r.valid & bloom.contains(jf, r.keys)) for r in rels]
        # all-gather restatement of the §3.1 (n + 1) filter-exchange model
        # (see core.join.filter_exchange_bytes): each of the n + 1 logical
        # filter transfers costs (k - 1) device hops on a k-device mesh
        fbytes = jnp.asarray(num_blocks * bloom.WORDS_PER_BLOCK * 4
                             * (k - 1) * (n_rels + 1), jnp.float32)
    else:
        fbytes = jnp.zeros((), jnp.float32)
    live_counts = jax.lax.psum(jnp.stack([r.count() for r in rels]), axes)

    # One partitioner for ALL relations (cogroup semantics) — matching keys
    # must land on the same device or strata never meet.  cap = local_n can
    # never overflow (a source holds local_n rows total); smaller caps trade
    # memory for counted drops.
    cap = bucket_cap or max(2 * local_n // k, 8)
    shuffled, sent_counts, overflows = [], [], []
    for r in rels:
        out, sent, ovf = shuffle_by_key(r, k, cap, axes, seed + 101)
        shuffled.append(out)
        sent_counts.append(sent)
        overflows.append(ovf)
    my_sent = (sum(sent_counts) * TUPLE_BYTES).astype(jnp.float32)
    device_sent = gather_concat(my_sent[None], axes)             # [k]
    sent_bytes = jnp.sum(device_sent)
    # dropped tuples are counted at the SENDING device (rows beyond the
    # bucket plan never leave it) — surfaced per device, never silent
    my_dropped = jnp.asarray(sum(overflows), jnp.int32)
    device_dropped = gather_concat(my_dropped[None], axes)       # [k]
    bucket_overflow = jnp.sum(device_dropped)

    sorted_rels = [sort_by_key(r) for r in shuffled]
    local_strata = build_strata(sorted_rels, max_strata)
    if merge == "psum":
        # no gather: every stratum keeps its per-device slot, overflow is
        # the summed per-device build overflow (what was actually dropped)
        local_strata = local_strata._replace(
            overflow=jax.lax.psum(local_strata.overflow, axes))
        return DistPrepareOut(sorted_rels, local_strata, local_strata,
                              live_counts, total_counts,
                              local_strata.population,
                              sent_bytes, device_sent, bucket_overflow,
                              device_dropped, fbytes)
    merged = merge_strata(local_strata, axes, max_strata)
    # replicate the (scalar) global overflow into the local strata too, so
    # both pytrees flowing out of a shard_map stage are well-defined
    local_strata = local_strata._replace(overflow=merged.overflow)
    return DistPrepareOut(sorted_rels, local_strata, merged,
                          live_counts, total_counts, merged.population,
                          sent_bytes, device_sent, bucket_overflow,
                          device_dropped, fbytes)


def dist_exact_stage(sorted_rels: Sequence[Relation], local_strata: Strata,
                     merged_strata: Strata, axes: Sequence[str], *,
                     agg: str = "sum", expr: str = "sum"):
    """§3.1.1 exact path: per-device per-stratum sums, merged, finished.

    ``per_stratum_value_sums`` is offset-independent (scatter-add), so each
    device reproduces the single-device per-stratum sums bit-for-bit; the
    merge re-slots them and ``exact_stage_from_sums`` is the same finishing
    arithmetic the single-device stage runs.
    """
    S = merged_strata.keys.shape[0]
    S_k_local = per_stratum_value_sums(sorted_rels, local_strata)
    _, merged = merge_by_key(local_strata.keys,
                             [S_k_local[i] for i in range(S_k_local.shape[0])],
                             axes, S)
    S_k = jnp.stack([jnp.where(merged_strata.valid, m, 0.0) for m in merged])
    return exact_stage_from_sums(S_k, merged_strata, agg=agg, expr=expr)


def dist_sample_stage(sorted_rels: Sequence[Relation], local_strata: Strata,
                      merged_keys: jnp.ndarray, merged_valid: jnp.ndarray,
                      b_merged: jnp.ndarray, b_max: int, seed,
                      axes: Sequence[str], *,
                      agg: str = "sum", dedup: bool = False,
                      confidence: float = 0.95, f_fn=None):
    """Stages 4-6, distributed: local draws, merged stats, canonical finish.

    ``b_merged`` is the host-decided per-stratum sample size in the MERGED
    [S] layout (the same array a single-device driver would produce); it is
    routed back to each device's local slots by key.  Draws are keyed on the
    join key, so the owning device reproduces the single-device per-stratum
    sufficient statistics exactly; the merge re-slots them and the estimator
    runs on a bit-identical [S] stats array.
    """
    S = merged_keys.shape[0]
    b_local = merged_to_local(merged_keys, local_strata,
                              jnp.asarray(b_merged, jnp.float32))
    f = EXPRS["sum"][0] if f_fn is None else f_fn
    sample = sample_edges(sorted_rels, local_strata, b_local, b_max, seed, f)
    st = sample.stats
    _, merged = merge_by_key(
        local_strata.keys,
        [st.valid, st.population, st.n_sampled, st.sum_f, st.sum_f2,
         sample.unique_f, sample.unique_count], axes, S)
    ok = merged[0] & merged_valid
    z = jnp.zeros((), jnp.float32)
    vals = [jnp.where(ok, m, z) for m in merged[1:]]
    mstats = StratumStats(ok, *vals[:4])
    msample = SampleResult(mstats, vals[4], vals[5],
                           jnp.zeros((1, 1)), jnp.zeros((1, 1), bool))
    value, err, cnt, dof = estimate_stage(msample, agg=agg, dedup=dedup,
                                          confidence=confidence)
    return value, err, cnt, dof, mstats


def _psum_parts(parts: SumParts, axes) -> SumParts:
    return SumParts(*[jax.lax.psum(x, axes) for x in parts])


def dist_exact_stage_psum(sorted_rels: Sequence[Relation],
                          local_strata: Strata, axes: Sequence[str], *,
                          agg: str = "sum", expr: str = "sum"):
    """Exact path, paper dataflow: per-device totals merged by one psum.

    Strata are device-complete after the shuffle, so per-device exact
    aggregates ADD across devices — no strata gather, no canonical re-slot.
    Results agree with the gather merge up to float reassociation.
    """
    exact_fn = {"sum": exact_sum_of_sums,
                "product": exact_sum_of_products}[expr]
    est = jax.lax.psum(exact_fn(sorted_rels, local_strata), axes)
    cnt = jax.lax.psum(exact_count(local_strata), axes)
    if agg == "count":
        est = cnt
    elif agg == "avg":
        est = est / jnp.maximum(cnt, 1.0)
    return est, cnt


def dist_sample_stage_psum(sorted_rels: Sequence[Relation],
                           local_strata: Strata, b_local: jnp.ndarray,
                           b_max: int, seed, axes: Sequence[str], *,
                           agg: str = "sum", dedup: bool = False,
                           confidence: float = 0.95, f_fn=None):
    """Stages 4-6, paper dataflow (§3.3-III): local draws, psum'd parts.

    ``b_local`` is the per-stratum budget in THIS device's slot layout
    (the driver decides over the concatenation of per-device strata and
    each device receives its slice).  Every estimator is a sum of
    per-stratum terms and strata are device-complete, so the merge is a
    single psum of the sufficient parts — the cheapest collective the mesh
    offers, at the cost of bit-parity with the single-device pipeline
    (statistical equivalence is what the accuracy gate asserts).
    """
    f = EXPRS["sum"][0] if f_fn is None else f_fn
    sample = sample_edges(sorted_rels, local_strata,
                          jnp.asarray(b_local, jnp.float32), b_max, seed, f)
    st = sample.stats
    cnt = jax.lax.psum(clt_count(st), axes)
    if dedup:
        parts = HTParts(*[jax.lax.psum(x, axes) for x in
                          ht_sum_parts(st, sample.unique_f,
                                       sample.unique_count)])
        est = ht_finish(parts, confidence)
    else:
        parts = _psum_parts(clt_sum_parts(st), axes)
        if agg == "avg":
            est = clt_avg_from(parts, confidence)
        elif agg == "stdev":
            tau2 = jax.lax.psum(clt_sum_parts(second_moment_stats(st)).tau,
                                axes)
            est = clt_stdev_from(parts, tau2, confidence)
        else:
            est = clt_finish(parts, confidence)
    value = cnt if agg == "count" else est.estimate
    err = jnp.zeros_like(est.error_bound) if agg == "count" \
        else est.error_bound
    return value, err, cnt, est.dof, st


def make_distributed_join(mesh: Mesh,
                          *,
                          n_rels: int,
                          join_axes: Sequence[str] = ("data",),
                          mode: str = "sample",      # 'sample' | 'exact'
                          filter_stage: bool = True,  # False -> repartition
                          expr: str = "sum",
                          fp_rate: float = 0.01,
                          sample_fraction: Optional[float] = None,
                          budget: Optional[QueryBudget] = None,
                          cost_model: Optional[CostModel] = None,
                          bucket_cap: Optional[int] = None,
                          max_strata: Optional[int] = None,
                          b_max: int = 1024,
                          confidence: float = 0.95,
                          num_blocks: Optional[int] = None,
                          merge: str = "gather",     # 'gather' | 'psum'
                          seed: int = 0):
    """Build a jitted SPMD join over ``mesh``.

    The returned callable takes ``n_rels`` global Relations (leading dim
    sharded over ``join_axes``) plus a traced ``d_dt`` scalar (measured filter
    latency, feeds the latency cost function) and returns a
    :class:`DistJoinResult` of replicated scalars.

    ``merge='gather'`` (default) reproduces the single-device pipeline
    bit-for-bit; ``merge='psum'`` is the paper's partial-aggregate merge
    (cheapest collectives — what the cluster-scale roofline dry-runs lower).

    Static choices (mode, filtering, capacities) are compile-time — the
    "driver" decides them; re-compilation on change is the Spark-stage
    analogue and keeps every device step a fixed dense program.
    """
    axes = tuple(join_axes)
    k = 1
    for a in axes:
        k *= mesh.shape[a]
    f_fn, _ = EXPRS[expr]
    if budget is not None and budget.latency_s is not None:
        assert cost_model is not None
    assert merge in ("gather", "psum"), merge

    def body(d_dt, *flat):
        rels = [Relation(*flat[3 * i: 3 * i + 3]) for i in range(n_rels)]
        local_n = rels[0].capacity
        S = max_strata or k * (bucket_cap or max(2 * local_n // k, 8))
        prep = dist_prepare_stage(rels, num_blocks, S, seed, axes,
                                  bucket_cap=bucket_cap,
                                  filter_stage=filter_stage, merge=merge)
        live_total = jnp.sum(prep.live_counts).astype(jnp.float32)
        input_total = jnp.sum(prep.total_counts).astype(jnp.float32)
        # psum mode: population is per-device, so the global total is a psum
        total_pop = jnp.sum(prep.population)
        if merge == "psum":
            total_pop = jax.lax.psum(total_pop, axes)
        meters = dict(
            shuffled_tuple_bytes=prep.shuffled_tuple_bytes,
            filter_bytes=prep.filter_bytes,
            live_total=live_total,
            input_total=input_total,
            overlap_fraction=live_total / jnp.maximum(input_total, 1),
            bucket_overflow=prep.bucket_overflow,
            strata_overflow=prep.strata.overflow,
            total_population=total_pop,
            device_shuffled_bytes=prep.device_shuffled_bytes,
            device_dropped=prep.device_dropped,
        )

        if mode == "exact":
            if merge == "psum":
                est, cnt = dist_exact_stage_psum(prep.sorted_rels,
                                                 prep.local_strata, axes,
                                                 agg="sum", expr=expr)
            else:
                est, cnt = dist_exact_stage(prep.sorted_rels,
                                            prep.local_strata, prep.strata,
                                            axes, agg="sum", expr=expr)
            return DistJoinResult(est, jnp.zeros(()), cnt, jnp.zeros(()),
                                  sample_draws=jnp.zeros(()), **meters)

        # --- stage 4: b_i from the budget (§3.2) ---
        if sample_fraction is not None:
            s = jnp.asarray(sample_fraction, jnp.float32)
        elif budget is not None and budget.latency_s is not None:
            s = fraction_for_latency(cost_model, budget.latency_s, d_dt,
                                     total_pop)
        elif budget is not None and budget.error is not None:
            s = jnp.asarray(budget.pilot_fraction, jnp.float32)
        else:
            raise ValueError("sample mode needs a fraction or a budget")

        # --- stage 5: sample during join + merge (§3.3/§3.4) ---
        if merge == "psum":
            # size b_i straight off each device's own strata — every local
            # stratum gets its budget (no global-[S] truncation)
            b_local = _pilot_sizes(prep.local_strata.population, s)
            value, err, cnt, dof, st = dist_sample_stage_psum(
                prep.sorted_rels, prep.local_strata, b_local, b_max,
                seed + 1, axes, agg="sum", confidence=confidence, f_fn=f_fn)
            return DistJoinResult(value, err, cnt, dof,
                                  sample_draws=jax.lax.psum(
                                      jnp.sum(st.n_sampled), axes), **meters)
        b_merged = _pilot_sizes(prep.population, s)
        value, err, cnt, dof, mstats = dist_sample_stage(
            prep.sorted_rels, prep.local_strata, prep.strata.keys,
            prep.strata.valid, b_merged, b_max, seed + 1, axes,
            agg="sum", dedup=False, confidence=confidence, f_fn=f_fn)
        return DistJoinResult(value, err, cnt, dof,
                              sample_draws=jnp.sum(mstats.n_sampled),
                              **meters)

    rel_spec = [P(axes), P(axes), P(axes)] * n_rels
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), *rel_spec),
                   out_specs=DistJoinResult(
                       *([P()] * len(DistJoinResult._fields))),
                   check_rep=False)

    @jax.jit
    def run(rels: Sequence[Relation], d_dt=0.0):
        flat = [x for r in rels for x in (r.keys, r.values, r.valid)]
        return fn(jnp.asarray(d_dt, jnp.float32), *flat)

    return run


def distributed_approx_join(mesh: Mesh, rels: Sequence[Relation],
                            fp_rate: float = 0.01, **kw) -> DistJoinResult:
    """Convenience wrapper: size the filter from the inputs and run once."""
    num_blocks = bloom.num_blocks_for(max(r.capacity for r in rels), fp_rate)
    run = make_distributed_join(mesh, n_rels=len(rels), fp_rate=fp_rate,
                                num_blocks=num_blocks, **kw)
    return run(rels)


# ---------------------------------------------------------------------------
# Serving executables: batched (vmap over query slots) distributed stages,
# one shard_map program per stage so the JoinServer's executable cache keys
# (stage, shape_class, batch) work identically for both backends.
# ---------------------------------------------------------------------------

def _rel_specs(axes, n):
    s = P(None, axes)
    return [Relation(s, s, s) for _ in range(n)]


def _local_strata_spec(axes):
    sharded = P(None, axes)
    return Strata(keys=sharded, valid=sharded,
                  starts=P(None, None, axes), counts=P(None, None, axes),
                  overflow=P())


def make_serve_prepare(mesh: Mesh, axes: Sequence[str], *, n_rels: int,
                       num_blocks: int, max_strata: int,
                       bucket_cap: Optional[int] = None,
                       merge: str = "gather"):
    """Batched distributed prepare: ``(rels_b, words_b, seeds) -> prep``.

    ``rels_b``: list of Relations with fields ``[B, N]``, sharded over
    ``axes`` on the row dim.  ``words_b``: ``[B, n, nb, W]`` replicated
    prebuilt dataset-filter words.  Returns a :class:`DistPrepareOut` whose
    per-device members stay sharded (feed them straight into the sample /
    exact executables) and whose merged members are replicated.

    ``merge='psum'`` skips the strata gather entirely: ``strata`` /
    ``population`` come back SHARDED — the host sees the concatenation of
    per-device strata (device d's slots at columns ``[d*S, (d+1)*S)``),
    which is a complete, disjoint cover of the global strata (every key
    lives on exactly one device after the shuffle), just not in the
    canonical key-sorted order.  Host-side sample sizing works unchanged on
    that layout; the psum sample/exact executables take each device's slice
    back via the same sharding.
    """
    axes = tuple(axes)
    assert merge in ("gather", "psum"), merge

    def per_query(flat, words, seed):
        rels = [Relation(*flat[3 * i: 3 * i + 3]) for i in range(n_rels)]
        return dist_prepare_stage(
            rels, num_blocks, max_strata, seed, axes, bucket_cap=bucket_cap,
            filter_words=[words[i] for i in range(n_rels)], merge=merge)

    def batched(*args):
        return jax.vmap(per_query)(*args)

    flat_spec = tuple(P(None, axes) for _ in range(3 * n_rels))
    strata_spec = _local_strata_spec(axes) if merge == "psum" \
        else Strata(P(), P(), P(), P(), P())
    out_spec = DistPrepareOut(
        sorted_rels=_rel_specs(axes, n_rels),
        local_strata=_local_strata_spec(axes),
        strata=strata_spec,
        live_counts=P(), total_counts=P(),
        population=P(None, axes) if merge == "psum" else P(),
        shuffled_tuple_bytes=P(), device_shuffled_bytes=P(),
        bucket_overflow=P(), device_dropped=P(), filter_bytes=P())
    fn = shard_map(batched, mesh=mesh,
                   in_specs=(flat_spec, P(), P()),
                   out_specs=out_spec, check_rep=False)

    @jax.jit
    def run(rels_b: Sequence[Relation], words_b, seeds):
        flat = tuple(x for r in rels_b for x in (r.keys, r.values, r.valid))
        return fn(flat, words_b, seeds)

    return run


def make_serve_sample(mesh: Mesh, axes: Sequence[str], *, n_rels: int,
                      b_max: int, agg: str, dedup: bool, confidence: float,
                      expr: str):
    """Batched distributed sample+estimate executable."""
    axes = tuple(axes)
    f_fn = EXPRS[expr][0]

    def per_query(flat, lstrata, mkeys, mvalid, b_merged, seed):
        sorted_rels = [Relation(*flat[3 * i: 3 * i + 3])
                       for i in range(n_rels)]
        return dist_sample_stage(sorted_rels, lstrata, mkeys, mvalid,
                                 b_merged, b_max, seed, axes, agg=agg,
                                 dedup=dedup, confidence=confidence, f_fn=f_fn)

    def batched(*args):
        return jax.vmap(per_query)(*args)

    flat_spec = tuple(P(None, axes) for _ in range(3 * n_rels))
    stats_spec = StratumStats(P(), P(), P(), P(), P())
    fn = shard_map(batched, mesh=mesh,
                   in_specs=(flat_spec, _local_strata_spec(axes), P(), P(),
                             P(), P()),
                   out_specs=(P(), P(), P(), P(), stats_spec),
                   check_rep=False)

    @jax.jit
    def run(sorted_rels, lstrata, mkeys, mvalid, b_merged, seeds):
        flat = tuple(x for r in sorted_rels
                     for x in (r.keys, r.values, r.valid))
        return fn(flat, lstrata, mkeys, mvalid, b_merged, seeds)

    return run


def make_serve_exact(mesh: Mesh, axes: Sequence[str], *, n_rels: int,
                     agg: str, expr: str):
    """Batched distributed exact-path executable."""
    axes = tuple(axes)

    def per_query(flat, lstrata, mstrata):
        sorted_rels = [Relation(*flat[3 * i: 3 * i + 3])
                       for i in range(n_rels)]
        return dist_exact_stage(sorted_rels, lstrata, mstrata, axes,
                                agg=agg, expr=expr)

    def batched(*args):
        return jax.vmap(per_query)(*args)

    flat_spec = tuple(P(None, axes) for _ in range(3 * n_rels))
    fn = shard_map(batched, mesh=mesh,
                   in_specs=(flat_spec, _local_strata_spec(axes),
                             Strata(P(), P(), P(), P(), P())),
                   out_specs=(P(), P()), check_rep=False)

    @jax.jit
    def run(sorted_rels, lstrata, mstrata):
        flat = tuple(x for r in sorted_rels
                     for x in (r.keys, r.values, r.valid))
        return fn(flat, lstrata, mstrata)

    return run


def make_serve_sample_psum(mesh: Mesh, axes: Sequence[str], *, n_rels: int,
                           b_max: int, agg: str, dedup: bool,
                           confidence: float, expr: str):
    """Batched psum-merge sample+estimate executable.

    ``b`` arrives in the concatenated per-device layout ``[B, k*S]`` (the
    same layout ``make_serve_prepare(merge='psum')`` emitted its strata in);
    sharding it over ``axes`` hands every device exactly its own slice.
    Estimates come back replicated; the per-stratum stats stay sharded so
    the host reads the same concatenated layout it sized ``b`` in.
    """
    axes = tuple(axes)
    f_fn = EXPRS[expr][0]

    def per_query(flat, lstrata, b_local, seed):
        sorted_rels = [Relation(*flat[3 * i: 3 * i + 3])
                       for i in range(n_rels)]
        return dist_sample_stage_psum(sorted_rels, lstrata, b_local, b_max,
                                      seed, axes, agg=agg, dedup=dedup,
                                      confidence=confidence, f_fn=f_fn)

    def batched(*args):
        return jax.vmap(per_query)(*args)

    flat_spec = tuple(P(None, axes) for _ in range(3 * n_rels))
    sharded = P(None, axes)
    stats_spec = StratumStats(sharded, sharded, sharded, sharded, sharded)
    fn = shard_map(batched, mesh=mesh,
                   in_specs=(flat_spec, _local_strata_spec(axes), sharded,
                             P()),
                   out_specs=(P(), P(), P(), P(), stats_spec),
                   check_rep=False)

    @jax.jit
    def run(sorted_rels, lstrata, b, seeds):
        flat = tuple(x for r in sorted_rels
                     for x in (r.keys, r.values, r.valid))
        return fn(flat, lstrata, b, seeds)

    return run


def make_serve_exact_psum(mesh: Mesh, axes: Sequence[str], *, n_rels: int,
                          agg: str, expr: str):
    """Batched psum-merge exact-path executable."""
    axes = tuple(axes)

    def per_query(flat, lstrata):
        sorted_rels = [Relation(*flat[3 * i: 3 * i + 3])
                       for i in range(n_rels)]
        return dist_exact_stage_psum(sorted_rels, lstrata, axes,
                                     agg=agg, expr=expr)

    def batched(*args):
        return jax.vmap(per_query)(*args)

    flat_spec = tuple(P(None, axes) for _ in range(3 * n_rels))
    fn = shard_map(batched, mesh=mesh,
                   in_specs=(flat_spec, _local_strata_spec(axes)),
                   out_specs=(P(), P()), check_rep=False)

    @jax.jit
    def run(sorted_rels, lstrata):
        flat = tuple(x for r in sorted_rels
                     for x in (r.keys, r.values, r.valid))
        return fn(flat, lstrata)

    return run


def make_serve_filter_build(mesh: Mesh, axes: Sequence[str], *,
                            num_blocks: int):
    """Distributed dataset-filter build: sharded Relation -> replicated words.

    The OR-reduce of per-device partition filters equals the single-device
    build bit-for-bit (scatter-OR is a set union), so cached words from this
    executable are interchangeable with single-device ones.
    """
    axes = tuple(axes)

    def build(keys, valid, seed):
        return or_reduce(bloom.build(keys, valid, num_blocks, seed).words,
                         axes)

    fn = shard_map(build, mesh=mesh, in_specs=(P(axes), P(axes), P()),
                   out_specs=P(), check_rep=False)
    return jax.jit(fn)
