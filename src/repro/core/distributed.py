"""Distributed ApproxJoin over a JAX device mesh (shard_map).

This is the paper's Spark dataflow (Fig. 7) mapped onto SPMD collectives
(DESIGN.md §2):

  stage                     Spark                       here
  ------------------------- --------------------------- ----------------------
  partition filters          Map at each worker          local bloom.build
  dataset filter             treeReduce OR to driver     all_gather + OR fold
                                                         (hierarchical: intra-
                                                         pod first, then pods)
  join filter + broadcast    driver AND + broadcast      local AND (replicated)
  probe + discard            filter() on workers         local probe -> mask
  cogroup shuffle            hash shuffle                bucketize + all_to_all
  sampleDuringJoin           per-key edge sampling       vectorized sampler
  merge partial results      collect at driver           psum of SumParts

Because the shuffle routes every key to exactly one device, strata are
device-complete afterwards and the per-device estimator parts ADD — the merge
is a single psum.  The sampler keys its PRNG on the join key, so the sampled
edges are identical no matter how many devices participated (tested).

Everything is static-shape: the shuffle uses capacity-bounded buckets
(overflow is counted and surfaced — the feedback path for elastic re-runs).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import bloom
from repro.core.budget import QueryBudget
from repro.core.cost import CostModel, fraction_for_latency
from repro.core.estimators import SumParts, clt_finish, clt_sum_parts
from repro.core.hashing import hash2, u32
from repro.core.join import EXPRS, TUPLE_BYTES
from repro.core.relation import Relation, sort_by_key
from repro.core.sampling import (build_strata, exact_count,
                                 exact_sum_of_products, exact_sum_of_sums,
                                 sample_edges)


class DistJoinResult(NamedTuple):
    estimate: jnp.ndarray
    error_bound: jnp.ndarray
    count: jnp.ndarray
    dof: jnp.ndarray
    # meters (replicated scalars)
    shuffled_tuple_bytes: jnp.ndarray   # live tuples that crossed devices
    filter_bytes: jnp.ndarray           # filter all_gather volume (model)
    live_total: jnp.ndarray
    input_total: jnp.ndarray
    overlap_fraction: jnp.ndarray
    bucket_overflow: jnp.ndarray
    strata_overflow: jnp.ndarray
    total_population: jnp.ndarray
    sample_draws: jnp.ndarray


def axis_size(a: str):
    """Size of a mapped mesh axis.  ``jax.lax.axis_size`` only exists in
    newer JAX; ``psum(1, axis)`` is the classic constant-folding idiom."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)


def combined_axis_index(axes: Sequence[str]) -> jnp.ndarray:
    """Linear device index over possibly-multiple mesh axes (major first)."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


def or_reduce(words: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """OR-merge partition filters across the mesh (Alg. 1 reduce phase).

    Hierarchical: reduce over the innermost (fast, intra-pod ICI) axis first,
    then the outer (inter-pod DCN) axis — only one |BF| message crosses the
    slow link per pod, the treeReduce insight restated for a torus.
    """
    for a in reversed(list(axes)):
        gathered = jax.lax.all_gather(words, a)  # [k_a, nb, W]
        words = functools.reduce(jnp.bitwise_or,
                                 [gathered[i] for i in range(gathered.shape[0])])
    return words


def bucketize(rel: Relation, dest: jnp.ndarray, k: int, cap: int):
    """Scatter live rows into k capacity-bounded send buckets.

    Returns (keys [k, cap], values [k, cap], valid [k, cap], overflow []).
    Rows are ranked within their destination by sort; rows beyond ``cap`` are
    dropped and counted (static shapes; same trick as MoE capacity).
    """
    n = rel.capacity
    d = jnp.where(rel.valid, dest, k)                      # invalid -> k
    order = jnp.argsort(d)                                 # stable
    ds = d[order]
    pos = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), ds[1:] != ds[:-1]])
    run_start = jax.lax.cummax(jnp.where(is_start, pos, 0))
    slot = pos - run_start
    ok = (ds < k) & (slot < cap)
    flat = jnp.where(ok, ds * cap + slot, k * cap)
    keys = jnp.zeros((k * cap + 1,), jnp.uint32).at[flat].set(
        rel.keys[order], mode="drop")[:-1].reshape(k, cap)
    vals = jnp.zeros((k * cap + 1,), jnp.float32).at[flat].set(
        rel.values[order], mode="drop")[:-1].reshape(k, cap)
    valid = jnp.zeros((k * cap + 1,), bool).at[flat].set(
        ok, mode="drop")[:-1].reshape(k, cap)
    overflow = jnp.sum(((ds < k) & (slot >= cap)).astype(jnp.int32))
    return keys, vals, valid, overflow


def shuffle_by_key(rel: Relation, k: int, cap: int, axes: Sequence[str],
                   seed: int):
    """Hash-partition a sharded relation so each key lands on one device."""
    dest = (hash2(rel.keys, seed) % u32(k)).astype(jnp.int32)
    me = combined_axis_index(axes)
    sent = rel.valid & (dest != me)
    keys, vals, valid, overflow = bucketize(rel, dest, k, cap)
    # Factor the bucket dim as (size(a0), size(a1), ..., cap) and exchange
    # each factor along ITS mesh axis — the composition is the all_to_all
    # over the combined (major-first) device index.  Exchanging always on
    # the leading dim would route the later axes by SOURCE index (bug).
    sizes = [axis_size(a) for a in axes]
    recv = []
    for x in (keys, vals, valid):
        x = x.reshape(*sizes, cap)
        for i, a in enumerate(axes):
            x = jax.lax.all_to_all(x, a, split_axis=i, concat_axis=i,
                                   tiled=True)
        recv.append(x.reshape(-1, cap))
    out = Relation(recv[0].reshape(-1), recv[1].reshape(-1),
                   recv[2].reshape(-1))
    return out, jnp.sum(sent.astype(jnp.int32)), overflow


def _psum_parts(parts: SumParts, axes) -> SumParts:
    return SumParts(*[jax.lax.psum(x, axes) for x in parts])


def make_distributed_join(mesh: Mesh,
                          *,
                          n_rels: int,
                          join_axes: Sequence[str] = ("data",),
                          mode: str = "sample",      # 'sample' | 'exact'
                          filter_stage: bool = True,  # False -> repartition
                          expr: str = "sum",
                          fp_rate: float = 0.01,
                          sample_fraction: Optional[float] = None,
                          budget: Optional[QueryBudget] = None,
                          cost_model: Optional[CostModel] = None,
                          bucket_cap: Optional[int] = None,
                          max_strata: Optional[int] = None,
                          b_max: int = 1024,
                          confidence: float = 0.95,
                          num_blocks: Optional[int] = None,
                          seed: int = 0):
    """Build a jitted SPMD join over ``mesh``.

    The returned callable takes ``n_rels`` global Relations (leading dim
    sharded over ``join_axes``) plus a traced ``d_dt`` scalar (measured filter
    latency, feeds the latency cost function) and returns a
    :class:`DistJoinResult` of replicated scalars.

    Static choices (mode, filtering, capacities) are compile-time — the
    "driver" decides them; re-compilation on change is the Spark-stage
    analogue and keeps every device step a fixed dense program.
    """
    axes = tuple(join_axes)
    k = 1
    for a in axes:
        k *= mesh.shape[a]
    f_fn, _ = EXPRS[expr]
    exact_fn = {"sum": exact_sum_of_sums,
                "product": exact_sum_of_products}[expr]
    if budget is not None and budget.latency_s is not None:
        assert cost_model is not None

    def body(d_dt, *flat):
        rels = [Relation(*flat[3 * i: 3 * i + 3]) for i in range(n_rels)]
        local_n = rels[0].capacity
        nb = num_blocks
        input_total = jax.lax.psum(
            sum(r.count() for r in rels), axes)

        # --- stage 1: filter (Alg. 1) ---
        if filter_stage:
            ds_words = [or_reduce(bloom.build(r.keys, r.valid, nb, seed).words,
                                  axes) for r in rels]
            jf = bloom.BloomFilter(functools.reduce(jnp.bitwise_and, ds_words),
                                   seed)
            rels = [Relation(r.keys, r.values,
                             r.valid & bloom.contains(jf, r.keys))
                    for r in rels]
            fbytes = jnp.asarray(nb * bloom.WORDS_PER_BLOCK * 4
                                 * (k - 1) * (n_rels + 1), jnp.float32)
        else:
            fbytes = jnp.zeros((), jnp.float32)
        live_total = jax.lax.psum(sum(r.count() for r in rels), axes)

        # --- stage 2: shuffle live tuples so strata are device-complete ---
        # NB: one partitioner for ALL relations (cogroup semantics) — matching
        # keys must land on the same device or strata never meet.
        cap = bucket_cap or max(2 * local_n // k, 8)
        shuffled, sent_counts, overflows = [], [], []
        for i, r in enumerate(rels):
            out, sent, ovf = shuffle_by_key(r, k, cap, axes, seed + 101)
            shuffled.append(out)
            sent_counts.append(sent)
            overflows.append(ovf)
        sent_bytes = jax.lax.psum(sum(sent_counts), axes) * TUPLE_BYTES
        bucket_overflow = jax.lax.psum(sum(overflows), axes)

        # --- stage 3: local group-by ---
        sorted_rels = [sort_by_key(r) for r in shuffled]
        strata = build_strata(sorted_rels, max_strata or k * cap)
        total_pop = jax.lax.psum(jnp.sum(strata.population), axes)
        strata_overflow = jax.lax.psum(strata.overflow, axes)

        meters = dict(
            shuffled_tuple_bytes=sent_bytes.astype(jnp.float32),
            filter_bytes=fbytes,
            live_total=live_total.astype(jnp.float32),
            input_total=input_total.astype(jnp.float32),
            overlap_fraction=live_total / jnp.maximum(input_total, 1),
            bucket_overflow=bucket_overflow,
            strata_overflow=strata_overflow,
            total_population=total_pop,
        )

        if mode == "exact":
            est = jax.lax.psum(exact_fn(sorted_rels, strata), axes)
            cnt = jax.lax.psum(exact_count(strata), axes)
            return DistJoinResult(est, jnp.zeros(()), cnt, jnp.zeros(()),
                                  sample_draws=jnp.zeros(()), **meters)

        # --- stage 4: b_i from the budget (§3.2) ---
        if sample_fraction is not None:
            s = jnp.asarray(sample_fraction, jnp.float32)
        elif budget is not None and budget.latency_s is not None:
            s = fraction_for_latency(cost_model, budget.latency_s, d_dt,
                                     total_pop)
        elif budget is not None and budget.error is not None:
            s = jnp.asarray(budget.pilot_fraction, jnp.float32)
        else:
            raise ValueError("sample mode needs a fraction or a budget")
        b_i = jnp.where(strata.population > 0,
                        jnp.maximum(jnp.ceil(s * strata.population), 1.0), 0.0)

        # --- stage 5: sample during join + psum merge (§3.3/§3.4) ---
        sample = sample_edges(sorted_rels, strata, b_i, b_max, seed + 1, f_fn)
        parts = _psum_parts(clt_sum_parts(sample.stats), axes)
        est = clt_finish(parts, confidence)
        return DistJoinResult(est.estimate, est.error_bound, parts.count,
                              est.dof,
                              sample_draws=parts.n_draws, **meters)

    rel_spec = [P(axes), P(axes), P(axes)] * n_rels
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), *rel_spec),
                   out_specs=DistJoinResult(*([P()] * len(DistJoinResult._fields))))

    @jax.jit
    def run(rels: Sequence[Relation], d_dt=0.0):
        flat = [x for r in rels for x in (r.keys, r.values, r.valid)]
        return fn(jnp.asarray(d_dt, jnp.float32), *flat)

    return run


def distributed_approx_join(mesh: Mesh, rels: Sequence[Relation],
                            fp_rate: float = 0.01, **kw) -> DistJoinResult:
    """Convenience wrapper: size the filter from the inputs and run once."""
    num_blocks = bloom.num_blocks_for(max(r.capacity for r in rels), fp_rate)
    run = make_distributed_join(mesh, n_rels=len(rels), fp_rate=fp_rate,
                                num_blocks=num_blocks, **kw)
    return run(rels)
