"""Query budget interface (paper §2).

The paper's query surface is an aggregation over an n-way equi-join plus a
budget clause:

    SELECT SUM(R1.V + R2.V + ... + Rn.V)
    FROM R1, ..., Rn WHERE R1.A = ... = Rn.A
    WITHIN 120 SECONDS            -- latency budget, or
    ERROR 0.01 CONFIDENCE 95%     -- error budget

:class:`QueryBudget` is the structured form; :func:`parse_budget` accepts the
paper's textual clause for the examples.  ``None`` budget = exact join.
"""

from __future__ import annotations

import re
from typing import NamedTuple, Optional


class QueryBudget(NamedTuple):
    latency_s: Optional[float] = None   # WITHIN d SECONDS
    error: Optional[float] = None       # ERROR e
    confidence: float = 0.95            # CONFIDENCE c%
    pilot_fraction: float = 0.1         # first-run fraction when sigma unknown

    @property
    def is_exact(self) -> bool:
        return self.latency_s is None and self.error is None


_WITHIN = re.compile(r"WITHIN\s+([0-9.]+)\s*SECONDS?", re.I)
_ERROR = re.compile(r"ERROR\s+([0-9.]+)(?:\s+CONFIDENCE\s+([0-9.]+)\s*%)?",
                    re.I)


def parse_budget(clause: str) -> QueryBudget:
    """Parse the paper's budget clause text into a QueryBudget."""
    latency = error = None
    confidence = 0.95
    m = _WITHIN.search(clause)
    if m:
        latency = float(m.group(1))
    m = _ERROR.search(clause)
    if m:
        error = float(m.group(1))
        if m.group(2):
            confidence = float(m.group(2)) / 100.0
    if latency is None and error is None and clause.strip():
        raise ValueError(f"unrecognized budget clause: {clause!r}")
    return QueryBudget(latency, error, confidence)
