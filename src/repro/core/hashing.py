"""Integer hashing shared by the Bloom sketch, the sampler, and the Pallas
kernels.

Everything here is uint32 arithmetic (wrap-around multiply / xor / shift) so
the pure-jnp reference paths and the Pallas kernel paths produce bit-identical
results, which the kernel tests assert.

The two primitives are the murmur3 finalizer (``fmix32``) for key hashing and
a counter-based stateless PRNG (``counter_hash``) used for sampling-during-join
draws: ``draw = fmix32(seed ^ fmix32(stratum ^ fmix32(counter)))``.  Stateless
draws are what make the sampler deterministic, replayable after preemption and
coordination-free across devices (see DESIGN.md §2).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Parquet/Impala split-block Bloom filter salts (8 odd constants, one per
# 32-bit lane of the 256-bit block).
SALT = (
    0x47B6137B,
    0x44974D91,
    0x8824AD5B,
    0xA2B7289D,
    0x705495C7,
    0x2DF1424B,
    0x9EFC4947,
    0x5C6BFB31,
)

GOLDEN = 0x9E3779B1  # 2^32 / phi, odd — used for cheap secondary mixing.

# NB: scalar literals are np.uint32, NOT jnp.uint32 — numpy scalars fold into
# the jaxpr as literals, while jnp scalars become captured device constants,
# which Pallas kernels reject ("captures constants ... pass them as inputs").
_U = np.uint32


def u32(x):
    """Cast to uint32 (wrapping); Python ints become numpy scalar literals."""
    if isinstance(x, (int, np.integer)):
        return np.uint32(x & 0xFFFFFFFF)
    return jnp.asarray(x).astype(jnp.uint32)


def fmix32(h: jnp.ndarray) -> jnp.ndarray:
    """Murmur3 32-bit finalizer — a full-avalanche bijection on uint32."""
    if isinstance(h, (int, np.integer)):  # pure-host path (e.g. seed mixing)
        x = int(h) & 0xFFFFFFFF
        x ^= x >> 16
        x = (x * 0x85EBCA6B) & 0xFFFFFFFF
        x ^= x >> 13
        x = (x * 0xC2B2AE35) & 0xFFFFFFFF
        x ^= x >> 16
        return np.uint32(x)
    h = u32(h)
    h = h ^ (h >> _U(16))
    h = h * _U(0x85EBCA6B)
    h = h ^ (h >> _U(13))
    h = h * _U(0xC2B2AE35)
    h = h ^ (h >> _U(16))
    return h


def hash2(key: jnp.ndarray, seed: int | jnp.ndarray) -> jnp.ndarray:
    """Seeded hash: fmix32(key ^ fmix32(seed * GOLDEN))."""
    if isinstance(seed, (int, np.integer)):
        s = fmix32((int(seed) * GOLDEN) & 0xFFFFFFFF)
    else:
        s = fmix32(u32(seed) * _U(GOLDEN))
    return fmix32(u32(key) ^ s)


def counter_hash(seed, stratum, counter, lane) -> jnp.ndarray:
    """Stateless PRNG draw for (stratum, counter, lane) under ``seed``.

    ``lane`` distinguishes the relation side of the bipartite edge draw
    (0 = left endpoint, 1 = right endpoint, ... for multi-way joins).
    All arguments broadcast.
    """
    h = fmix32(u32(counter) * _U(GOLDEN) + u32(lane))
    s = u32(stratum)
    if isinstance(s, np.uint32):  # host-scalar path: avoid np overflow warns
        s = np.uint32((int(s) * 0x85EBCA6B) & 0xFFFFFFFF)
    else:
        s = s * _U(0x85EBCA6B)
    h = fmix32(h ^ s)
    return fmix32(h ^ u32(seed))


def bounded(h: jnp.ndarray, bound: jnp.ndarray) -> jnp.ndarray:
    """Map a uint32 hash into [0, bound) (bound >= 1, int32).

    Plain modulo; the bias is O(bound / 2^32), negligible for the stratum
    sizes we draw from (documented in DESIGN.md).
    """
    b = jnp.maximum(u32(bound), _U(1))
    return (h % b).astype(jnp.int32)
