"""The paper's comparison systems (§5/§6, Fig. 1) + shuffle-volume models
(Appendix A.1, Eq. 18-26).

Implemented baselines:

* ``native_join``     — Spark RDD join: cogroup (no pre-filter) + full
                        cross-product.  Exact; meters the full shuffle and the
                        full cross-product op count (the memory blow-up the
                        paper reports shows up here as the op count).
* ``repartition_join``— hash-shuffle all tuples, local join.  Exact.
* ``broadcast_join``  — smaller inputs replicated to every node.  Exact.
* ``prejoin_sampling``— Fig. 1 "sample inputs, then join": Bernoulli(p) per
                        input, join the samples, scale by p^-n.  Fast but
                        statistically broken for stratified outputs (loses
                        strata; variance blows up) — reproduced on purpose.
* ``postjoin_sampling``— Fig. 1 "join, then sample": exact join materialized
                        (op count = full cross product), stratified sample of
                        the output.  Accurate but slow; also the SnappyData
                        comparator shape for Fig. 12.

All return :class:`BaselineResult` carrying the estimate and the meters the
paper plots (shuffled bytes, cross-product ops).  The *volume models* are the
closed-form Eq. 18-26 used by the Fig. 4 / Fig. 14 benchmarks.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp

from repro.core.estimators import Estimate, clt_sum
from repro.core.hashing import counter_hash, u32
from repro.core.join import EXPRS, TUPLE_BYTES
from repro.core.relation import Relation, sort_by_key
from repro.core.sampling import build_strata, exact_count, sample_edges


class BaselineResult(NamedTuple):
    estimate: jnp.ndarray
    error_bound: jnp.ndarray
    count: jnp.ndarray              # join-output cardinality it processed
    shuffled_bytes: jnp.ndarray     # modeled shuffle volume for this plan
    cross_product_ops: jnp.ndarray  # pair evaluations performed


# --- Appendix A.1 closed-form shuffle-volume models (bytes) -----------------

def volume_broadcast(sizes_bytes: Sequence[float], k: int) -> float:
    """Eq. 18: all smaller inputs replicated to the k-1 other nodes."""
    smaller = sorted(sizes_bytes)[:-1]
    return float(sum(smaller) * (k - 1))


def volume_repartition(sizes_bytes: Sequence[float], k: int) -> float:
    """Eq. 21: every tuple moves with probability (k-1)/k."""
    return float(sum(sizes_bytes) * (k - 1) / k)


def volume_approxjoin(live_bytes: Sequence[float], filter_bytes: float,
                      k: int) -> float:
    """Eq. 24: n+1 filter broadcasts + only live tuples repartitioned."""
    n = len(live_bytes)
    return float(filter_bytes * (k - 1) * (n + 1)
                 + sum(live_bytes) * (k - 1) / k)


# --- exact baselines ---------------------------------------------------------

def _exact(rels: Sequence[Relation], expr: str, max_strata=None):
    sorted_rels = [sort_by_key(r) for r in rels]
    strata = build_strata(sorted_rels, max_strata or rels[0].capacity)
    _, exact_fn = EXPRS[expr]
    return exact_fn(sorted_rels, strata), exact_count(strata), strata


def native_join(rels: Sequence[Relation], *, expr: str = "sum",
                k: int = 1) -> BaselineResult:
    est, cnt, _ = _exact(rels, expr)
    sizes = [float(r.count()) * TUPLE_BYTES for r in rels]
    return BaselineResult(est, jnp.zeros(()), cnt,
                          jnp.asarray(volume_repartition(sizes, max(k, 2))),
                          cnt)


def repartition_join(rels: Sequence[Relation], *, expr: str = "sum",
                     k: int = 1) -> BaselineResult:
    est, cnt, _ = _exact(rels, expr)
    sizes = [float(r.count()) * TUPLE_BYTES for r in rels]
    return BaselineResult(est, jnp.zeros(()), cnt,
                          jnp.asarray(volume_repartition(sizes, max(k, 2))),
                          cnt)


def broadcast_join(rels: Sequence[Relation], *, expr: str = "sum",
                   k: int = 1) -> BaselineResult:
    est, cnt, _ = _exact(rels, expr)
    sizes = [float(r.count()) * TUPLE_BYTES for r in rels]
    return BaselineResult(est, jnp.zeros(()), cnt,
                          jnp.asarray(volume_broadcast(sizes, max(k, 2))),
                          cnt)


# --- sampling baselines (Fig. 1) ---------------------------------------------

def prejoin_sampling(rels: Sequence[Relation], fraction: float, *,
                     expr: str = "sum", seed: int = 0,
                     k: int = 1) -> BaselineResult:
    """Sample each input Bernoulli(p), join the samples, scale by p^-n.

    This is the strategy the paper shows loses an order of magnitude of
    accuracy (Fig. 1): strata with few tuples vanish from the sample and the
    scale-up amplifies whatever survives.
    """
    p_u32 = u32(min(max(fraction, 0.0), 1.0) * 0xFFFFFFFF)
    sampled = []
    for i, r in enumerate(rels):
        rows = jnp.arange(r.capacity, dtype=jnp.uint32)
        keep = counter_hash(seed + 17 * i, r.keys, rows, 3) < p_u32
        sampled.append(Relation(r.keys, r.values, r.valid & keep))
    est, cnt, _ = _exact(sampled, expr)
    scale = (1.0 / max(fraction, 1e-9)) ** len(rels)
    sizes = [float(r.count()) * TUPLE_BYTES for r in sampled]
    return BaselineResult(est * scale, jnp.zeros(()), cnt * scale,
                          jnp.asarray(volume_repartition(sizes, max(k, 2))),
                          cnt)


def postjoin_sampling(rels: Sequence[Relation], fraction: float, *,
                      expr: str = "sum", seed: int = 0, b_max: int = 4096,
                      max_strata=None, k: int = 1,
                      confidence: float = 0.95) -> BaselineResult:
    """Exact join first, stratified sampleByKey after (Fig. 1 "accurate but
    slow"; also the SnappyData-shaped comparator of Fig. 12).

    Statistically equals our sampler with b_i = s*B_i over unfiltered inputs;
    the meters tell the real story: full shuffle + full cross-product ops.
    """
    f_fn, _ = EXPRS[expr]
    sorted_rels = [sort_by_key(r) for r in rels]
    strata = build_strata(sorted_rels, max_strata or rels[0].capacity)
    b_i = jnp.ceil(fraction * strata.population)
    sample = sample_edges(sorted_rels, strata, b_i, b_max, seed, f_fn)
    est: Estimate = clt_sum(sample.stats, confidence)
    cnt = exact_count(strata)
    sizes = [float(r.count()) * TUPLE_BYTES for r in rels]
    return BaselineResult(est.estimate, est.error_bound, cnt,
                          jnp.asarray(volume_repartition(sizes, max(k, 2))),
                          cnt)  # ops: the full cross product was materialized
