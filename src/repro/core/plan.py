"""Query-plan IR + compiler: multi-way join DAGs as first-class objects.

The paper's operator is an aggregate over an *n*-way equi-join within a
budget (§2, §4).  This module lifts that one level up the stack, the way
the Conclave snippet does for Spark codegen: a :class:`Plan` is a small DAG
of :class:`PlanNode` s, each naming its inputs (registered datasets or
earlier nodes), its aggregate, and its own error/latency budget.

The compiler's central move is **flattening**: a node that references
another node imports that node's *leaf dataset set*, so every node compiles
to a single fused n-way ApproxJoin stage with the cascaded Bloom
intersection (:func:`repro.core.bloom.intersect_all`) of ALL leaf filters
pushed down before any shuffle — a binary join tree never materializes an
intermediate.  On an equi-join chain ``(A ⋈ B) ⋈ C`` the fused 3-way stage
is semantically the same query, and pushing the full 3-way AND below the
shuffle strictly dominates the 2-way-at-a-time filter a binary tree can
apply (quantified by :func:`node_bytes_model`, asserted in
``benchmarks/serve_bench.py --plans``).

Budget propagation rule: a node's budget/aggregate governs exactly its own
fused stage.  A referenced node is *also* an output — it still executes its
own aggregate under its own budget as a separate stage — referencing it
only donates its leaf set to the referencing node.

Execution lives in the engine (``JoinServer.compile_plan`` /
``submit_plan``): each compiled node becomes an ordinary engine request
over the concatenated leaf relations, so plan results are bit-identical to
the equivalent composed direct ``approx_join`` calls by construction.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import bloom
from repro.core.budget import QueryBudget
from repro.core.join import TUPLE_BYTES, filter_exchange_bytes
from repro.core.relation import Relation, sort_by_key
from repro.core.sampling import build_strata, exact_count


@dataclass(frozen=True)
class PlanNode:
    """One join+aggregate in the DAG.

    ``inputs`` name registered datasets or EARLIER nodes of the same plan
    (node names shadow dataset names, so a plan can safely reuse a dataset's
    name for a derived node).  Forward references are rejected — the node
    order is the topological order, so the DAG property holds by
    construction.
    """

    name: str
    inputs: Tuple[str, ...]
    budget: QueryBudget = QueryBudget()
    agg: str = "sum"
    expr: str = "sum"
    max_strata: Optional[int] = None
    b_max: int = 2048
    dedup: bool = False
    use_kernels: bool = False
    fp_rate: float = 0.01

    def __post_init__(self):
        object.__setattr__(self, "inputs", tuple(self.inputs))
        if not self.name:
            raise ValueError("PlanNode needs a non-empty name")
        if "/" in self.name:
            raise ValueError(
                f"PlanNode name {self.name!r} may not contain '/' (reserved "
                "for the engine's plan-id/node-id query ids)")
        if len(self.inputs) < 1:
            raise ValueError(f"PlanNode {self.name!r} has no inputs")

    def signature(self) -> tuple:
        return (self.name, self.inputs, tuple(self.budget), self.agg,
                self.expr, self.max_strata, self.b_max, self.dedup,
                self.use_kernels, self.fp_rate)


@dataclass(frozen=True)
class Plan:
    """An ordered DAG of :class:`PlanNode` s (order = topological order)."""

    nodes: Tuple[PlanNode, ...]

    def __post_init__(self):
        object.__setattr__(self, "nodes", tuple(self.nodes))
        if not self.nodes:
            raise ValueError("Plan needs at least one node")
        seen: set = set()
        for node in self.nodes:
            if node.name in seen:
                raise ValueError(f"duplicate plan node name {node.name!r}")
            for inp in node.inputs:
                if inp == node.name:
                    raise ValueError(
                        f"plan node {node.name!r} references itself")
            seen.add(node.name)

    def signature(self) -> tuple:
        """Hashable identity for the engine's compiled-plan cache."""
        return tuple(n.signature() for n in self.nodes)

    def hierarchy(self) -> Dict[str, List[str]]:
        """Node name -> directly referenced earlier-node names.

        The plan's span hierarchy: the engine's tracer records one query
        span per node and tags the plan's submit instant with these edges,
        so trace consumers (``launch/trace_dump.py``) can nest each node's
        span under the nodes that reference it.  Inputs that resolve as
        datasets (no earlier node of that name) are leaves and excluded.
        """
        earlier: set = set()
        edges: Dict[str, List[str]] = {}
        for node in self.nodes:
            edges[node.name] = [i for i in node.inputs if i in earlier]
            earlier.add(node.name)
        return edges

    def leaf_inputs(self, name: str) -> Tuple[str, ...]:
        """Flattened, order-preserving leaf dataset set of a node.

        Only EARLIER nodes resolve as node references (node order is the
        topological order); a same-named later node reads as a dataset
        name, so the expansion can never cycle.
        """
        earlier: Dict[str, PlanNode] = {}
        target = None
        for n in self.nodes:
            if n.name == name:
                target = n
                break
            earlier[n.name] = n
        if target is None:
            raise ValueError(f"unknown plan node {name!r}")

        def leaves(node: PlanNode) -> List[str]:
            out: List[str] = []
            for inp in node.inputs:
                ref = earlier.get(inp)
                if ref is not None and ref is not node:
                    out.extend(leaves(ref))
                else:
                    out.append(inp)
            return out

        seen: set = set()
        flat: List[str] = []
        for leaf in leaves(target):
            if leaf not in seen:
                seen.add(leaf)
                flat.append(leaf)
        return tuple(flat)


class CompiledNode(NamedTuple):
    node: PlanNode
    datasets: Tuple[str, ...]   # flattened leaf dataset names
    n_rels: int                 # relations after dataset expansion


class CompiledPlan(NamedTuple):
    plan: Plan
    nodes: Tuple[CompiledNode, ...]
    # per node name: modeled shuffle bytes with full cascaded pushdown vs a
    # left-deep binary tree (2-way filters only), plus the live overlap
    # fraction (feeds psum bucket planning as the request's overlap hint)
    bytes_model: Dict[str, dict]


def compile_plan(plan: Plan, datasets: Mapping[str, Sequence[Relation]], *,
                 model_bytes: bool = True, model_seed: int = 0,
                 ) -> CompiledPlan:
    """Resolve, flatten, and cost a plan against registered datasets.

    ``datasets`` maps each registered dataset name to its relation list (a
    registered dataset may hold several relations — its full join input
    set); a leaf contributes *all* its relations to the fused stage, in
    registration order.  Raises typed errors on unknown names and on fused
    stages with fewer than two relations.
    """
    earlier: set = set()
    compiled: List[CompiledNode] = []
    model: Dict[str, dict] = {}
    for node in plan.nodes:
        for inp in node.inputs:
            if inp not in earlier and inp not in datasets:
                raise ValueError(
                    f"plan node {node.name!r} input {inp!r} is neither an "
                    f"earlier plan node nor a registered dataset "
                    f"(known datasets: {sorted(datasets)})")
        earlier.add(node.name)
        leaf_names = plan.leaf_inputs(node.name)
        rels: List[Relation] = []
        for leaf in leaf_names:
            rels.extend(datasets[leaf])
        if len(rels) < 2:
            raise ValueError(
                f"plan node {node.name!r} fuses to {len(rels)} relation(s); "
                "a join stage needs at least two")
        compiled.append(CompiledNode(node, leaf_names, len(rels)))
        if model_bytes:
            model[node.name] = node_bytes_model(
                rels, fp_rate=node.fp_rate, seed=model_seed)
    return CompiledPlan(plan, tuple(compiled), model)


def node_bytes_model(rels: Sequence[Relation], *, fp_rate: float = 0.01,
                     seed: int = 0) -> dict:
    """Modeled shuffle bytes for a fused n-way stage vs a binary join tree.

    ``bytes_pushdown`` charges the paper's §3.1 model for the fused stage:
    every input filtered by the full n-way AND before the shuffle, plus one
    (n + 1) filter exchange.  ``bytes_binary`` models the same query as a
    left-deep binary tree WITHOUT cascaded pushdown: each 2-way stage can
    only AND the two filters it sees, ships its intermediate join result
    into the next stage, and pays its own (2 + 1) filter exchange.  The
    intermediate cardinalities are exact (strata product counts over the
    filtered prefix), not sampled — this is a planning model, computed once
    per compiled plan, never on the serve hot path.

    The binary model is deliberately conservative (it under-counts the
    baseline): stage j's fresh input is charged at its *full-AND* live count
    — fewer rows than the 2-way filter a real binary engine could achieve —
    so ``bytes_pushdown < bytes_binary`` is a lower bound on the real win.
    """
    n = len(rels)
    cap = max(r.capacity for r in rels)
    num_blocks = bloom.num_blocks_for(cap, fp_rate)
    fbytes = num_blocks * bloom.WORDS_PER_BLOCK * 4
    filters = [bloom.build(r.keys, r.valid, num_blocks, seed) for r in rels]
    total = sum(int(jax.device_get(r.count())) for r in rels)

    def live_under(filter_idxs, j):
        """Rows of rels[j] surviving the AND of the named filters."""
        jf = bloom.intersect_all([filters[i] for i in filter_idxs])
        keep = rels[j].valid & bloom.contains(jf, rels[j].keys)
        return int(jax.device_get(jnp.sum(keep)))

    every = tuple(range(n))
    live_full = [live_under(every, j) for j in range(n)]
    bytes_pushdown = (sum(live_full) * TUPLE_BYTES
                      + int(filter_exchange_bytes(n, fbytes)))

    def prefix_join_count(j):
        """|rels[0] ⋈ ... ⋈ rels[j-1]| restricted to keys live under the
        first j+1 filters — the intermediate a binary tree ships into
        stage j after that stage's own 2-way filter."""
        jf = bloom.intersect_all(filters[: j + 1])
        live = [Relation(r.keys, r.values,
                         r.valid & bloom.contains(jf, r.keys))
                for r in rels[:j]]
        strata = build_strata([sort_by_key(r) for r in live], cap)
        return int(jax.device_get(exact_count(strata)))

    bytes_binary = 0
    for j in range(1, n):
        left = (live_under((0, 1), 0) if j == 1 else prefix_join_count(j))
        right = live_under(tuple(range(j + 1)), j)
        bytes_binary += ((left + right) * TUPLE_BYTES
                         + int(filter_exchange_bytes(2, fbytes)))

    return dict(
        n=n, filter_bytes=fbytes,
        live_counts=live_full, total_count=total,
        overlap=sum(live_full) / max(total, 1),
        bytes_pushdown=bytes_pushdown, bytes_binary=bytes_binary,
        reduction_x=bytes_binary / max(bytes_pushdown, 1),
    )
