"""ApproxJoin — the paper's operator, end to end (single device).

Pipeline (paper Fig. 2/7):

  1. build a Bloom filter per input                         (§3.1, Alg. 1)
  2. AND them into the join filter, probe, drop dead tuples (§3.1)
  3. group surviving tuples into strata (sort + segments)   (§3.3)
  4. decide: exact join affordable? else pick b_i            (§3.1.1, §3.2)
  5. stratified edge-sampling during the join               (§3.3, Alg. 2)
  6. estimate + error bound (CLT or Horvitz-Thompson)       (§3.4)

The orchestration lives in Python (the Spark "driver" role); every stage is a
jittable pure function (the "executor" role).  The distributed version with
identical semantics is ``core/distributed.py`` (shard_map over the mesh).
"""

from __future__ import annotations

import time
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom
from repro.core.budget import QueryBudget
from repro.core.cost import (CostModel, SigmaRegistry, sizes_for_error,
                             sizes_for_latency)
from repro.core.estimators import (Estimate, StratumStats, clt_avg, clt_count,
                                   clt_stdev, clt_sum, horvitz_thompson_sum)
from repro.core.relation import Relation, sort_by_key
from repro.core.sampling import (SampleResult, Strata, build_strata,
                                 default_f, exact_count, exact_sum_of_products,
                                 exact_sum_of_products_from,
                                 exact_sum_of_sums, exact_sum_of_sums_from,
                                 sample_edges)

TUPLE_BYTES = 8  # uint32 key + float32 value


def filter_exchange_bytes(n: int, fbytes) -> jnp.ndarray:
    """§3.1 filter-exchange transfer model: bytes moved to build + ship the
    join filter for an n-way join.

    The n per-dataset filters travel to the merge site (n transfers) and the
    AND-merged join filter is broadcast back to the workers; as in Spark's
    torrent broadcast the paper charges the broadcast once, not per-worker —
    hence (n + 1) filter-sized transfers for every n >= 2.  The distributed
    engine's all-gather merge (``distributed.py``) restates the same model as
    ``(k - 1) * (n + 1)`` per-device transfers on a k-device mesh: each of
    the n + 1 logical transfers costs (k - 1) device hops.
    """
    return fbytes * (n + 1)


class JoinDiagnostics(NamedTuple):
    total_counts: jnp.ndarray       # [n] tuples per input
    live_counts: jnp.ndarray        # [n] tuples surviving the join filter
    overlap_fraction: jnp.ndarray   # paper §3.1.1 definition
    filter_bytes: int               # |BF| bytes (per filter)
    shuffled_bytes_filtered: jnp.ndarray   # live tuples + filters (ours)
    shuffled_bytes_repartition: jnp.ndarray  # all tuples (baseline model)
    num_strata: jnp.ndarray
    strata_overflow: jnp.ndarray
    total_population: jnp.ndarray   # sum_i B_i (join output size)
    sample_draws: jnp.ndarray       # sum_i b_i actually drawn
    d_filter_s: float               # measured wall time of stage 1-2
    sampled: bool                   # False -> exact path was taken
    dist_dropped_tuples: float = 0.0  # mesh shuffle rows beyond bucket_cap


class JoinResult(NamedTuple):
    estimate: jnp.ndarray
    error_bound: jnp.ndarray
    count: jnp.ndarray              # exact join-output cardinality
    dof: jnp.ndarray
    diagnostics: JoinDiagnostics
    stats: Optional[StratumStats] = None
    strata: Optional[Strata] = None


EXPRS: dict = {
    "sum": (default_f, exact_sum_of_sums),
    "product": (lambda vs: jnp.prod(jnp.stack(vs), axis=0),
                exact_sum_of_products),
}


def build_join_filter(rels: Sequence[Relation], num_blocks: int,
                      seed: int) -> bloom.BloomFilter:
    """Alg. 1: per-input filters, AND-merged into the join filter."""
    filters = [bloom.build(r.keys, r.valid, num_blocks, seed) for r in rels]
    return bloom.intersect_all(filters)


def filter_relations(rels: Sequence[Relation],
                     join_filter: bloom.BloomFilter) -> list[Relation]:
    """Probe + discard (the shuffle-avoidance step)."""
    return [Relation(r.keys, r.values,
                     r.valid & bloom.contains(join_filter, r.keys))
            for r in rels]


# ---------------------------------------------------------------------------
# Stage functions.  Each is a pure function of arrays + static config, so the
# serving engine (runtime/join_serve.py) can jit(vmap(...)) them across a
# batch of same-shape queries; approx_join below composes the same functions
# eagerly, which keeps the two paths bit-identical by construction.
# ---------------------------------------------------------------------------

class PrepareOut(NamedTuple):
    """Stages 1-3 output: live sorted relations + strata + row counts.

    ``population`` duplicates ``strata.population`` as a plain array: the
    Strata properties reduce over fixed axes, so they cannot be read off a
    *batched* Strata pytree — the serving engine needs the per-example value
    computed inside the vmapped stage.
    """

    sorted_rels: list[Relation]
    strata: Strata
    live_counts: jnp.ndarray   # int32 [n]
    total_counts: jnp.ndarray  # int32 [n]
    population: jnp.ndarray    # f32   [S]


def _prepare_tail(live: Sequence[Relation], rels: Sequence[Relation],
                  max_strata: int) -> PrepareOut:
    """Shared sort/group-by tail of every prepare variant (jnp and kernel,
    single and batched) — one copy, so the bit-parity contract between the
    variants cannot drift."""
    sorted_rels = [sort_by_key(r) for r in live]
    strata = build_strata(sorted_rels, max_strata)
    return PrepareOut(sorted_rels, strata,
                      jnp.stack([r.count() for r in live]),
                      jnp.stack([r.count() for r in rels]),
                      strata.population)


def prepare_stage(rels: Sequence[Relation], num_blocks: int, max_strata: int,
                  seed) -> PrepareOut:
    """Filter build/AND/probe, sort, group-by — one jit/vmap-friendly pass.

    ``seed`` may be a traced array (per-query seeds batch under vmap) —
    :func:`bloom.intersect_all` checks seed equality only on concrete ints,
    so the cascaded AND-merge routes through it on tracers too.
    """
    filters = [bloom.build(r.keys, r.valid, num_blocks, seed) for r in rels]
    join_filter = bloom.intersect_all(filters)
    return _prepare_tail(filter_relations(rels, join_filter), rels,
                         max_strata)


def prepare_stage_pre(rels: Sequence[Relation], filter_words: jnp.ndarray,
                      max_strata: int, seed) -> PrepareOut:
    """:func:`prepare_stage` with PREBUILT per-input filter words.

    ``filter_words`` is ``[n_inputs, num_blocks, W]`` — the packed words of
    each input's dataset filter, e.g. from the JoinServer's per-dataset cache
    (built once per ``(num_blocks, seed)``, reused every step).  Everything
    downstream of the build is identical to :func:`prepare_stage`, so the
    results are bit-identical to building from scratch.
    """
    if filter_words.shape[0] != len(rels):
        raise ValueError(
            f"prepare_stage_pre: {filter_words.shape[0]} prebuilt filters "
            f"for {len(rels)} inputs")
    join_filter = bloom.intersect_all(
        [bloom.BloomFilter(filter_words[i], seed)
         for i in range(filter_words.shape[0])])
    return _prepare_tail(filter_relations(rels, join_filter), rels,
                         max_strata)


def prepare_stage_kernels(rels: Sequence[Relation], num_blocks: int,
                          max_strata: int, seed, *,
                          filter_words: Optional[jnp.ndarray] = None,
                          interpret: bool = True) -> PrepareOut:
    """Kernel-backed :func:`prepare_stage` / :func:`prepare_stage_pre`.

    Same stage contract, Pallas execution: per-input filters come from the
    hash kernel + scatter-OR commit (or arrive PREBUILT as ``filter_words``
    ``[n_inputs, num_blocks, W]`` — e.g. the serving engine's per-dataset
    cache), the AND-merge happens on the packed words, and the probe runs
    through the VMEM-resident filter kernel.  ``seed`` is the FILTER seed
    and may be a traced array (the engine's decoupled ``filter_seed``);
    results are bit-identical to the jnp stages — the kernels share the
    uint32 hash math (asserted in ``tests/test_kernels.py``).
    """
    from repro.kernels import ops as kops
    if filter_words is None:
        words = bloom.intersect_all(
            [kops.build_filter(r.keys, r.valid, num_blocks, seed,
                               interpret=interpret) for r in rels]).words
    else:
        if filter_words.shape[0] != len(rels):
            raise ValueError(
                f"prepare_stage_kernels: {filter_words.shape[0]} prebuilt "
                f"filters for {len(rels)} inputs")
        words = bloom.intersect_all(
            [bloom.BloomFilter(filter_words[i], seed)
             for i in range(filter_words.shape[0])]).words
    live = [Relation(r.keys, r.values,
                     r.valid & kops.probe_filter(words, r.keys, seed,
                                                 interpret=interpret))
            for r in rels]
    return _prepare_tail(live, rels, max_strata)


def prepare_stage_kernels_batched(rels: Sequence[Relation],
                                  filter_words: jnp.ndarray,
                                  max_strata: int, seeds, *,
                                  interpret: bool = True) -> PrepareOut:
    """Slot-batched kernel prepare: the engine's fused-batch counterpart.

    ``rels`` carry slot-stacked ``[B, N]`` arrays, ``filter_words`` is
    ``[B, n_inputs, num_blocks, W]`` (per-slot prebuilt words — the engine
    always has them, from its per-dataset cache or a streaming window's
    OR-merge), ``seeds`` is uint32 ``[B]``.  The AND-merge and the probe run
    through the stacked-filter kernel over a ``(batch_slot, key_block)``
    grid — NOT vmap: the probe kernel owns the slot dimension — and the
    sort/group-by tail vmaps per slot exactly like the jnp path, so every
    slot is bit-identical to :func:`prepare_stage_kernels` on its own.
    """
    from repro.kernels import ops as kops
    if filter_words.shape[1] != len(rels):
        raise ValueError(
            f"prepare_stage_kernels_batched: {filter_words.shape[1]} "
            f"prebuilt filters for {len(rels)} inputs")
    jwords = bloom.intersect_all(
        [bloom.BloomFilter(filter_words[:, i], seeds)
         for i in range(filter_words.shape[1])]).words
    live = [Relation(r.keys, r.values,
                     r.valid & kops.probe_filter_batched(
                         jwords, r.keys, seeds, interpret=interpret))
            for r in rels]
    return jax.vmap(
        lambda live_i, rels_i: _prepare_tail(live_i, rels_i, max_strata))(
        live, list(rels))


def exact_stage(sorted_rels: Sequence[Relation], strata: Strata, *,
                agg: str, expr: str) -> tuple[jnp.ndarray, jnp.ndarray]:
    """§3.1.1 exact fast path: (estimate, count) from sufficient statistics."""
    exact_fn = EXPRS[expr][1]
    est = exact_fn(sorted_rels, strata)
    cnt = exact_count(strata)
    if agg == "count":
        est = cnt
    elif agg == "avg":
        est = est / jnp.maximum(cnt, 1.0)
    return est, cnt


def exact_stage_from_sums(S_k: jnp.ndarray, strata: Strata, *,
                          agg: str, expr: str
                          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`exact_stage` from per-stratum value sums ``[n, S]``.

    The distributed path computes ``S_k`` per device, merges the owned strata
    into the canonical key-sorted ``[S]`` layout, and finishes here with the
    same arithmetic as the single-device stage — bit-identical results.
    """
    finish = {"sum": exact_sum_of_sums_from,
              "product": exact_sum_of_products_from}[expr]
    est = finish(S_k, strata)
    cnt = exact_count(strata)
    if agg == "count":
        est = cnt
    elif agg == "avg":
        est = est / jnp.maximum(cnt, 1.0)
    return est, cnt


def estimate_stage(sample: SampleResult, *, agg: str, dedup: bool,
                   confidence: float):
    """§3.4: sufficient statistics -> (value, error bound, count, dof)."""
    if dedup:
        est = horvitz_thompson_sum(sample.stats, sample.unique_f,
                                   sample.unique_count, confidence)
    elif agg == "avg":
        est = clt_avg(sample.stats, confidence)
    elif agg == "stdev":
        est = clt_stdev(sample.stats, confidence)
    else:
        est = clt_sum(sample.stats, confidence)
    cnt = clt_count(sample.stats)
    value = cnt if agg == "count" else est.estimate
    err = jnp.zeros_like(est.error_bound) if agg == "count" \
        else est.error_bound
    return value, err, cnt, est.dof


def sample_stage(sorted_rels: Sequence[Relation], strata: Strata,
                 b_i: jnp.ndarray, b_max: int, seed, *,
                 agg: str = "sum", dedup: bool = False,
                 confidence: float = 0.95,
                 f_fn: Callable = None):
    """Stages 4-6 (sampled path): draw + aggregate + error bound."""
    sample = sample_edges(sorted_rels, strata, b_i, b_max, seed,
                          default_f if f_fn is None else f_fn)
    value, err, cnt, dof = estimate_stage(sample, agg=agg, dedup=dedup,
                                          confidence=confidence)
    return value, err, cnt, dof, sample.stats


def _kernel_sample_result(stats: StratumStats) -> SampleResult:
    """Wrap kernel StratumStats as a SampleResult (non-dedup: the HT/dedup
    fields are unused by :func:`estimate_stage`, stubbed to zeros)."""
    zeros = stats.sum_f * 0
    return SampleResult(stats, zeros, zeros,
                        jnp.zeros((1, 1)), jnp.zeros((1, 1), bool))


def sample_stage_kernels(sorted_rels: Sequence[Relation], strata: Strata,
                         b_i: jnp.ndarray, b_max: int, seed, *,
                         agg: str = "sum", confidence: float = 0.95,
                         expr: str = "sum",
                         interpret: bool = True):
    """Kernel-backed :func:`sample_stage` (two-way, non-dedup): the fused
    draw->gather->f->reduce Pallas sampler + the shared estimate stage."""
    from repro.kernels import ops as kops
    stats = kops.sample_stats(sorted_rels, strata, b_i, b_max, seed, expr,
                              interpret=interpret)
    value, err, cnt, dof = estimate_stage(
        _kernel_sample_result(stats), agg=agg, dedup=False,
        confidence=confidence)
    return value, err, cnt, dof, stats


def sample_stage_kernels_batched(sorted_rels: Sequence[Relation],
                                 strata: Strata, b_i: jnp.ndarray,
                                 b_max: int, seeds, *,
                                 agg: str = "sum", confidence: float = 0.95,
                                 expr: str = "sum", interpret: bool = True):
    """Slot-batched kernel sample stage (engine counterpart).

    Inputs are slot-stacked (``[B, ...]`` leaves, as emitted by the batched
    prepare); the fused sampler runs the ``(batch_slot, strata_block)``
    kernel grid directly — the slot dimension belongs to the kernel, not
    vmap — and the estimator finish vmaps per slot.  The batched Strata
    pytree's reducing properties (``joinable``/``population``) cannot be
    read off batched leaves, so they are recomputed here over the per-slot
    axes (same arithmetic, one axis over).
    """
    from repro.kernels import ops as kops
    joinable = strata.valid & jnp.all(strata.counts > 0, axis=1)
    population = jnp.where(
        joinable,
        jnp.prod(jnp.maximum(strata.counts, 0).astype(jnp.float32), axis=1),
        0.0)
    stats = kops.sample_stats_batched(
        sorted_rels[0].values, sorted_rels[1].values,
        strata.keys, strata.starts, strata.counts, joinable, population,
        b_i, seeds, b_max, expr, interpret=interpret)
    value, err, cnt, dof = jax.vmap(
        lambda s: estimate_stage(_kernel_sample_result(s), agg=agg,
                                 dedup=False, confidence=confidence))(stats)
    return value, err, cnt, dof, stats


def _pilot_sizes(population, fraction: float) -> jnp.ndarray:
    b = jnp.ceil(fraction * jnp.asarray(population, jnp.float32))
    return jnp.where(jnp.asarray(population) > 0, jnp.maximum(b, 1.0), 0.0)


def decide_sample_sizes(budget: QueryBudget, strata: Strata,
                        cost_model: Optional[CostModel], d_dt: float,
                        sigma: Optional[np.ndarray],
                        confidence: float) -> jnp.ndarray:
    """§3.2: budget -> per-stratum b_i.  Latency and error combine by min."""
    population = strata.population
    b = None
    if budget.error is not None:
        if sigma is not None:
            b = sizes_for_error(budget.error, sigma, population, confidence)
        else:  # first execution: pilot run at a fixed fraction (§3.2-II)
            b = _pilot_sizes(population, budget.pilot_fraction)
    if budget.latency_s is not None:
        assert cost_model is not None, "latency budget needs a CostModel"
        bl = sizes_for_latency(cost_model, budget.latency_s, d_dt, population)
        b = bl if b is None else jnp.minimum(b, bl)
    assert b is not None
    return b


def measured_sigma(stats: StratumStats) -> jnp.ndarray:
    """Per-stratum sigma estimate fed back into the SigmaRegistry."""
    b = jnp.maximum(stats.n_sampled, 1.0)
    r2 = (stats.sum_f2 - stats.sum_f**2 / b) / jnp.maximum(b - 1.0, 1.0)
    return jnp.sqrt(jnp.maximum(r2, 0.0))


def approx_join(rels: Sequence[Relation],
                budget: QueryBudget = QueryBudget(),
                *,
                agg: str = "sum",
                expr: str = "sum",
                f: Optional[Callable] = None,
                seed: int = 0,
                fp_rate: float = 0.01,
                max_strata: Optional[int] = None,
                b_max: Optional[int] = 2048,
                cost_model: Optional[CostModel] = None,
                sigma_registry: Optional[SigmaRegistry] = None,
                query_id: str = "q0",
                dedup: bool = False,
                use_kernels: bool = False) -> JoinResult:
    """The paper's approxjoin() (§4): join + aggregate within a budget.

    ``expr`` selects f over joined values ('sum' -> v1+...+vn); ``agg`` is the
    outer aggregate ('sum' | 'count' | 'avg').  ``dedup=True`` removes
    duplicate edges and switches to the Horvitz-Thompson estimator.
    ``use_kernels=True`` routes filter build/probe and the (two-way,
    non-dedup) sampler through the Pallas kernels (kernels/ops.py) —
    bit-identical results, fused VMEM execution on TPU.
    """
    f_fn, exact_fn = EXPRS[expr] if f is None else (f, None)
    n = len(rels)
    max_n = max(r.capacity for r in rels)
    # size the strata grid from the LARGEST input: keyed on rels[0] alone, a
    # join whose later relation is bigger under-sizes S and silently inflates
    # strata_overflow (the overflowing keys fall out of the sample frame)
    S = max_strata or max_n

    # --- stage 1: filtering (timed: feeds d_dt in the latency cost fn) ---
    t0 = time.perf_counter()
    num_blocks = bloom.num_blocks_for(max_n, fp_rate)
    if use_kernels:
        from repro.kernels import ops as kops
        interp = kops.use_interpret()
        prep = prepare_stage_kernels(rels, num_blocks, S, seed,
                                     interpret=interp)
    else:
        prep = prepare_stage(rels, num_blocks, S, seed)
    sorted_rels, strata = prep.sorted_rels, prep.strata
    live_counts, total_counts = prep.live_counts, prep.total_counts
    jax.block_until_ready(strata.counts)
    d_filter = time.perf_counter() - t0

    population = strata.population
    total_pop = jnp.sum(population)
    overlap = jnp.sum(live_counts) / jnp.maximum(jnp.sum(total_counts), 1)
    fbytes = num_blocks * bloom.WORDS_PER_BLOCK * 4
    diag = dict(
        total_counts=total_counts, live_counts=live_counts,
        overlap_fraction=overlap, filter_bytes=fbytes,
        shuffled_bytes_filtered=jnp.sum(live_counts) * TUPLE_BYTES
        + filter_exchange_bytes(n, fbytes),
        shuffled_bytes_repartition=jnp.sum(total_counts) * TUPLE_BYTES,
        num_strata=strata.num_strata, strata_overflow=strata.overflow,
        total_population=total_pop, d_filter_s=d_filter,
    )

    # --- stage 2: exact fast path (§3.1.1 "is filtering sufficient?") ---
    exact_affordable = budget.is_exact or (
        budget.latency_s is not None and cost_model is not None
        and exact_fn is not None
        and float(cost_model.beta_compute) * float(total_pop)
        + cost_model.epsilon + d_filter <= budget.latency_s
        and budget.error is None)
    if exact_affordable:
        assert exact_fn is not None, "exact path needs a separable expr"
        est, cnt = exact_stage(sorted_rels, strata, agg=agg, expr=expr)
        return JoinResult(est, jnp.zeros(()), cnt, jnp.zeros(()),
                          JoinDiagnostics(sample_draws=jnp.zeros(()),
                                          sampled=False, **diag),
                          strata=strata)

    # --- stage 3: budget -> b_i (§3.2) ---
    sigma = None
    if (budget.error is not None and sigma_registry is not None
            and sigma_registry.has(query_id)):
        keys = np.asarray(jax.device_get(strata.keys))
        sigma = sigma_registry.lookup(query_id, keys)
    b_i = decide_sample_sizes(budget, strata, cost_model, d_filter, sigma,
                              budget.confidence)
    if b_max is None:
        # adaptive grid: the driver sizes the static [S, b_max] draw grid
        # from the budget (pow2-bucketed to bound recompiles).  Without
        # this, latency is flat in b_i and the latency cost function can't
        # steer (found via the Fig-11 fidelity bench; see EXPERIMENTS.md).
        peak = int(jax.device_get(jnp.max(b_i)))
        b_max = max(64, 1 << (min(peak, 8192) - 1).bit_length())

    # --- stage 4+5: sample during join + estimate (§3.3, §3.4) ---
    if use_kernels and not dedup and n == 2 and f is None:
        value, err, cnt, dof, kstats = sample_stage_kernels(
            sorted_rels, strata, b_i, b_max, seed + 1, agg=agg,
            confidence=budget.confidence, expr=expr, interpret=interp)
        sample = _kernel_sample_result(kstats)
    else:
        sample = sample_edges(sorted_rels, strata, b_i, b_max, seed + 1, f_fn)
        value, err, cnt, dof = estimate_stage(sample, agg=agg, dedup=dedup,
                                              confidence=budget.confidence)

    # --- feedback: store measured sigma for the next execution (§3.2-II) ---
    if sigma_registry is not None:
        sig = np.asarray(jax.device_get(measured_sigma(sample.stats)))
        keys = np.asarray(jax.device_get(strata.keys))
        ok = np.asarray(jax.device_get(sample.stats.valid
                                       & (sample.stats.n_sampled > 1)))
        sigma_registry.update(query_id, keys, sig, ok)

    return JoinResult(value, err, cnt, dof,
                      JoinDiagnostics(
                          sample_draws=jnp.sum(sample.stats.n_sampled),
                          sampled=True, **diag),
                      stats=sample.stats, strata=strata)
