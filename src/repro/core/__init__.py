"""ApproxJoin core: the paper's contribution as a composable JAX module.

Public surface:
  relation     — static-shape Relation (the RDD stand-in)
  bloom        — split-block Bloom sketch + Alg. 1 filter algebra
  sampling     — stratified sampling during the join (Alg. 2) + exact paths
  estimators   — CLT / Horvitz-Thompson error bounds (§3.4)
  cost         — query-budget cost functions + sigma feedback (§3.2)
  budget       — WITHIN/ERROR query budget interface (§2)
  join         — single-device approx_join orchestrator
  plan         — query-plan IR: multi-way join DAGs compiled to fused stages
  distributed  — shard_map SPMD pipeline over the mesh
  window       — incremental sub-window layer for streaming joins
  baselines    — Spark native/repartition/broadcast + pre/post-join sampling
"""

from repro.core.baselines import (BaselineResult, broadcast_join, native_join,
                                  postjoin_sampling, prejoin_sampling,
                                  repartition_join, volume_approxjoin,
                                  volume_broadcast, volume_repartition)
from repro.core.budget import QueryBudget, parse_budget
from repro.core.cost import CostModel, SigmaRegistry, calibrate_beta
from repro.core.distributed import (DistJoinResult, dist_exact_stage,
                                    dist_prepare_stage, dist_sample_stage,
                                    distributed_approx_join,
                                    make_distributed_join)
from repro.core.estimators import (Estimate, StratumStats, accuracy_loss,
                                   clt_avg, clt_count, clt_sum,
                                   horvitz_thompson_sum, t_quantile)
from repro.core.join import JoinResult, approx_join
from repro.core.plan import (CompiledPlan, Plan, PlanNode, compile_plan,
                             node_bytes_model)
from repro.core.relation import Relation, relation
from repro.core.sampling import (Reservoir, Strata, build_strata,
                                 reservoir_empty, reservoir_extend,
                                 reservoir_merge, sample_edges)
from repro.core.window import (SubWindow, WindowBuffer, WindowSpec,
                               window_relations)

__all__ = [n for n in dir() if not n.startswith("_")]
