"""Split-block Bloom filters (the paper's sketch, TPU-adapted).

The paper uses a flat bit-vector Bloom filter (§3.1, Algorithm 1).  On TPU we
use the *split-block* variant (Parquet/Impala): a key selects one 256-bit
block (8 x uint32 lanes) and sets exactly one bit in each lane, chosen by
eight per-lane salted hashes.  Build and probe are then gathers plus lane-wise
bitwise ops on aligned 8-word vectors — VPU-friendly, one block touch per key
instead of h random bit probes (DESIGN.md §2).

Filter algebra is unchanged from the paper:
  * partition filters merge with OR   (Algorithm 1, reduce phase)
  * dataset filters merge with AND    (Algorithm 1, join filter)
and those are plain ``bitwise_or`` / ``bitwise_and`` on the packed words, so a
distributed merge is an all-gather + fold (or any reduction tree XLA picks).

Sizing uses the paper's Eq. 27, |BF| = -N ln p / (ln 2)^2 bits, rounded up to
a power-of-two number of blocks; the split-block layout costs a small constant
in false-positive rate versus the optimal flat filter, which the property
tests bound empirically.

Appendix-B variants (counting / invertible / scalable) are provided as a
functional counting filter plus size models for the Fig-15 benchmark.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.hashing import SALT, fmix32, hash2, u32

WORDS_PER_BLOCK = 8
BITS_PER_BLOCK = 32 * WORDS_PER_BLOCK


class BloomFilter(NamedTuple):
    """Packed split-block Bloom filter: uint32 words [num_blocks, 8]."""

    words: jnp.ndarray
    seed: int = 0

    @property
    def num_blocks(self) -> int:
        return self.words.shape[0]

    @property
    def num_bits(self) -> int:
        return self.num_blocks * BITS_PER_BLOCK

    @property
    def size_bytes(self) -> int:
        return self.num_bits // 8


def num_blocks_for(n_keys: int, fp_rate: float) -> int:
    """Paper Eq. 27 sizing, rounded up to a power-of-two block count."""
    n_keys = max(int(n_keys), 1)
    bits = -n_keys * math.log(max(min(fp_rate, 0.5), 1e-12)) / (math.log(2) ** 2)
    blocks = max(1, math.ceil(bits / BITS_PER_BLOCK))
    return 1 << (blocks - 1).bit_length()


def block_index(keys: jnp.ndarray, num_blocks: int, seed) -> jnp.ndarray:
    """Which block each key lands in (num_blocks must be a power of two)."""
    return (hash2(keys, seed) & u32(num_blocks - 1)).astype(jnp.int32)


def lane_masks(keys: jnp.ndarray, seed) -> jnp.ndarray:
    """[..., 8] uint32 — the one-bit-per-lane masks for each key.

    Scalar numpy literals per lane (not a stacked device array) so this
    traces cleanly inside Pallas kernels (see core.hashing note).
    """
    h = fmix32(hash2(keys, seed) * u32(0x85EBCA6B) + u32(1))
    lanes = []
    for s in SALT:
        # bit position in lane = top 5 bits of (h * salt)
        bits = (h * u32(s)) >> u32(27)
        lanes.append((u32(1) << bits).astype(jnp.uint32))
    return jnp.stack(lanes, axis=-1)


def empty(num_blocks: int, seed: int = 0) -> BloomFilter:
    return BloomFilter(jnp.zeros((num_blocks, WORDS_PER_BLOCK), jnp.uint32), seed)


def scatter_or(blk: jnp.ndarray, masks: jnp.ndarray, valid: jnp.ndarray,
               num_blocks: int, seed: int = 0) -> BloomFilter:
    """Scatter-OR (block, mask) pairs into a packed filter.

    TPU Pallas has no scatter atomics, so the scatter-OR is expressed as an
    unpacked scatter-max over bits ([num_blocks, 8, 32] uint8) and packed once
    at the end; the Pallas build kernel computes the (block, mask) pairs and
    this same scatter runs in its jit wrapper (see kernels/bloom_build).
    """
    blk = jnp.where(valid, blk, num_blocks)  # overflow row is dropped
    bits = _unpack(masks)  # [N, 8, 32] uint8
    grid = jnp.zeros((num_blocks + 1, WORDS_PER_BLOCK, 32), jnp.uint8)
    grid = grid.at[blk].max(bits)
    return BloomFilter(_pack(grid[:num_blocks]), seed)


def build(keys: jnp.ndarray, valid: jnp.ndarray, num_blocks: int,
          seed: int = 0) -> BloomFilter:
    """Build a filter over the valid keys (pure-jnp reference path)."""
    blk = block_index(keys, num_blocks, seed)
    masks = lane_masks(keys, seed)  # [N, 8]
    return scatter_or(blk, masks, valid, num_blocks, seed)


def contains(f: BloomFilter, keys: jnp.ndarray) -> jnp.ndarray:
    """Membership probe (pure-jnp reference; hot path has a Pallas kernel)."""
    blk = block_index(keys, f.num_blocks, f.seed)
    masks = lane_masks(keys, f.seed)
    gathered = f.words[blk]  # [N, 8]
    return jnp.all((gathered & masks) == masks, axis=-1)


def union(a: BloomFilter, b: BloomFilter) -> BloomFilter:
    """OR-merge (partition filters -> dataset filter)."""
    assert a.seed == b.seed and a.num_blocks == b.num_blocks
    return BloomFilter(a.words | b.words, a.seed)


def intersect(a: BloomFilter, b: BloomFilter) -> BloomFilter:
    """AND-merge (dataset filters -> join filter).

    As in the paper, the AND of Bloom filters is a filter whose set is a
    superset of the intersection of the sets (false positives possible, false
    negatives not).
    """
    assert a.seed == b.seed and a.num_blocks == b.num_blocks
    return BloomFilter(a.words & b.words, a.seed)


def intersect_all(filters: list[BloomFilter]) -> BloomFilter:
    """AND-merge n dataset filters into the join filter (§3.1, Alg. 1).

    Validates that the filters agree before merging: intersecting filters
    with different geometry or hash seeds silently returns garbage (the AND
    of unrelated bit patterns).  Word shapes are static and always checked;
    seeds are compared only when both are concrete Python ints — under
    jit/vmap the seed is a tracer (one seed per batch slot) and equality
    cannot be evaluated at trace time, which is exactly the case where the
    caller passes the *same* seed object to every filter anyway.
    """
    filters = list(filters)
    if not filters:
        raise ValueError("intersect_all: need at least one filter")
    first = filters[0]
    words = first.words
    for i, f in enumerate(filters[1:], start=1):
        if f.words.shape != first.words.shape:
            raise ValueError(
                f"intersect_all: filter {i} words shape {f.words.shape} != "
                f"filter 0 shape {first.words.shape} (num_blocks mismatch)")
        if (isinstance(f.seed, int) and isinstance(first.seed, int)
                and f.seed != first.seed):
            raise ValueError(
                f"intersect_all: filter {i} seed {f.seed} != filter 0 seed "
                f"{first.seed} — filters hash incompatibly")
        words = words & f.words
    return BloomFilter(words, first.seed)


def _unpack(words: jnp.ndarray) -> jnp.ndarray:
    """uint32 [..., W] -> uint8 bits [..., W, 32]."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return ((words[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.uint8)


def _pack(bits: jnp.ndarray) -> jnp.ndarray:
    """uint8 bits [..., W, 32] -> uint32 [..., W]."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) << shifts, axis=-1, dtype=jnp.uint32)


def fill_fraction(f: BloomFilter) -> jnp.ndarray:
    """Fraction of set bits (sanity metric; ~0.5 at design load)."""
    return jnp.mean(_unpack(f.words).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Appendix-B variants: size models + a functional counting filter.
# ---------------------------------------------------------------------------

def flat_filter_bits(n_keys: int, fp_rate: float) -> int:
    """Regular Bloom filter size (paper Eq. 27), in bits."""
    n_keys = max(int(n_keys), 1)
    return math.ceil(-n_keys * math.log(fp_rate) / (math.log(2) ** 2))


def counting_filter_bits(n_keys: int, fp_rate: float, counter_bits: int = 4) -> int:
    """Counting BF: a ``counter_bits`` counter per cell instead of one bit."""
    return flat_filter_bits(n_keys, fp_rate) * counter_bits


def invertible_filter_bits(n_keys: int, fp_rate: float,
                           key_bits: int = 32, count_bits: int = 32) -> int:
    """IBF: each cell stores (count, keySum, hashSum) — modeled per [26]."""
    cells = flat_filter_bits(n_keys, fp_rate) // 8  # h≈ln2·bits/n, cells≈1.5n..
    cells = max(cells, int(1.3 * n_keys))
    return cells * (count_bits + key_bits + key_bits)


def scalable_filter_bits(n_keys: int, fp_rate: float, initial: int = 4096,
                         growth: int = 2, tightening: float = 0.9) -> int:
    """SBF [41]: series of filters of growing size / tightening error."""
    total, cap, err, added = 0, initial, fp_rate * (1 - tightening), 0
    while added < n_keys:
        total += flat_filter_bits(cap, err)
        added += cap
        cap *= growth
        err *= tightening
    return total


class CountingFilter(NamedTuple):
    """Functional counting Bloom filter (supports remove), Appendix B-II."""

    counts: jnp.ndarray  # int32 [num_blocks, 8, 32] (unpacked cells)
    seed: int = 0

    @property
    def num_blocks(self) -> int:
        return self.counts.shape[0]


def counting_empty(num_blocks: int, seed: int = 0) -> CountingFilter:
    return CountingFilter(jnp.zeros((num_blocks, WORDS_PER_BLOCK, 32), jnp.int32), seed)


def counting_add(f: CountingFilter, keys, valid, sign: int = 1) -> CountingFilter:
    blk = block_index(keys, f.num_blocks, f.seed)
    bits = _unpack(lane_masks(keys, f.seed)).astype(jnp.int32) * sign
    blk = jnp.where(valid, blk, f.num_blocks)
    grid = jnp.zeros((f.num_blocks + 1,) + f.counts.shape[1:], jnp.int32)
    grid = grid.at[blk].add(bits)
    return CountingFilter(f.counts + grid[: f.num_blocks], f.seed)


def counting_contains(f: CountingFilter, keys) -> jnp.ndarray:
    packed = BloomFilter(_pack((f.counts > 0).astype(jnp.uint8)), f.seed)
    return contains(packed, keys)


def false_positive_rate(num_blocks: int, n_keys: int) -> float:
    """Predicted FPR of the split-block filter at load n_keys.

    Per-lane analysis: each lane of a block holding ``c`` keys has FPR
    1-(1-1/32)^c; block FPR = prod over 8 lanes; averaged over the Poisson
    block-occupancy distribution (numpy, used for sizing sanity checks).
    """
    lam = n_keys / num_blocks
    cs = np.arange(0, max(int(lam * 8), 16) + 1)
    # log-space Poisson pmf (factorials overflow past ~170)
    logpmf = -lam + cs * np.log(max(lam, 1e-12)) \
        - np.array([math.lgamma(int(c) + 1) for c in cs])
    pois = np.exp(logpmf)
    per_lane = 1.0 - (1.0 - 1.0 / 32.0) ** cs
    return float(np.sum(pois * per_lane ** WORDS_PER_BLOCK))


# ---------------------------------------------------------------------------
# Appendix B-III: functional Scalable Bloom Filter with the UNION operation
# (the merge the paper contributed upstream — "SBFs contain a set of regular
# Bloom filters, so union two SBFs by unioning the stages pairwise").
# ---------------------------------------------------------------------------

class ScalableFilter:
    """Host-managed SBF: a list of split-block stages of doubling capacity
    and tightening error; add() spills to a fresh stage when the current one
    reaches its design load.  JAX arrays inside, Python growth control (the
    structure is data-dependent, which is exactly why the static pipeline
    uses fixed-size filters — this variant serves ad-hoc driver-side use)."""

    def __init__(self, initial_capacity: int = 4096, fp_rate: float = 0.01,
                 growth: int = 2, tightening: float = 0.5, seed: int = 0):
        self.growth = growth
        self.tightening = tightening
        self.seed = seed
        self.stages: list[BloomFilter] = []
        self.caps: list[int] = []
        self.errs: list[float] = []
        self.counts: list[int] = []
        self._next_cap = initial_capacity
        self._next_err = fp_rate * (1 - tightening)

    def _push_stage(self) -> None:
        nb = num_blocks_for(self._next_cap, self._next_err)
        self.stages.append(empty(nb, self.seed))
        self.caps.append(self._next_cap)
        self.errs.append(self._next_err)
        self.counts.append(0)
        self._next_cap *= self.growth
        self._next_err *= self.tightening

    def add(self, keys) -> None:
        keys = jnp.asarray(keys, jnp.uint32).reshape(-1)
        while keys.shape[0]:
            if not self.stages or self.counts[-1] >= self.caps[-1]:
                self._push_stage()
            room = self.caps[-1] - self.counts[-1]
            batch, keys = keys[:room], keys[room:]
            add = build(batch, jnp.ones(batch.shape[0], bool),
                        self.stages[-1].num_blocks, self.seed)
            self.stages[-1] = union(self.stages[-1], add)
            self.counts[-1] += int(batch.shape[0])

    def contains(self, keys) -> jnp.ndarray:
        keys = jnp.asarray(keys, jnp.uint32)
        out = jnp.zeros(keys.shape, bool)
        for st in self.stages:
            out = out | contains(st, keys)
        return out

    def merge(self, other: "ScalableFilter") -> "ScalableFilter":
        """Union of two SBFs: pairwise-union stages of equal geometry,
        carry extra stages verbatim (the upstream-PR semantics)."""
        assert self.seed == other.seed
        a, b = self, other
        out = ScalableFilter(seed=self.seed)
        n = max(len(a.stages), len(b.stages))
        for i in range(n):
            if i < len(a.stages) and i < len(b.stages):
                assert a.stages[i].num_blocks == b.stages[i].num_blocks, \
                    "stage geometry mismatch: merge requires same schedule"
                out.stages.append(union(a.stages[i], b.stages[i]))
                out.caps.append(a.caps[i])
                out.errs.append(a.errs[i])
                out.counts.append(a.counts[i] + b.counts[i])
            else:
                src = a if i < len(a.stages) else b
                out.stages.append(src.stages[i])
                out.caps.append(src.caps[i])
                out.errs.append(src.errs[i])
                out.counts.append(src.counts[i])
        if out.caps:
            out._next_cap = out.caps[-1] * out.growth
            out._next_err = out.errs[-1] * out.tightening
        return out
