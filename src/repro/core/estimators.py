"""Error estimation for sampled joins (paper §3.4).

Two estimators, exactly as the paper prescribes:

* **CLT / stratified with-replacement** (Eq. 12-14): the edge sampler draws
  with replacement, so the classic stratified-sampling expansion estimator
  applies.  ``tau_hat = sum_i (B_i / b_i) * sum_j v_ij`` with variance
  ``Var = sum_i B_i (B_i - b_i) r_i^2 / b_i`` and a t interval on
  ``f = sum_i b_i - m`` degrees of freedom.

* **Horvitz-Thompson** (Eq. 15-17): when duplicate edges are removed the
  draws are no longer i.i.d.; HT stays unbiased given the inclusion
  probabilities ``pi_i``.  For our counter-hash sampler the per-edge inclusion
  probability inside stratum i is exact: ``pi = 1 - (1 - 1/B_i)^{b_i}``.

The t quantile is computed in pure JAX from the normal quantile
(``jax.scipy.special.ndtri``) via the Cornish-Fisher expansion — no scipy
dependency (the paper used Apache Commons Math; DESIGN.md §2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax.scipy.special import ndtri


def t_quantile(p, df):
    """Student-t quantile via Cornish-Fisher expansion around the normal.

    Accurate to ~1e-3 for df >= 3 (property-tested against exact values);
    df is clamped to 1 to stay finite when a query samples almost nothing.
    """
    df = jnp.maximum(jnp.asarray(df, jnp.float32), 1.0)
    z = ndtri(jnp.asarray(p, jnp.float32))
    z3, z5, z7 = z**3, z**5, z**7
    g1 = (z3 + z) / 4.0
    g2 = (5.0 * z5 + 16.0 * z3 + 3.0 * z) / 96.0
    g3 = (3.0 * z7 + 19.0 * z5 + 17.0 * z3 - 15.0 * z) / 384.0
    return z + g1 / df + g2 / df**2 + g3 / df**3


class StratumStats(NamedTuple):
    """Per-stratum sufficient statistics emitted by the sampler.

    All arrays are [S] with a validity mask; S is the static strata capacity.
    ``population`` is B_i — the *join-output* population of stratum i (the
    bipartite edge count, prod of per-side counts).
    """

    valid: jnp.ndarray       # bool  [S]
    population: jnp.ndarray  # f32   [S]  B_i
    n_sampled: jnp.ndarray   # f32   [S]  b_i (actual draws)
    sum_f: jnp.ndarray       # f32   [S]  sum of f(edge) over sample
    sum_f2: jnp.ndarray      # f32   [S]  sum of f(edge)^2 over sample


class Estimate(NamedTuple):
    estimate: jnp.ndarray       # point estimate of the population total
    error_bound: jnp.ndarray    # half-width of the CI at the given confidence
    variance: jnp.ndarray       # estimated Var(tau_hat)
    dof: jnp.ndarray            # degrees of freedom used for the t interval

    @property
    def lo(self):
        return self.estimate - self.error_bound

    @property
    def hi(self):
        return self.estimate + self.error_bound


def _masked(x, valid):
    return jnp.where(valid, x, 0.0)


def clt_sum(stats: StratumStats, confidence: float = 0.95) -> Estimate:
    """Paper Eq. 12-14: stratified expansion estimator for SUM."""
    return clt_finish(clt_sum_parts(stats), confidence)


class SumParts(NamedTuple):
    """psum-able pieces of the CLT estimate (distributed merge, §3.3-III).

    After the key shuffle each stratum lives wholly on one device, so
    per-device parts ADD across devices: ``finish(psum(parts))`` equals the
    single-device estimate over the union of strata.
    """

    tau: jnp.ndarray        # sum_i B_i * mean_i
    var: jnp.ndarray        # sum_i B_i (B_i - b_i) r_i^2 / b_i
    n_draws: jnp.ndarray    # sum_i b_i
    m_strata: jnp.ndarray   # number of contributing strata
    count: jnp.ndarray      # sum_i B_i (exact join-output count)


def clt_sum_parts(stats: StratumStats) -> SumParts:
    ok = stats.valid & (stats.n_sampled > 0)
    b = jnp.maximum(stats.n_sampled, 1.0)
    B = stats.population
    tau = jnp.sum(_masked(B * stats.sum_f / b, ok))
    var_ok = ok & (stats.n_sampled > 1)
    r2 = (stats.sum_f2 - stats.sum_f**2 / b) / jnp.maximum(b - 1.0, 1.0)
    r2 = jnp.maximum(r2, 0.0)
    fpc = jnp.maximum(B - b, 0.0)
    var = jnp.sum(_masked(B * fpc * r2 / b, var_ok))
    return SumParts(tau, var,
                    jnp.sum(_masked(stats.n_sampled, ok)),
                    jnp.sum(ok.astype(jnp.float32)),
                    jnp.sum(_masked(B, stats.valid)))


def clt_finish(parts: SumParts, confidence: float = 0.95) -> Estimate:
    dof = jnp.maximum(parts.n_draws - parts.m_strata, 1.0)
    t = t_quantile(0.5 + confidence / 2.0, dof)
    return Estimate(parts.tau, t * jnp.sqrt(parts.var), parts.var, dof)


def clt_count(stats: StratumStats) -> jnp.ndarray:
    """COUNT of the join output is exact given the strata: sum_i B_i."""
    return jnp.sum(_masked(stats.population, stats.valid))


def clt_avg_from(parts: SumParts, confidence: float = 0.95) -> Estimate:
    """AVG finish from psum-able parts (count is exact, CI just rescales)."""
    s = clt_finish(parts, confidence)
    n = jnp.maximum(parts.count, 1.0)
    return Estimate(s.estimate / n, s.error_bound / n, s.variance / n**2, s.dof)


def clt_avg(stats: StratumStats, confidence: float = 0.95) -> Estimate:
    """AVG = SUM / COUNT (count is exact, so the CI just rescales)."""
    return clt_avg_from(clt_sum_parts(stats), confidence)


def inclusion_probability(population, n_sampled):
    """P(edge included at least once) under b_i with-replacement draws.

    Computed as -expm1(b * log1p(-1/B)) — float32-stable for B up to 1e7+
    (the naive 1-(1-1/B)^b loses all precision past B ~ 1e5)."""
    B = jnp.maximum(jnp.asarray(population, jnp.float32), 1.0)
    b = jnp.asarray(n_sampled, jnp.float32)
    return -jnp.expm1(b * jnp.log1p(-jnp.minimum(1.0 / B, 0.999999)))


class HTParts(NamedTuple):
    """psum-able pieces of the Horvitz-Thompson estimate (Eq. 15-17).

    Strata sample independently, so every term is a sum of per-stratum
    contributions — a distributed merge of device-complete strata is a
    plain ADD, exactly like :class:`SumParts`.
    """

    tau: jnp.ndarray       # sum_i sum_{distinct e in i} f_e / pi_i
    var: jnp.ndarray       # sum_i (1 - pi_i)/pi_i^2 * y_i^2
    m_strata: jnp.ndarray  # number of contributing strata


def ht_sum_parts(stats: StratumStats, unique_f: jnp.ndarray,
                 unique_counts: jnp.ndarray) -> HTParts:
    ok = stats.valid & (unique_counts > 0)
    pi = inclusion_probability(stats.population, stats.n_sampled)
    pi = jnp.where(ok, jnp.maximum(pi, 1e-9), 1.0)
    tau = jnp.sum(_masked(unique_f / pi, ok))
    # Var(HT) with independent strata: only the first term of Eq. 17 survives
    # across strata (pi_ij = pi_i pi_j when strata sample independently);
    # within a stratum we use the standard per-unit HT variance with the
    # per-stratum aggregate y_i as the unit (paper's formulation).
    var = jnp.sum(_masked((1.0 - pi) / pi**2 * unique_f**2, ok))
    return HTParts(tau, var, jnp.sum(ok.astype(jnp.float32)))


def ht_finish(parts: HTParts, confidence: float = 0.95) -> Estimate:
    dof = jnp.maximum(parts.m_strata - 1.0, 1.0)
    t = t_quantile(0.5 + confidence / 2.0, dof)
    return Estimate(parts.tau, t * jnp.sqrt(parts.var), parts.var, dof)


def horvitz_thompson_sum(stats: StratumStats, unique_f: jnp.ndarray,
                         unique_counts: jnp.ndarray,
                         confidence: float = 0.95) -> Estimate:
    """Paper Eq. 15-17 for the deduplicated sample.

    ``unique_f``/``unique_counts`` are [S]: the per-stratum sum of f over the
    *distinct* sampled edges, and how many distinct edges were kept.  Treating
    each stratum as the HT unit with pi_i from ``inclusion_probability``:
      tau_ht  = sum_i y_i / pi_i, where y_i is scaled to the stratum total.
    Within a stratum every edge shares the same pi, so y_i/pi_i =
    (B_i / E[#distinct]) * y_i in expectation; we use the exact per-edge form:
    each distinct edge contributes f_e / pi_i.
    """
    return ht_finish(ht_sum_parts(stats, unique_f, unique_counts), confidence)


def second_moment_stats(stats: StratumStats) -> StratumStats:
    """Reuse the SUM machinery with f <- f^2 (feeds the STDEV estimator)."""
    return stats._replace(sum_f=stats.sum_f2,
                          sum_f2=jnp.zeros_like(stats.sum_f2))


def clt_stdev_from(parts: SumParts, tau2: jnp.ndarray,
                   confidence: float = 0.95) -> Estimate:
    """STDEV finish from psum-able parts plus the second-moment total.

    ``tau2`` is ``clt_sum_parts(second_moment_stats(stats)).tau`` — a plain
    sum over strata, so it merges across devices by ADD like everything else.
    """
    n = jnp.maximum(parts.count, 1.0)
    s1 = clt_finish(parts, confidence)
    m1 = s1.estimate / n
    m2 = tau2 / n
    var = jnp.maximum(m2 - m1 * m1, 0.0)
    sd = jnp.sqrt(var)
    # delta method: d(sd)/d(m1) = -m1/sd; propagate the SUM CI through m1
    dm1 = s1.error_bound / n
    bound = jnp.where(sd > 0, jnp.abs(m1) / jnp.maximum(sd, 1e-9) * dm1,
                      dm1)
    return Estimate(sd, bound, bound ** 2, s1.dof)


def clt_stdev(stats: StratumStats, confidence: float = 0.95) -> Estimate:
    """STDEV over the join output (the 4th aggregate of the paper's §2
    interface): sqrt(E[f^2] - E[f]^2) with both moments estimated by the
    stratified expansion estimator; the CI half-width follows by the delta
    method from the SUM bounds (first-order)."""
    return clt_stdev_from(clt_sum_parts(stats),
                          clt_sum_parts(second_moment_stats(stats)).tau,
                          confidence)


def accuracy_loss(approx, exact):
    """The paper's metric: (approx - exact) / exact (§5.1)."""
    exact = jnp.where(exact == 0, 1.0, exact)
    return (approx - exact) / exact
