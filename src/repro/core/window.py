"""Incremental windowing for streaming ApproxJoin (StreamApprox dataflow).

A stream is an unbounded sequence of per-tenant micro-batches; queries run
over **windows** measured in *sub-windows* (micro-batch slots of a fixed row
capacity).  ``WindowSpec(size, slide, sub_rows)`` covers both shapes the
streaming literature cares about:

* tumbling — ``slide == size``: consecutive disjoint windows;
* sliding  — ``slide < size``: window ``w`` spans sub-windows
  ``[w*slide, w*slide + size)``, so consecutive windows share
  ``size - slide`` sub-windows.

The key property this module exists for: a window's per-input Bloom filter
is the **OR of its sub-windows' filters** (scatter-OR is a set union, so the
OR of sub-window words is bit-identical to a from-scratch build over the
window's concatenated rows at the same geometry/seed).  Sub-window filter
words are therefore built once on arrival — cached by sub-window fingerprint
in the JoinServer's filter cache — OR-merged per emission, and simply left
out of the OR once the sub-window expires.  A slide never rebuilds the
filter of a surviving sub-window.

Everything here is host-side bookkeeping over static-shape
:class:`~repro.core.relation.Relation` slots; the device work (builds, ORs,
join stages) stays in the serving engine's cached executables.
"""

from __future__ import annotations

from collections import deque
from typing import NamedTuple, Sequence

from repro.core.relation import (Relation, bucket_capacity, concatenate,
                                 pad_to)


class WindowSpec(NamedTuple):
    """Window geometry in sub-window units.

    ``sub_rows`` is the per-side row capacity of ONE sub-window; a window's
    relations have ``size * sub_rows`` rows (pow2-bucketed at assembly).
    """

    size: int       # sub-windows per window
    slide: int      # sub-windows advanced per emission (== size: tumbling)
    sub_rows: int   # per-side row capacity of one sub-window

    @property
    def tumbling(self) -> bool:
        return self.slide == self.size

    def start(self, w: int) -> int:
        """First sub-window index of window ``w``."""
        return w * self.slide

    def end(self, w: int) -> int:
        """One past the last sub-window index of window ``w``."""
        return w * self.slide + self.size

    def validate(self) -> "WindowSpec":
        if not (1 <= self.slide <= self.size):
            raise ValueError(f"need 1 <= slide <= size, got {self}")
        if self.sub_rows < 1:
            raise ValueError(f"sub_rows must be positive, got {self}")
        return self


class SubWindow(NamedTuple):
    """One admitted micro-batch: bucketed relations + their fingerprints.

    ``fps`` key the per-sub-window filter-word cache — the identity that
    makes a slide reuse every surviving sub-window's build.
    """

    index: int
    rels: tuple
    fps: tuple


class WindowBuffer:
    """Host-side ring of live sub-windows with emission bookkeeping.

    ``push`` returns the windows that became due plus the sub-windows that
    expired (no longer reachable by ANY future window) — the caller retires
    the expired filter words.  Live occupancy is bounded by ``spec.size``.
    """

    def __init__(self, spec: WindowSpec):
        self.spec = spec.validate()
        self.live: deque = deque()
        self.arrived = 0          # sub-windows pushed so far
        self.emitted = 0          # windows emitted so far

    def push(self, sub: SubWindow):
        assert sub.index == self.arrived, (sub.index, self.arrived)
        self.live.append(sub)
        self.arrived += 1
        due, expired = [], []
        while self.arrived >= self.spec.end(self.emitted):
            start = self.spec.start(self.emitted)
            subs = [s for s in self.live if s.index >= start]
            assert len(subs) == self.spec.size, (len(subs), self.spec)
            due.append((self.emitted, subs))
            self.emitted += 1
            # retire everything no future window (>= emitted) can reach
            next_start = self.spec.start(self.emitted)
            while self.live and self.live[0].index < next_start:
                expired.append(self.live.popleft())
        return due, expired


def window_relations(subs: Sequence[SubWindow],
                     minimum: int = 1) -> list[Relation]:
    """Assemble a window's per-side relations from its sub-windows.

    Concatenation order is arrival order; the result is padded to the
    window's pow2 capacity bucket (invalid padding rows), so every window of
    a given spec lands in ONE serving shape class.
    """
    n_sides = len(subs[0].rels)
    cap = bucket_capacity(len(subs) * subs[0].rels[0].capacity, minimum)
    return [pad_to(concatenate([s.rels[side] for s in subs]), cap)
            for side in range(n_sides)]
