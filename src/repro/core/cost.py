"""Query-budget cost functions (paper §3.2).

Converts a user budget — desired latency or desired error bound — into
per-stratum sample sizes ``b_i``:

* latency:  Eq. 6/7.  ``s = (d_desired - d_dt - eps) / beta / sum_i B_i``,
  then ``b_i = s * B_i``.  ``beta_compute`` (seconds per sampled edge) is
  profiled offline with :func:`calibrate_beta` — the paper's Figure 5
  microbenchmark, which it finds (and we re-verify) to be linear.

* error bound:  Eq. 9/10.  ``b_i = (z_{a/2} * sigma_i / err)^2`` with
  ``z_{0.025} = 1.96``.  sigma_i is unknown on first execution; the paper's
  feedback loop stores the measured per-stratum sigma and reuses it — here a
  :class:`SigmaRegistry` keyed by (query id, join key), JSON-persistable so
  the loop survives restarts (feeds the fault-tolerance story).

Both paths are combined (Eq. 11) by taking the per-stratum minimum when the
user supplies both constraints: latency is a hard budget, the error target is
met when affordable.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimators import t_quantile


class CostModel(NamedTuple):
    """Latency model d_cp = beta_compute * CP_total + epsilon (Eq. 5)."""

    beta_compute: float   # seconds per sampled cross-product row
    epsilon: float = 0.0  # fixed noise/overhead term


def calibrate_beta(sizes=(1 << 14, 1 << 16, 1 << 18), repeats: int = 3,
                   seed: int = 0) -> CostModel:
    """Offline cluster profiling (paper Fig. 5): time f-eval over N sampled
    edges for growing N, fit a line.  Runs on whatever backend is present —
    the slope is the machine-specific constant the paper calls beta."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []

    @jax.jit
    def work(a, b):
        return jnp.sum(a + b) + jnp.sum((a + b) ** 2)

    for n in sizes:
        a = jnp.asarray(rng.random(n, np.float32))
        b = jnp.asarray(rng.random(n, np.float32))
        work(a, b).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            work(a, b).block_until_ready()
        xs.append(n)
        ys.append((time.perf_counter() - t0) / repeats)
    slope, intercept = np.polyfit(np.asarray(xs, np.float64),
                                  np.asarray(ys, np.float64), 1)
    return CostModel(float(max(slope, 1e-12)), float(max(intercept, 0.0)))


def calibrate_pipeline(rels, *, max_strata: int, b_max: int,
                       fractions=(0.05, 0.4), seed: int = 0) -> CostModel:
    """Two-point calibration against the REAL sampling pipeline.

    Times the full approx_join sampled path at two pilot fractions and fits
    d = beta * total_draws + eps.  Captures everything the flat-array
    microbenchmark (calibrate_beta) misses — grid dispatch, estimator,
    framework overhead — so the Fig-11 budget fidelity holds on the actual
    operator."""
    import time as _time

    from repro.core.budget import QueryBudget
    from repro.core.join import approx_join

    pts = []
    for frac in fractions:
        kw = dict(max_strata=max_strata, b_max=None, seed=seed)
        res = approx_join(rels, QueryBudget(error=1e9, pilot_fraction=frac),
                          **kw)  # warm-up: compile this grid bucket
        jax.block_until_ready(res.estimate)
        t0 = _time.perf_counter()
        res = approx_join(rels, QueryBudget(error=1e9, pilot_fraction=frac),
                          **kw)
        jax.block_until_ready(res.estimate)
        pts.append((float(res.diagnostics.sample_draws),
                    _time.perf_counter() - t0))
    (x0, y0), (x1, y1) = pts
    beta = max((y1 - y0) / max(x1 - x0, 1.0), 1e-12)
    eps = max(y0 - beta * x0, 0.0)
    return CostModel(beta, eps)


def fraction_for_latency(cost: CostModel, d_desired: float, d_dt,
                         total_population) -> jnp.ndarray:
    """Eq. 6: the sampling fraction affordable in the remaining time."""
    d_rem = jnp.maximum(d_desired - d_dt - cost.epsilon, 0.0)
    cp_total = d_rem / cost.beta_compute
    s = cp_total / jnp.maximum(jnp.asarray(total_population, jnp.float32), 1.0)
    return jnp.clip(s, 0.0, 1.0)


def sizes_for_latency(cost: CostModel, d_desired: float, d_dt,
                      population) -> jnp.ndarray:
    """Eq. 7: b_i = s * B_i (at least 1 draw for non-empty strata)."""
    s = fraction_for_latency(cost, d_desired, d_dt,
                             jnp.sum(jnp.asarray(population)))
    b = jnp.ceil(s * jnp.asarray(population, jnp.float32))
    return jnp.where(jnp.asarray(population) > 0, jnp.maximum(b, 1.0), 0.0)


def sizes_for_error(err_desired: float, sigma, population,
                    confidence: float = 0.95) -> jnp.ndarray:
    """Eq. 9/10: b_i = (z * sigma_i / err)^2, capped at B_i draws
    (beyond B_i with-replacement draws the FPC term is zero anyway)."""
    z = t_quantile(0.5 + confidence / 2.0, 1e6)  # -> normal quantile
    b = jnp.ceil((z * jnp.asarray(sigma, jnp.float32)
                  / max(err_desired, 1e-12)) ** 2)
    b = jnp.minimum(b, jnp.asarray(population, jnp.float32))
    return jnp.where(jnp.asarray(population) > 0, jnp.maximum(b, 1.0), 0.0)


def predicted_latency(cost: CostModel, b_i, d_dt) -> jnp.ndarray:
    """Eq. 5 forward model — used by tests/benchmarks for fidelity checks."""
    return cost.beta_compute * jnp.sum(jnp.asarray(b_i, jnp.float32)) \
        + cost.epsilon + d_dt


@dataclass
class SigmaRegistry:
    """Feedback store: per-(query, stratum-key) sigma estimates (§3.2-II).

    First execution -> no entry -> caller falls back to the latency path or a
    default pilot fraction; after execution :meth:`update` records measured
    sigmas so subsequent runs can hit the error-bound target directly."""

    table: dict = field(default_factory=dict)

    def lookup(self, query_id: str, keys: np.ndarray,
               default: float = 1.0) -> np.ndarray:
        q = self.table.get(query_id, {})
        return np.asarray([q.get(int(k), default) for k in keys], np.float32)

    def has(self, query_id: str) -> bool:
        return query_id in self.table

    def update(self, query_id: str, keys, sigmas, valid) -> None:
        keys = np.asarray(keys)
        sigmas = np.asarray(sigmas)
        valid = np.asarray(valid)
        q = self.table.setdefault(query_id, {})
        for k, s, v in zip(keys, sigmas, valid):
            if v:
                q[int(k)] = float(s)

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump({q: {str(k): v for k, v in t.items()}
                       for q, t in self.table.items()}, fh)

    @classmethod
    def load(cls, path: str) -> "SigmaRegistry":
        with open(path) as fh:
            raw = json.load(fh)
        return cls({q: {int(k): float(v) for k, v in t.items()}
                    for q, t in raw.items()})
