"""Stratified sampling *during* the join (paper §3.3, Algorithm 2).

The join of n relations on key C_i is the complete n-partite graph over the
per-side tuple groups; sampling the join output = sampling edges from that
graph without materializing it.  Per stratum (join key) we draw ``b_i`` edges
by picking one endpoint per side with a counter-based stateless hash:

    idx_side = start_side + counter_hash(seed, key, draw, side) % count_side

Everything is vectorized over a static [S, b_max] grid (S = strata capacity,
b_max = per-stratum draw capacity) — there is no per-key loop, matching the
"dense pass" TPU constraint (DESIGN.md §2).  Draws are keyed by the *join key*
(not the stratum index), so the sample is invariant to how tuples were
partitioned across devices — the coordination-free property the paper needs
for distributed sampling, made exact here.

The group-by machinery (``build_strata``) identifies strata from the sorted
lead relation and locates each stratum's segment in every side with
``searchsorted`` — O(N log N), no hash tables, no dynamic shapes.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.estimators import StratumStats
from repro.core.hashing import GOLDEN, bounded, counter_hash, fmix32, hash2, u32
from repro.core.relation import Relation

SENTINEL = 0xFFFFFFFF  # invalid-row key fill; real keys must be < 2^32 - 1


class Strata(NamedTuple):
    """Join strata: one row per distinct key of the (sorted) lead relation.

    ``starts``/``counts`` are [n_sides, S]: the segment of each stratum in
    each side's sorted key array.  ``joinable`` marks strata present (count>0)
    on every side — only those produce join output.
    """

    keys: jnp.ndarray      # uint32 [S]
    valid: jnp.ndarray     # bool   [S] stratum slot holds a real key
    starts: jnp.ndarray    # int32  [n_sides, S]
    counts: jnp.ndarray    # int32  [n_sides, S]
    overflow: jnp.ndarray  # int32  [] strata beyond capacity S (diagnostic)

    @property
    def joinable(self) -> jnp.ndarray:
        return self.valid & jnp.all(self.counts > 0, axis=0)

    @property
    def population(self) -> jnp.ndarray:
        """B_i — join-output size per stratum (product of side counts)."""
        p = jnp.prod(jnp.maximum(self.counts, 0).astype(jnp.float32), axis=0)
        return jnp.where(self.joinable, p, 0.0)

    @property
    def num_strata(self) -> jnp.ndarray:
        """m — number of joinable strata."""
        return jnp.sum(self.joinable.astype(jnp.int32))


def _segment(sorted_keys: jnp.ndarray, stratum_keys: jnp.ndarray):
    start = jnp.searchsorted(sorted_keys, stratum_keys, side="left")
    end = jnp.searchsorted(sorted_keys, stratum_keys, side="right")
    return start.astype(jnp.int32), (end - start).astype(jnp.int32)


def build_strata(sorted_rels: Sequence[Relation], max_strata: int) -> Strata:
    """Identify strata from sorted_rels[0]; locate segments in every side.

    All relations must already be sorted by ``masked_keys()`` (invalid rows
    filled with SENTINEL sort last).  Strata beyond ``max_strata`` are counted
    in ``overflow`` (they are dropped; callers size S = key capacity to make
    this impossible in exact mode).
    """
    lead = sorted_rels[0]
    mk = lead.masked_keys(SENTINEL)
    first = jnp.ones((1,), bool) if mk.shape[0] else jnp.zeros((0,), bool)
    is_start = lead.valid & jnp.concatenate([first, mk[1:] != mk[:-1]])
    sid = jnp.cumsum(is_start.astype(jnp.int32)) - 1  # stratum index per row
    total = jnp.sum(is_start.astype(jnp.int32))
    S = max_strata
    slot = jnp.where(is_start & (sid < S), sid, S)  # overflow -> row S
    keys = jnp.full((S + 1,), SENTINEL, jnp.uint32).at[slot].set(mk,
                                                                 mode="drop")
    keys = keys[:S]
    valid = jnp.arange(S) < jnp.minimum(total, S)
    keys = jnp.where(valid, keys, u32(SENTINEL))
    starts, counts = [], []
    for r in sorted_rels:
        s, c = _segment(r.masked_keys(SENTINEL), keys)
        starts.append(s)
        counts.append(jnp.where(valid, c, 0))
    return Strata(keys, valid,
                  jnp.stack(starts), jnp.stack(counts),
                  jnp.maximum(total - S, 0))


def edge_indices(strata: Strata, b_max: int, seed) -> jnp.ndarray:
    """Draw endpoint indices for every (stratum, draw, side).

    Returns int32 [n_sides, S, b_max] — absolute row indices into each side's
    sorted arrays.  Pure function of (seed, join key, draw counter, side):
    deterministic, replayable, partition-invariant.
    """
    n_sides, S = strata.starts.shape
    t = jnp.arange(b_max, dtype=jnp.uint32)[None, :]          # [1, b_max]
    keys = strata.keys[:, None]                               # [S, 1]
    idx = []
    for side in range(n_sides):
        h = counter_hash(seed, keys, t, side)                 # [S, b_max]
        cnt = jnp.maximum(strata.counts[side], 1)[:, None]
        idx.append(strata.starts[side][:, None] + bounded(h, cnt))
    return jnp.stack(idx)


def edge_id(idx_in_stratum: jnp.ndarray) -> jnp.ndarray:
    """Collision-resistant id of an edge from per-side in-stratum offsets.

    [n_sides, S, b_max] -> uint32 [S, b_max].  Hash-combined (a true mixed
    radix id can overflow u32 for large strata); collision probability within
    a stratum is ~b_max^2 / 2^33 — negligible at our draw capacities and only
    used for the HT dedup path (documented in DESIGN.md §8).
    """
    h = u32(0)
    for side in range(idx_in_stratum.shape[0]):
        h = fmix32(h * u32(GOLDEN) ^ u32(idx_in_stratum[side]))
    return h


class SampleResult(NamedTuple):
    stats: StratumStats       # with-replacement sufficient statistics
    unique_f: jnp.ndarray     # [S] sum of f over *distinct* edges (HT path)
    unique_count: jnp.ndarray # [S] number of distinct edges
    f_values: jnp.ndarray     # [S, b_max] sampled f(edge) (0 where masked)
    mask: jnp.ndarray         # bool [S, b_max] draw validity


def default_f(values: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """The paper's running aggregate: SUM(R1.V + R2.V + ... + Rn.V)."""
    out = values[0]
    for v in values[1:]:
        out = out + v
    return out


def sample_edges(sorted_rels: Sequence[Relation], strata: Strata,
                 b_i: jnp.ndarray, b_max: int, seed,
                 f: Callable[[Sequence[jnp.ndarray]], jnp.ndarray]
                 = default_f) -> SampleResult:
    """Algorithm 2, vectorized: draw, gather, aggregate per stratum.

    ``b_i`` is float/int [S] — the per-stratum budget from the cost function
    (§3.2); actual draws are ``min(b_i, b_max)`` over joinable strata.
    """
    S = strata.keys.shape[0]
    idx = edge_indices(strata, b_max, seed)                   # [n, S, b_max]
    vals = [r.values[idx[side]] for side, r in enumerate(sorted_rels)]
    fv = f(vals)                                              # [S, b_max]
    t = jnp.arange(b_max, dtype=jnp.float32)[None, :]
    mask = (t < jnp.asarray(b_i, jnp.float32)[:, None]) & \
        strata.joinable[:, None]
    fm = jnp.where(mask, fv, 0.0)
    n_sampled = jnp.sum(mask, axis=1, dtype=jnp.float32)
    stats = StratumStats(
        valid=strata.joinable,
        population=strata.population,
        n_sampled=n_sampled,
        sum_f=jnp.sum(fm, axis=1),
        sum_f2=jnp.sum(fm * fm, axis=1),
    )
    # --- dedup path (Horvitz-Thompson, §3.4-II) ---
    eid = edge_id(idx - strata.starts[:, :, None])            # [S, b_max]
    eid = jnp.where(mask, eid, u32(SENTINEL))
    order = jnp.argsort(eid, axis=1)
    eid_s = jnp.take_along_axis(eid, order, axis=1)
    fv_s = jnp.take_along_axis(fm, order, axis=1)
    first = jnp.concatenate(
        [jnp.ones((S, 1), bool), eid_s[:, 1:] != eid_s[:, :-1]], axis=1)
    keep = first & (eid_s != u32(SENTINEL))
    unique_f = jnp.sum(jnp.where(keep, fv_s, 0.0), axis=1)
    unique_count = jnp.sum(keep, axis=1, dtype=jnp.float32)
    return SampleResult(stats, unique_f, unique_count, fm, mask)


# ---------------------------------------------------------------------------
# Exact aggregates from sufficient statistics (DESIGN.md §2, beyond-paper).
# The cartesian structure of the join makes SUM-type aggregates separable:
#   sum over edges of  sum_k v_k  =  sum_k ( S_k * prod_{j != k} B_j )
#   sum over edges of prod_k v_k  =  prod_k S_k
# computed per stratum in one segment-sum pass — O(N), no cross product.
# Used as the oracle in tests and as the exact fast path when no budget is
# given and the overlap is large.
# ---------------------------------------------------------------------------

def per_stratum_value_sums(sorted_rels, strata) -> jnp.ndarray:
    """[n_sides, S] sum of values per stratum per side.

    Scatter-add keyed by stratum slot rather than a cumsum-difference: each
    stratum's sum then depends only on its OWN rows (same relative order),
    never on what happens to sort before them — which is what lets a device
    holding a shuffled subset of the strata reproduce the single-device
    per-stratum sums bit-for-bit (core/distributed.py relies on this).
    """
    S = strata.keys.shape[0]
    sums = []
    for side, r in enumerate(sorted_rels):
        mk = r.masked_keys(SENTINEL)
        slot = jnp.clip(jnp.searchsorted(strata.keys, mk), 0, S - 1)
        ok = r.valid & (strata.keys[slot] == mk) & strata.valid[slot]
        tgt = jnp.where(ok, slot, S)  # overflow row, dropped
        sums.append(jnp.zeros((S + 1,), jnp.float32).at[tgt].add(
            jnp.where(ok, r.values, 0.0))[:S])
    return jnp.stack(sums)


# Back-compat alias (pre-PR-2 private name).
_per_stratum_value_sums = per_stratum_value_sums


def exact_sum_of_sums_from(S_k: jnp.ndarray, strata: Strata) -> jnp.ndarray:
    """Finish SUM(v_1 + ... + v_n) from per-stratum value sums [n, S].

    Split out so the distributed pipeline can merge per-device S_k into the
    canonical [S] layout and then run the *same* finishing arithmetic as the
    single-device path (bit-identical results).
    """
    B_k = jnp.maximum(strata.counts, 0).astype(jnp.float32)   # [n, S]
    total_B = strata.population                               # [S]
    per_stratum = jnp.zeros_like(total_B)
    n = S_k.shape[0]
    for k in range(n):
        # NB: the select sits BETWEEN the multiply and the accumulate add, so
        # XLA cannot contract add(mul(..)) into an fma — fma rounds once, and
        # whether the contraction fires depends on what else is in the fused
        # computation, which would make the result depend on jit context
        # (eager vs jit(vmap(stage)) vs shard_map).  Bit-parity between the
        # driver, the serving engine, and the distributed pipeline needs this
        # arithmetic to be context-independent.
        term = jnp.where(B_k[k] > 0,
                         S_k[k] * (total_B / jnp.maximum(B_k[k], 1.0)), 0.0)
        per_stratum = per_stratum + term
    return jnp.sum(jnp.where(strata.joinable, per_stratum, 0.0))


def exact_sum_of_products_from(S_k: jnp.ndarray,
                               strata: Strata) -> jnp.ndarray:
    """Finish SUM(v_1 * ... * v_n) from per-stratum value sums [n, S]."""
    per_stratum = jnp.prod(S_k, axis=0)
    return jnp.sum(jnp.where(strata.joinable, per_stratum, 0.0))


def exact_sum_of_sums(sorted_rels, strata) -> jnp.ndarray:
    """Exact SUM(v_1 + ... + v_n) over the join output."""
    return exact_sum_of_sums_from(per_stratum_value_sums(sorted_rels, strata),
                                  strata)


def exact_sum_of_products(sorted_rels, strata) -> jnp.ndarray:
    """Exact SUM(v_1 * ... * v_n) over the join output."""
    return exact_sum_of_products_from(
        per_stratum_value_sums(sorted_rels, strata), strata)


def exact_count(strata: Strata) -> jnp.ndarray:
    return jnp.sum(strata.population)


# ---------------------------------------------------------------------------
# Merge-able per-stratum reservoirs (streaming, StreamApprox-style).
#
# A bounded uniform sample per stratum over an UNBOUNDED stream of values:
# every item gets a priority from the stateless counter hash keyed on its
# arrival identity (tick, row) — never on which reservoir folded it — and a
# stratum from its key hash; the reservoir is the bottom-``cap`` priorities
# per stratum.  Bottom-k by a uniform priority is a uniform without-
# replacement sample (the classic distributed-reservoir trick), and it makes
# the sketch *exactly* mergeable: bottom-k of a union only needs the
# bottom-k of each part, so ``extend(extend(E, A), B)`` equals
# ``merge(extend(E, A), extend(E, B))`` bit-for-bit (up to u32 priority
# ties, ~n^2/2^33).  Static [S, cap] shapes, one sort per fold — jittable,
# vmappable, and shardable like every other stage here.
# ---------------------------------------------------------------------------

class Reservoir(NamedTuple):
    """Per-stratum bottom-k value reservoir (priority SENTINEL = empty slot).

    ``n_seen`` counts every valid item ever offered per stratum — the
    denominator that turns the reservoir into rate/moment estimates.
    """

    priority: jnp.ndarray  # uint32 [S, cap], ascending per row
    values: jnp.ndarray    # f32    [S, cap]
    n_seen: jnp.ndarray    # f32    [S]


def reservoir_empty(num_strata: int, cap: int) -> Reservoir:
    return Reservoir(jnp.full((num_strata, cap), SENTINEL, jnp.uint32),
                     jnp.zeros((num_strata, cap), jnp.float32),
                     jnp.zeros((num_strata,), jnp.float32))


def _keep_bottom(priority: jnp.ndarray, values: jnp.ndarray, cap: int):
    order = jnp.argsort(priority, axis=1)
    return (jnp.take_along_axis(priority, order, axis=1)[:, :cap],
            jnp.take_along_axis(values, order, axis=1)[:, :cap])


def reservoir_extend(res: Reservoir, keys: jnp.ndarray, values: jnp.ndarray,
                     valid: jnp.ndarray, seed, tick) -> Reservoir:
    """Fold one micro-batch into the reservoir.

    ``tick`` is the arrival index of the batch (must be unique per fold of
    the same stream — priorities are ``counter_hash(seed, tick, row, 3)``, so
    reusing a tick would replay the same priorities).  Stratum assignment is
    ``hash2(key, seed) % S``.  Invalid rows are ignored everywhere.
    """
    S, cap = res.priority.shape
    n = keys.shape[0]
    sid = bounded(hash2(keys, seed), jnp.int32(S))               # [n]
    rows = jnp.arange(n, dtype=jnp.uint32)
    pri = counter_hash(seed, u32(tick), rows, 3)
    pri = jnp.where(pri == u32(SENTINEL), u32(SENTINEL - 1), pri)
    # stage only the incoming batch's bottom-cap per stratum (bottom-k of a
    # union needs only the bottom-k of each part): lexsort by (stratum,
    # priority), rank within the stratum run, keep ranks < cap — the final
    # per-row sort then runs over [S, 2*cap], independent of batch size
    d = jnp.where(valid, sid, S)
    order = jnp.lexsort((pri, d))
    ds = d[order]
    pos = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), ds[1:] != ds[:-1]])
    slot = pos - jax.lax.cummax(jnp.where(is_start, pos, 0))
    ok = (ds < S) & (slot < cap)
    flat = jnp.where(ok, ds * cap + slot, S * cap)
    grid_p = jnp.full((S * cap + 1,), SENTINEL, jnp.uint32).at[flat].set(
        pri[order], mode="drop")[:-1].reshape(S, cap)
    grid_v = jnp.zeros((S * cap + 1,), jnp.float32).at[flat].set(
        values[order], mode="drop")[:-1].reshape(S, cap)
    p, v = _keep_bottom(jnp.concatenate([res.priority, grid_p], axis=1),
                        jnp.concatenate([res.values, grid_v], axis=1), cap)
    seen = jnp.zeros((S + 1,), jnp.float32).at[d].add(
        valid.astype(jnp.float32))[:S]
    return Reservoir(p, v, res.n_seen + seen)


def reservoir_merge(a: Reservoir, b: Reservoir) -> Reservoir:
    """Union of two reservoirs over disjoint (tick-distinct) sub-streams."""
    assert a.priority.shape == b.priority.shape, (a.priority.shape,
                                                 b.priority.shape)
    cap = a.priority.shape[1]
    p, v = _keep_bottom(jnp.concatenate([a.priority, b.priority], axis=1),
                        jnp.concatenate([a.values, b.values], axis=1), cap)
    return Reservoir(p, v, a.n_seen + b.n_seen)


def reservoir_fill(res: Reservoir) -> jnp.ndarray:
    """Occupied slots per stratum ([S] f32) — min(n_seen, cap)."""
    return jnp.sum((res.priority != u32(SENTINEL)).astype(jnp.float32),
                   axis=1)


def reservoir_moments(res: Reservoir):
    """(n [S], mean [S], var [S]) of the reservoir sample per stratum.

    Unbiased sample mean/variance of the stream per stratum (the reservoir
    is a uniform sample); feeds streaming sigma diagnostics.
    """
    m = res.priority != u32(SENTINEL)
    n = jnp.sum(m.astype(jnp.float32), axis=1)
    nz = jnp.maximum(n, 1.0)
    vm = jnp.where(m, res.values, 0.0)
    mean = jnp.sum(vm, axis=1) / nz
    var = jnp.sum(jnp.where(m, (res.values - mean[:, None]) ** 2, 0.0),
                  axis=1) / jnp.maximum(n - 1.0, 1.0)
    return n, mean, var
