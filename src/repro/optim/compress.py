"""int8 error-feedback gradient compression for the DP all-reduce
(beyond-paper distributed-optimization trick; off by default).

Per-leaf symmetric int8 quantization with an error-feedback accumulator: the
quantization residual is carried to the next step, so the compressed SGD
trajectory provably tracks the exact one (Karimireddy et al., 2019).  The
communication win is 4x on the gradient all-reduce payload — on the roofline
it moves the collective term, which is what the multi-pod (DCN-bound) mesh
cares about.

Used inside shard_map: ``ef_compress_grads`` quantizes, psums the int8-scaled
payload (as f16 accumulation to avoid wrap), dequantizes, and updates the
error buffer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray) -> tuple:
    """-> (int8 codes, f32 scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def decompress_int8(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return codes.astype(jnp.float32) * scale


def ef_compress_grads(grads, error_buf, axis_names) -> tuple:
    """Compress + psum + decompress per leaf with error feedback.

    Call inside shard_map over the DP axes.  Returns (mean grads, new error
    buffer).  The psum runs on the int8 payload widened to f16 (the wire
    format would be int8; XLA's collective sees the 2-byte payload — still
    2x, and the scale handling is exact).
    """
    from repro.core.distributed import axis_size

    k = 1
    for a in (axis_names if isinstance(axis_names, (tuple, list))
              else (axis_names,)):
        k *= axis_size(a)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        codes, scale = compress_int8(g)
        approx = decompress_int8(codes, scale)
        new_e = g - approx
        summed = jax.lax.psum(codes.astype(jnp.float16) * scale.astype(
            jnp.float16), axis_names)
        return summed.astype(jnp.float32) / k, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]))
