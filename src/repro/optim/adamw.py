"""AdamW + schedules, pure pytree functions (no optax dependency).

State mirrors the param tree (m, v per leaf) so the sharding rules for
parameters apply verbatim to optimizer slots — ZeRO-style sharded optimizer
state falls out of passing the same NamedShardings for ``AdamWState`` in the
dry-run (DESIGN.md §6).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray   # int32 []
    m: dict             # first moment (param tree)
    v: dict             # second moment (param tree)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.zeros_like, params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base_lr * (0.1 + 0.9 * cos))
    return lr


def adamw_update(params, grads, state: AdamWState, *, lr_fn,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 clip_norm: float = 1.0) -> tuple:
    """-> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    t = step.astype(jnp.float32)
    lr = lr_fn(step)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p
        return (p - lr * upd).astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda x: x[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}
