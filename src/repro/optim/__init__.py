from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               cosine_schedule, global_norm)
from repro.optim.compress import (compress_int8, decompress_int8,
                                  ef_compress_grads)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "compress_int8", "decompress_int8",
           "ef_compress_grads"]
