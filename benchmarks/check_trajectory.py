"""Perf-trajectory regression gate over the BENCH_*.json artifacts.

CI has always uploaded ``BENCH_serve.json`` / ``BENCH_stream.json`` /
``BENCH_kernel.json`` / ``BENCH_async.json`` — and never compared two
runs, so the recorded perf trajectory gated nothing.  This script closes
the loop: it compares the artifacts of the CURRENT run (cwd) against a
baseline snapshot and fails (exit 1) when throughput drops, or tail
latency rises, by more than the tolerance (default 20%,
``REPRO_TRAJECTORY_TOL`` / ``--tol``).

Baselines live in ``benchmarks/baselines/`` (committed; note the files are
named ``serve.json`` etc. WITHOUT the ``BENCH_`` prefix — the artifacts
themselves are gitignored) and are refreshed on main via ``--refresh``
into an actions/cache directory, which takes precedence when present so
the gate tracks the trajectory run-over-run rather than only
vs the committed snapshot.

Machine calibration: absolute q/s depends on the runner, so each baseline
snapshot stores a CPU micro-benchmark score (``calibration.json``).  The
gate re-measures the score and scales expectations by the speed ratio —
a 2x-slower runner is allowed 2x-lower q/s and 2x-higher latency before
the tolerance applies.  Rows are matched by ``(bench, mode)``; gated
metrics are throughput (``qps``, ``tuples_per_s`` — lower is a
regression), machine-independent speedup ratios (``x``, ``p95_ratio``),
and tail latency (``*_p95_s``, ``window_ms_p95`` — higher is a
regression, with a small absolute floor so microsecond jitter on
near-zero latencies cannot fail the gate).

Usage:
  python -m benchmarks.check_trajectory              # gate cwd artifacts
  python -m benchmarks.check_trajectory --refresh \
      --baseline-dir .bench-baselines                # snapshot cwd -> dir
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

COMMITTED_DIR = os.path.join(os.path.dirname(__file__), "baselines")
CACHE_DIR = ".bench-baselines"
LATENCY_FLOOR_S = 0.05          # absolute slack for *_s latency metrics
LATENCY_FLOOR_MS = 50.0         # ... and for *_ms metrics
THROUGHPUT_KEYS = ("qps", "tuples_per_s")
RATIO_KEYS = ("x", "p95_ratio")            # machine-independent, unscaled
LATENCY_KEYS = ("queue_latency_p95_s", "e2e_latency_p95_s",
                "window_ms_p95")
# row-size fields: a smoke-mode artifact must not be gated against a
# full-mode baseline (or vice versa) — scales differ by design
SIZE_KEYS = ("queries", "windows")


def calibration_score(repeats: int = 5) -> float:
    """Single-core CPU speed score (higher = faster), stable to ~10%: the
    median time of a fixed numpy workload.  Used to scale throughput and
    latency expectations between the machine that wrote a baseline and the
    machine running the gate."""
    import numpy as np
    a = np.random.default_rng(0).normal(size=(384, 384)).astype(np.float32)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        b = a
        for _ in range(24):
            b = np.tanh(b @ a * 0.01)
        b.sum()
        ts.append(time.perf_counter() - t0)
    return 1.0 / float(np.median(ts))


def baseline_dir(flag: str | None) -> str:
    """Precedence: explicit flag > REPRO_BASELINE_DIR > the actions/cache
    refresh dir (when populated) > the committed snapshot."""
    if flag:
        return flag
    env = os.environ.get("REPRO_BASELINE_DIR")
    if env:
        return env
    if glob.glob(os.path.join(CACHE_DIR, "*.json")):
        return CACHE_DIR
    return COMMITTED_DIR


def _rows_by_mode(path: str) -> dict:
    with open(path) as fh:
        rows = json.load(fh)
    return {(r.get("bench"), r.get("mode")): r for r in rows}


def _artifact_of(baseline_file: str) -> str:
    return "BENCH_" + os.path.basename(baseline_file)


def compare(new_rows: dict, old_rows: dict, *, tol: float,
            factor: float) -> tuple[list[str], list[str]]:
    """(failures, notes) for one artifact.  ``factor`` > 1 means this
    machine is SLOWER than the baseline's by that ratio."""
    failures, notes = [], []
    for key, old in old_rows.items():
        new = new_rows.get(key)
        tag = f"{key[0]}/{key[1]}"
        if new is None:
            failures.append(f"{tag}: row disappeared from the artifact")
            continue
        if any(k in old and k in new
               and max(old[k], new[k]) > 2 * max(min(old[k], new[k]), 1)
               for k in SIZE_KEYS):
            notes.append(f"{tag}: scale changed (smoke vs full?) — skipped")
            continue
        for k in THROUGHPUT_KEYS + RATIO_KEYS:
            if k not in old or k not in new:
                continue
            scale = 1.0 if k in RATIO_KEYS else factor
            floor = old[k] / scale * (1.0 - tol)
            if new[k] < floor:
                failures.append(
                    f"{tag}: {k} regressed {old[k]} -> {new[k]} "
                    f"(floor {floor:.3g} at tol {tol:.0%}, "
                    f"machine factor {factor:.2f})")
        for k in LATENCY_KEYS:
            if k not in old or k not in new:
                continue
            abs_floor = LATENCY_FLOOR_MS if k.endswith("_ms") \
                or "_ms_" in k else LATENCY_FLOOR_S
            ceil = old[k] * factor * (1.0 + tol) + abs_floor
            if new[k] > ceil:
                failures.append(
                    f"{tag}: {k} regressed {old[k]} -> {new[k]} "
                    f"(ceiling {ceil:.3g} at tol {tol:.0%}, "
                    f"machine factor {factor:.2f})")
    for key in new_rows.keys() - old_rows.keys():
        notes.append(f"{key[0]}/{key[1]}: new row (no baseline) — passes")
    return failures, notes


def check(base_dir: str, tol: float) -> int:
    base_files = sorted(f for f in glob.glob(os.path.join(base_dir, "*.json"))
                        if os.path.basename(f) != "calibration.json")
    if not base_files:
        print(f"[trajectory] no baselines under {base_dir} — nothing gated")
        return 0
    cal_path = os.path.join(base_dir, "calibration.json")
    factor = 1.0
    if os.path.exists(cal_path):
        with open(cal_path) as fh:
            base_score = json.load(fh)["score"]
        cur_score = calibration_score()
        # clamp: a wildly different score means the micro-benchmark is not
        # representative on this machine; better a strict gate than none
        factor = min(max(base_score / cur_score, 0.25), 4.0)
        print(f"[trajectory] calibration: baseline {base_score:.1f}, "
              f"here {cur_score:.1f} -> machine factor {factor:.2f}")

    failed = False
    for bf in base_files:
        artifact = _artifact_of(bf)
        if not os.path.exists(artifact):
            print(f"FAIL {artifact}: baseline exists but the bench no "
                  "longer writes the artifact")
            failed = True
            continue
        failures, notes = compare(_rows_by_mode(artifact), _rows_by_mode(bf),
                                  tol=tol, factor=factor)
        for n in notes:
            print(f"  note {artifact}: {n}")
        for f in failures:
            print(f"FAIL {artifact}: {f}")
        if not failures:
            print(f"  ok  {artifact} vs {bf}")
        failed = failed or bool(failures)
    for artifact in sorted(glob.glob("BENCH_*.json")):
        if not os.path.exists(os.path.join(
                base_dir, artifact[len("BENCH_"):])):
            print(f"  note {artifact}: no baseline yet — run --refresh")
    print("[trajectory] " + ("REGRESSED" if failed else "ok"))
    return 1 if failed else 0


def refresh(base_dir: str) -> int:
    artifacts = sorted(glob.glob("BENCH_*.json"))
    if not artifacts:
        print("[trajectory] --refresh found no BENCH_*.json in cwd")
        return 1
    os.makedirs(base_dir, exist_ok=True)
    for artifact in artifacts:
        dest = os.path.join(base_dir, artifact[len("BENCH_"):])
        with open(artifact) as fh:
            rows = json.load(fh)
        with open(dest, "w") as fh:
            json.dump(rows, fh, indent=1)
        print(f"[trajectory] {artifact} -> {dest}")
    with open(os.path.join(base_dir, "calibration.json"), "w") as fh:
        json.dump({"score": calibration_score()}, fh)
    print(f"[trajectory] refreshed {len(artifacts)} baselines in {base_dir}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=None)
    ap.add_argument("--tol", type=float, default=float(
        os.environ.get("REPRO_TRAJECTORY_TOL", "0.20")))
    ap.add_argument("--refresh", action="store_true",
                    help="snapshot cwd artifacts as the new baseline "
                         "instead of gating")
    args = ap.parse_args(argv)
    if args.refresh:
        # --refresh defaults to the cache dir: refreshing the COMMITTED
        # snapshot is a deliberate, reviewed act (run it with
        # --baseline-dir benchmarks/baselines and commit the diff)
        return refresh(args.baseline_dir or CACHE_DIR)
    return check(baseline_dir(args.baseline_dir), args.tol)


if __name__ == "__main__":
    sys.exit(main())
