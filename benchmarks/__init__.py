"""Benchmarks: one module per paper figure/table (Fig 1, 4, 5, 8-15 and the
Appendix-A volume models).  ``python -m benchmarks.run`` executes all and
prints a CSV; each module also exposes ``run()`` returning rows."""
