"""Fig. 1: accuracy + latency of the three sampling strategies vs fraction
(sampling before / during / after the join)."""

from __future__ import annotations

from benchmarks.common import pair_with_overlap, row, scaled, timed
from repro.core import (QueryBudget, approx_join, native_join,
                        postjoin_sampling, prejoin_sampling)

FRACTIONS = scaled((0.01, 0.05, 0.1, 0.5), (0.05, 0.5))
N = scaled(1 << 13, 1 << 11)


def run() -> list[dict]:
    rels = pair_with_overlap(N, 0.2, seed=1, keys_per_dataset=512)
    exact = float(native_join(rels).estimate)
    rows = []
    for frac in FRACTIONS:
        t_pre, pre = timed(prejoin_sampling, rels, frac, seed=3)
        t_dur, dur = timed(
            lambda: approx_join(rels, QueryBudget(error=1.0,
                                                  pilot_fraction=frac),
                                max_strata=1024, b_max=2048, seed=3))
        t_post, post = timed(postjoin_sampling, rels, frac, seed=3,
                             max_strata=1024)
        for name, res, t in (("before_join", pre, t_pre),
                             ("during_join(approxjoin)", dur, t_dur),
                             ("after_join", post, t_post)):
            err = abs(float(res.estimate) - exact) / abs(exact)
            rows.append(row("fig01", strategy=name, fraction=frac,
                            accuracy_loss=round(err, 6),
                            latency_s=round(t, 4)))
    return rows
