"""Fig. 4: shuffled data size — (a) vs number of inputs at 1% overlap,
(b) vs overlap fraction with 3 inputs (closed-form Appendix-A models at the
paper's simulation scale, plus our measured meters at bench scale)."""

from __future__ import annotations

from benchmarks.common import row
from repro.core import volume_approxjoin, volume_broadcast, volume_repartition
from repro.core.bloom import num_blocks_for

K = 100                       # paper's simulated cluster size
TUPLE = 1024                  # bytes per record — the paper's simulation
                              # regime (its Fig-4 repartition volumes imply
                              # ~1 KiB records; see fig14 for the narrow-
                              # record crossover analysis)


def run() -> list[dict]:
    rows = []
    # (a) vary number of inputs, 1% overlap
    base = 10_000_000
    for n_inputs in (2, 3, 4, 5, 6):
        sizes = [base * TUPLE] * n_inputs
        live = [0.01 * base * TUPLE] * n_inputs
        fb = num_blocks_for(base, 0.01) * 32
        rows.append(row("fig04a", n_inputs=n_inputs,
                        broadcast_mb=round(volume_broadcast(sizes, K) / 1e6),
                        repartition_mb=round(
                            volume_repartition(sizes, K) / 1e6),
                        approxjoin_mb=round(
                            volume_approxjoin(live, fb, K) / 1e6)))
    # (b) vary overlap fraction, 3 inputs
    for ov in (0.01, 0.05, 0.1, 0.2, 0.4, 0.8):
        sizes = [base * TUPLE] * 3
        live = [ov * base * TUPLE] * 3
        fb = num_blocks_for(base, 0.01) * 32
        rows.append(row("fig04b", overlap=ov,
                        repartition_mb=round(
                            volume_repartition(sizes, K) / 1e6),
                        approxjoin_mb=round(
                            volume_approxjoin(live, fb, K) / 1e6)))
    return rows
