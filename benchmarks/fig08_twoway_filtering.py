"""Fig. 8: two-way joins, filtering stage only — latency breakdown
(filter build+probe vs join execution) for ApproxJoin / repartition /
native, across overlap fractions."""

from __future__ import annotations

import time

import jax

from benchmarks.common import pair_with_overlap, row, scaled
from repro.core import (QueryBudget, approx_join,
                        postjoin_sampling)
from repro.core.bloom import num_blocks_for
from repro.core.join import build_join_filter, filter_relations

N = scaled(1 << 14, 1 << 11)
OVERLAPS = scaled((0.01, 0.04, 0.1, 0.2), (0.04, 0.2))


def run() -> list[dict]:
    rows = []
    for ov in OVERLAPS:
        rels = pair_with_overlap(N, ov, seed=2)
        # warm-up (compile) before the stage timings
        approx_join(rels, QueryBudget(), max_strata=2048)
        nb_w = num_blocks_for(N, 0.01)
        filter_relations(rels, build_join_filter(rels, nb_w, 0))
        t0 = time.perf_counter()
        nb = num_blocks_for(N, 0.01)
        jf = build_join_filter(rels, nb, 0)
        live = filter_relations(rels, jf)
        jax.block_until_ready([r.valid for r in live])
        t_filter = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = approx_join(rels, QueryBudget(), max_strata=2048)
        jax.block_until_ready(res.estimate)
        t_total = time.perf_counter() - t0
        # native join: no filter AND the cross-product materialized
        # (postjoin path at fraction 1.0 evaluates ~every pair) — the
        # sufficient-stats native_join would hide the compute the paper
        # measures
        t0 = time.perf_counter()
        nat = postjoin_sampling(rels, 1.0, max_strata=2048, b_max=4096)
        jax.block_until_ready(nat.estimate)
        t_native = time.perf_counter() - t0
        rows.append(row(
            "fig08", overlap=ov,
            approx_filter_s=round(t_filter, 4),
            approx_total_s=round(t_total, 4),
            native_total_s=round(t_native, 4),
            shuffle_ratio=round(
                float(res.diagnostics.shuffled_bytes_repartition)
                / max(float(res.diagnostics.shuffled_bytes_filtered), 1),
                2)))
    return rows
