"""Fig. 13: real-world workloads — CAIDA-like 3-way flow join and the
Netflix-like ratings join: latency, shuffled bytes, accuracy vs fraction."""

from __future__ import annotations

from benchmarks.common import row, scaled, timed
from repro.core import (QueryBudget, approx_join,
                        postjoin_sampling)
from repro.data import flows, netflix

FLOW_SCALE = scaled(4096, 1024)
NETFLIX_N = scaled(1 << 15, 1 << 12)
NETFLIX_S = scaled(1 << 13, 1 << 10)


def run() -> list[dict]:
    rows = []
    # network flows: 3-way join, filtering only
    fr = flows.flow_tables(scale=FLOW_SCALE, shared_fraction=0.03,
                           seed=1)[::-1]
    t_aj, res = timed(lambda: approx_join(fr, QueryBudget(),
                                          max_strata=FLOW_SCALE), repeats=2)
    # materializing comparator (the paper's native join pays the full
    # cross-product); sufficient-stats native_join hides that cost
    t_nat, _ = timed(postjoin_sampling, fr, 1.0, max_strata=FLOW_SCALE,
                     b_max=2048, repeats=2)
    d = res.diagnostics
    rows.append(row("fig13_network", approxjoin_s=round(t_aj, 4),
                    native_s=round(t_nat, 4),
                    shuffle_reduction_x=round(
                        float(d.shuffled_bytes_repartition)
                        / max(float(d.shuffled_bytes_filtered), 1), 1)))
    exact = float(res.estimate)
    for frac in (0.1, 0.5):
        _, approx = timed(lambda: approx_join(
            fr, QueryBudget(error=1.0, pilot_fraction=frac),
            max_strata=FLOW_SCALE, b_max=512, seed=3), repeats=2)
        err = abs(float(approx.estimate) - exact) / abs(exact)
        rows.append(row("fig13_network", fraction=frac,
                        accuracy_loss=round(err, 6)))
    # netflix ratings join (latency only, as in the paper)
    nr = netflix.ratings_tables(NETFLIX_N, NETFLIX_N >> 3, seed=2)
    t_aj, res = timed(lambda: approx_join(nr, QueryBudget(),
                                          max_strata=NETFLIX_S), repeats=2)
    t_nat, _ = timed(postjoin_sampling, nr, 1.0, max_strata=NETFLIX_S,
                     b_max=2048, repeats=2)
    for frac in (0.1, 1.0):
        t_s, _ = timed(lambda: approx_join(
            nr, QueryBudget(error=1.0, pilot_fraction=frac),
            max_strata=NETFLIX_S, b_max=256, seed=4), repeats=2)
        rows.append(row("fig13_netflix", fraction=frac,
                        approxjoin_s=round(t_s, 4)))
    rows.append(row("fig13_netflix", exact_approxjoin_s=round(t_aj, 4),
                    native_s=round(t_nat, 4)))
    return rows
