"""Fig. 11: cost-function effectiveness — give ApproxJoin a latency budget,
measure the achieved latency (and the accuracy at the chosen sample size)."""

from __future__ import annotations

import time

import jax

from benchmarks.common import pair_with_overlap, row, scaled
from repro.core import QueryBudget, approx_join, native_join
from repro.core.cost import calibrate_pipeline

N = scaled(1 << 14, 1 << 12)


def run() -> list[dict]:
    rels = pair_with_overlap(N, 0.2, seed=7, keys_per_dataset=512)
    exact = float(native_join(rels).estimate)
    # calibrate against the REAL operator (paper Fig. 5 -> Fig. 11 loop)
    cost = calibrate_pipeline(rels, max_strata=1024, b_max=None, seed=8)
    rows = [row("fig11", beta=f"{cost.beta_compute:.2e}",
                eps=f"{cost.epsilon:.3f}")]
    for budget_s in (0.05, 0.2, 0.5):
        # steady-state timing (first call compiles the grid bucket; the
        # paper's fidelity claim is about repeated query execution)
        res = approx_join(rels, QueryBudget(latency_s=budget_s),
                          cost_model=cost, max_strata=1024, b_max=None,
                          seed=8)
        jax.block_until_ready(res.estimate)
        t0 = time.perf_counter()
        res = approx_join(rels, QueryBudget(latency_s=budget_s),
                          cost_model=cost, max_strata=1024, b_max=None,
                          seed=8)
        jax.block_until_ready(res.estimate)
        took = time.perf_counter() - t0
        err = abs(float(res.estimate) - exact) / abs(exact)
        rows.append(row("fig11", desired_s=budget_s,
                        achieved_s=round(took, 4),
                        sampled=bool(res.diagnostics.sampled),
                        draws=int(res.diagnostics.sample_draws)
                        if res.diagnostics.sampled else 0,
                        accuracy_loss=round(err, 6)))
    return rows
