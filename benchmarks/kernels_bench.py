"""Kernel-path microbenchmarks: join-stage wall times on this host and the
HBM-traffic model that motivates the fused edge_sample kernel (the jnp path
materializes the [S, b_max] grids; the kernel keeps them in VMEM)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, scaled, timed
from repro.core import bloom
from repro.core.relation import relation, sort_by_key
from repro.core.sampling import build_strata, sample_edges
from repro.kernels import ops

N = scaled(1 << 15, 1 << 12)
S, B_MAX = scaled(1024, 512), scaled(512, 128)


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    r1 = sort_by_key(relation(rng.integers(0, S // 2, N).astype(np.uint32),
                              rng.normal(3, 1, N).astype(np.float32)))
    r2 = sort_by_key(relation(rng.integers(0, S // 2, N).astype(np.uint32),
                              rng.normal(1, 2, N).astype(np.float32)))
    nb = bloom.num_blocks_for(N, 0.01)
    t_build, f = timed(lambda: bloom.build(r1.keys, r1.valid, nb, 0))
    t_probe, _ = timed(lambda: bloom.contains(f, r2.keys))
    strata = build_strata([r1, r2], S)
    import jax.numpy as jnp
    b_i = jnp.ceil(0.2 * strata.population)
    t_jnp, _ = timed(lambda: sample_edges([r1, r2], strata, b_i, B_MAX, 1))
    t_kern, _ = timed(lambda: ops.sample_stats([r1, r2], strata, b_i,
                                               B_MAX, 1, interpret=True))
    # HBM-traffic model (f32): jnp path materializes 2 idx + 2 val + f + f^2
    grid_bytes = S * B_MAX * 4 * 6
    fused_bytes = S * 4 * 3 + N * 4 * 2   # stats out + values in
    return [
        row("kernels", stage="bloom_build", seconds=round(t_build, 4),
            n=N),
        row("kernels", stage="bloom_probe", seconds=round(t_probe, 4),
            n=N),
        row("kernels", stage="edge_sample_jnp", seconds=round(t_jnp, 4),
            grid_hbm_mb=round(grid_bytes / 1e6, 1)),
        row("kernels", stage="edge_sample_fused(interpret)",
            seconds=round(t_kern, 4),
            fused_hbm_mb=round(fused_bytes / 1e6, 1),
            traffic_reduction_x=round(grid_bytes / fused_bytes, 1)),
    ]
