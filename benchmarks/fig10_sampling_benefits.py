"""Fig. 10: (a) scalability with worker count [shuffle-model, Appendix A],
(b) latency vs sampling fraction vs the extended repartition join,
(c) accuracy loss vs sampling fraction."""

from __future__ import annotations

from benchmarks.common import pair_with_overlap, row, scaled, timed
from repro.core import (QueryBudget, approx_join, native_join,
                        postjoin_sampling, volume_approxjoin,
                        volume_repartition)
from repro.core.bloom import num_blocks_for

N = scaled(1 << 14, 1 << 11)


def run() -> list[dict]:
    rows = []
    # (a) shuffle volume vs cluster size at 1% overlap (analytic, paper model)
    base = 10_000_000 * 8
    for k in (2, 4, 8, 16, 32):
        fb = num_blocks_for(10_000_000, 0.01) * 32
        rows.append(row("fig10a", k=k,
                        repartition_mb=round(
                            volume_repartition([base] * 2, k) / 1e6),
                        approxjoin_mb=round(
                            volume_approxjoin([0.01 * base] * 2, fb, k)
                            / 1e6)))
    # (b)+(c): latency & accuracy vs fraction, 20% overlap workload
    rels = pair_with_overlap(N, 0.2, seed=5, keys_per_dataset=512)
    exact = float(native_join(rels).estimate)
    for frac in (0.01, 0.1, 0.4, 0.8):
        t_dur, dur = timed(
            lambda: approx_join(rels, QueryBudget(error=1.0,
                                                  pilot_fraction=frac),
                                max_strata=1024, b_max=4096, seed=6),
            repeats=2)
        t_post, post = timed(postjoin_sampling, rels, frac, seed=6,
                             b_max=4096, max_strata=1024, repeats=2)
        rows.append(row(
            "fig10bc", fraction=frac,
            approxjoin_s=round(t_dur, 4),
            extended_repartition_s=round(t_post, 4),
            approxjoin_err=round(abs(float(dur.estimate) - exact)
                                 / abs(exact), 6),
            extended_repartition_err=round(abs(float(post.estimate) - exact)
                                           / abs(exact), 6)))
    return rows
