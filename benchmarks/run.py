"""Run every paper-figure benchmark and print one CSV.

  PYTHONPATH=src python -m benchmarks.run            # all, full scale
  PYTHONPATH=src python -m benchmarks.run fig01 ...  # subset by prefix
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI gate: every figure
                                                     # end-to-end, small scale
"""

from __future__ import annotations

import argparse
import importlib
import os
import time

MODULES = [
    ("fig01", "fig01_sampling_strategies"),
    ("fig04", "fig04_shuffle_models"),
    ("fig05", "fig05_cost_function"),
    ("fig08", "fig08_twoway_filtering"),
    ("fig09", "fig09_multiway"),
    ("fig10", "fig10_sampling_benefits"),
    ("fig11", "fig11_budget_fidelity"),
    ("fig12", "fig12_tpch"),
    ("fig13", "fig13_realworld"),
    ("fig14", "fig14_fp_tradeoff"),
    ("fig15", "fig15_bloom_variants"),
    ("kernels", "kernels_bench"),
    ("serve", "serve_bench"),
    ("stream", "stream_bench"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("figs", nargs="*", help="subset of figures, by prefix")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale end-to-end run of every figure")
    args = ap.parse_args()
    if args.smoke:
        # must land before the figure modules (and benchmarks.common) import
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    from benchmarks.common import print_rows

    failures = []
    for name, modname in MODULES:
        if args.figs and not any(name.startswith(w) for w in args.figs):
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{modname}")
            rows = mod.run()
            print_rows(rows)
            print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"# {name}: FAILED {type(e).__name__}: {e}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: "
                         f"{[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
