"""Run every paper-figure benchmark and print one CSV.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig01 ...  # subset by prefix
"""

from __future__ import annotations

import sys
import time

from benchmarks import (fig01_sampling_strategies, fig04_shuffle_models,
                        fig05_cost_function, fig08_twoway_filtering,
                        fig09_multiway, fig10_sampling_benefits,
                        fig11_budget_fidelity, fig12_tpch, fig13_realworld,
                        fig14_fp_tradeoff, fig15_bloom_variants,
                        kernels_bench)
from benchmarks.common import print_rows

MODULES = [
    ("fig01", fig01_sampling_strategies),
    ("fig04", fig04_shuffle_models),
    ("fig05", fig05_cost_function),
    ("fig08", fig08_twoway_filtering),
    ("fig09", fig09_multiway),
    ("fig10", fig10_sampling_benefits),
    ("fig11", fig11_budget_fidelity),
    ("fig12", fig12_tpch),
    ("fig13", fig13_realworld),
    ("fig14", fig14_fp_tradeoff),
    ("fig15", fig15_bloom_variants),
    ("kernels", kernels_bench),
]


def main() -> None:
    want = sys.argv[1:]
    failures = []
    for name, mod in MODULES:
        if want and not any(name.startswith(w) for w in want):
            continue
        t0 = time.time()
        try:
            rows = mod.run()
            print_rows(rows)
            print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"# {name}: FAILED {type(e).__name__}: {e}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: "
                         f"{[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
