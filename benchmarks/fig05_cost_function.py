"""Fig. 5: offline profiling of the cross-product latency vs input size —
the linearity that justifies d_cp = beta * CP_total (Eq. 5) — and the fitted
beta_compute for THIS machine (used by the budget benches)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, scaled
from repro.core.cost import calibrate_beta


def run() -> list[dict]:
    sizes = scaled((1 << 14, 1 << 16, 1 << 18, 1 << 20), (1 << 12, 1 << 14))
    cost = calibrate_beta(sizes=sizes, repeats=3)
    rows = [row("fig05", beta_compute=f"{cost.beta_compute:.3e}",
                epsilon=f"{cost.epsilon:.3e}")]
    # linearity check: residual of the linear fit
    import time

    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(0)

    @jax.jit
    def work(a, b):
        return jnp.sum(a + b) + jnp.sum((a + b) ** 2)

    for n in sizes:
        a = jnp.asarray(rng.random(n, np.float32))
        b = jnp.asarray(rng.random(n, np.float32))
        work(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            work(a, b).block_until_ready()
        t = (time.perf_counter() - t0) / 3
        pred = cost.beta_compute * n + cost.epsilon
        rows.append(row("fig05", n=n, measured_s=f"{t:.3e}",
                        linear_fit_s=f"{pred:.3e}"))
    return rows
