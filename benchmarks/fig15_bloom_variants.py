"""Fig. 15 (Appendix B): size of the Bloom-filter variants (regular /
counting / invertible / scalable / our split-block) vs false-positive rate,
at 100 K inserted items."""

from __future__ import annotations

from benchmarks.common import row
from repro.core import bloom

N = 100_000


def run() -> list[dict]:
    rows = []
    for fp in (0.1, 0.01, 0.001):
        rows.append(row(
            "fig15", fp_rate=fp,
            regular_kb=round(bloom.flat_filter_bits(N, fp) / 8e3, 1),
            split_block_kb=round(
                bloom.num_blocks_for(N, fp) * 32 / 1e3, 1),
            counting_kb=round(bloom.counting_filter_bits(N, fp) / 8e3, 1),
            invertible_kb=round(
                bloom.invertible_filter_bits(N, fp) / 8e3, 1),
            scalable_kb=round(bloom.scalable_filter_bits(N, fp) / 8e3, 1)))
    return rows
