"""Fig. 12: TPC-H — (a) Q3/Q4/Q10 join-core latencies for ApproxJoin vs the
SnappyData-shaped comparator (post-join sampling over offline synopses),
(b) latency and (c) accuracy vs sampling fraction on the
CUSTOMER |><| ORDERS money query."""

from __future__ import annotations

from benchmarks.common import row, scaled, timed
from repro.core import QueryBudget, approx_join, native_join, postjoin_sampling
from repro.data import tpch

SCALE = scaled(0.005, 0.002)


def run() -> list[dict]:
    t = tpch.generate(scale=SCALE, seed=1)
    rows = []
    # (a) query join cores, filtering only (exact), vs post-join comparator
    cores = {"Q3": tpch.q3_core(t), "Q4": [tpch.q4_core(t)],
             "Q10": tpch.q10_core(t)}
    for name, joins in cores.items():
        t_aj = t_sd = 0.0
        for rels in joins:
            ta, _ = timed(lambda r=rels: approx_join(
                r, QueryBudget(), max_strata=1 << 13), repeats=2)
            ts, _ = timed(postjoin_sampling, rels, 1.0, max_strata=1 << 13,
                          b_max=64, repeats=2)
            t_aj += ta
            t_sd += ts
        rows.append(row("fig12a", query=name,
                        approxjoin_s=round(t_aj, 4),
                        snappydata_style_s=round(t_sd, 4)))
    # (b)+(c) the money query with sampling
    rels = tpch.q_customer_orders(t)
    exact = float(native_join(rels).estimate)
    for frac in (0.2, 0.6, 1.0):
        if frac >= 1.0:
            ta, res = timed(lambda: approx_join(rels, QueryBudget(),
                                                max_strata=1 << 13),
                            repeats=2)
            err = abs(float(res.estimate) - exact) / abs(exact)
        else:
            ta, res = timed(lambda: approx_join(
                rels, QueryBudget(error=100.0, pilot_fraction=frac),
                max_strata=1 << 13, b_max=64, seed=9), repeats=2)
            err = abs(float(res.estimate) - exact) / abs(exact)
        ts, post = timed(postjoin_sampling, rels, frac,
                         max_strata=1 << 13, b_max=64, repeats=2)
        err_post = abs(float(post.estimate) - exact) / abs(exact)
        rows.append(row("fig12bc", fraction=frac,
                        approxjoin_s=round(ta, 4),
                        snappydata_style_s=round(ts, 4),
                        approxjoin_err=round(err, 6),
                        snappydata_style_err=round(err_post, 6)))
    return rows
