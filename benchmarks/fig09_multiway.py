"""Fig. 9: multi-way joins — latency and shuffled size vs overlap fraction
(3-way) and vs number of inputs (2/3/4-way at the paper's overlap setup)."""

from __future__ import annotations

from benchmarks.common import row, scaled, timed
from repro.core import QueryBudget, approx_join
from repro.data.synthetic import overlapping_relations

N = scaled(1 << 13, 1 << 11)


def run() -> list[dict]:
    rows = []
    for ov in (0.01, 0.06, 0.1):
        rels = overlapping_relations([N] * 3, ov, seed=3)
        t, res = timed(lambda: approx_join(rels, QueryBudget(),
                                           max_strata=2048), repeats=2)
        d = res.diagnostics
        rows.append(row("fig09ab", overlap=ov, latency_s=round(t, 4),
                        shuffled_filtered_b=int(d.shuffled_bytes_filtered),
                        shuffled_repartition_b=int(
                            d.shuffled_bytes_repartition),
                        reduction_x=round(
                            float(d.shuffled_bytes_repartition)
                            / max(float(d.shuffled_bytes_filtered), 1), 2)))
    # paper setup: 2-way ov=1%, 3-way ov=0.33%, 4-way ov=0.25%
    for n_inputs, ov in ((2, 0.01), (3, 0.0033), (4, 0.0025)):
        rels = overlapping_relations([N] * n_inputs, ov, seed=4)
        t, res = timed(lambda: approx_join(rels, QueryBudget(),
                                           max_strata=2048), repeats=2)
        d = res.diagnostics
        rows.append(row("fig09c", n_inputs=n_inputs, overlap=ov,
                        latency_s=round(t, 4),
                        reduction_x=round(
                            float(d.shuffled_bytes_repartition)
                            / max(float(d.shuffled_bytes_filtered), 1), 2)))
    return rows
