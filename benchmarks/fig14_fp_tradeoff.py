"""Fig. 14: shuffled volume vs Bloom false-positive rate (Appendix A.1
simulation: |R1|=1e4, |R2|=1e6, |R3|=1e7, 1% overlap, k=100) — broadcast,
repartition, ApproxJoin, and the no-false-positive optimum."""

from __future__ import annotations

from benchmarks.common import row
from repro.core import volume_broadcast, volume_repartition
from repro.core.bloom import num_blocks_for

K = 100
SIZES = (10_000, 1_000_000, 10_000_000)
# Full records ride the shuffle (the paper's simulation joins wide tuples);
# the |BF| broadcast cost in Eq. 24 is paid per *key*, so the win grows
# with record width.  1 KiB ~ a flow record with payload metadata; the 8 B
# narrow case is reported too to show the crossover honestly.
TUPLE = 1024
TUPLE_NARROW = 8
OVERLAP = 0.01


def run() -> list[dict]:
    rows = []
    sizes_b = [s * TUPLE for s in SIZES]
    live_b = [OVERLAP * s * TUPLE for s in SIZES]
    opt = (sum(live_b) * (K - 1) / K)
    for fp in (0.5, 0.2, 0.1, 0.05, 0.01, 0.001):
        fb = num_blocks_for(max(SIZES), fp) * 32
        # false positives let (fp x non-joining) tuples through the filter
        leaked = [fp * (s - l) for s, l in zip(sizes_b, live_b)]
        vol = fb * (K - 1) * (len(SIZES) + 1) \
            + (sum(live_b) + sum(leaked)) * (K - 1) / K
        rows.append(row("fig14", fp_rate=fp,
                        approxjoin_mb=round(vol / 1e6, 2),
                        optimal_mb=round(
                            (fb * (K - 1) * (len(SIZES) + 1) + opt) / 1e6,
                            2)))
    rows.append(row("fig14",
                    broadcast_mb=round(volume_broadcast(sizes_b, K) / 1e6, 1),
                    repartition_mb=round(
                        volume_repartition(sizes_b, K) / 1e6, 1)))
    # narrow-record crossover: with 8 B tuples the filter broadcast
    # dominates and repartition wins — the technique pays off when
    # |record| >> bits-per-key, which the paper's workloads satisfy
    sizes_n = [s_ * TUPLE_NARROW for s_ in SIZES]
    fb = num_blocks_for(max(SIZES), 0.01) * 32
    live_n = [OVERLAP * s_ for s_ in sizes_n]
    vol_n = fb * (K - 1) * (len(SIZES) + 1) \
        + sum(live_n) * (K - 1) / K
    rows.append(row("fig14", note="narrow_8B_crossover",
                    approxjoin_mb=round(vol_n / 1e6, 1),
                    repartition_mb=round(
                        volume_repartition(sizes_n, K) / 1e6, 1)))
    return rows
