"""JoinServer serving throughput: batched multi-tenant engine vs cold
approx_join driver calls on the same query stream.

Two capacity shape classes are interleaved (the worst case for batching);
the engine must (a) batch same-class queries into fused dispatches and
(b) show ZERO executable-cache compiles after the warmup phase — asserted
here, which makes this bench the compiled-executable-reuse regression gate.

``--distributed`` additionally serves the same workload through the mesh
pipeline at 1/2/4/8 host-platform devices — in BOTH serve modes
(exact-parity gather merge vs psum merge with capacity-planned buckets) —
reporting q/s, measured per-device shuffled bytes, the static per-device
wire-bytes model, dropped-tuple counts, and the per-dataset
Bloom-filter-reuse counter (one build per registered relation across the
whole multi-step run — asserted).  At every mesh size > 1 the psum mode's
wire bytes must be STRICTLY below the gather mode's (asserted: that is the
point of the capacity-planned serve path).  The full row set is written to
``BENCH_serve.json`` so the serving perf trajectory is recorded per run.
Re-execs itself under ``--xla_force_host_platform_device_count=8`` when
needed:

  PYTHONPATH=src python -m benchmarks.serve_bench --distributed

``--kernels`` runs the Pallas-path regression gate instead: batched kernel
serving must beat the retired per-query kernel loop on q/s, bit-identically
per slot of a mixed-seed batch, with zero recompiles/filter rebuilds after
warmup (seeds are runtime kernel operands) — asserted — and writes the
``BENCH_kernel.json`` artifact.

``--async-trace`` runs the async-tier gate: one Poisson arrival trace
(rate = 60% of the warmed engine's calibrated capacity) replayed three
ways — caller-driven step loop, ``AsyncJoinServer`` event loop, 2-replica
``AsyncJoinFrontDoor`` — with per-query bit-parity across all three
asserted, async q/s >= step loop, and async queue-latency p95 STRICTLY
below it.  Writes ``BENCH_async.json``.  ``REPRO_TRACE_QUERIES`` scales
the trace (smoke default 48 in CI, 1024 full; set it to 1_000_000 for a
million-query soak).

``--plans`` runs the query-plan regression gate: a 2-node plan (2-way
stage + fused 3-way stage) served through ``JoinServer.submit_plan`` must
be bit-identical per node to the composed direct ``approx_join`` calls,
beat them on q/s with zero recompiles after warmup and one plan compile
(cache hits after), and the compiled byte model must show the cascaded
Bloom-intersection pushdown strictly reducing modeled shuffle bytes vs a
left-deep binary join tree — all asserted — writing ``BENCH_plan.json``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import row, scaled
from repro.core.budget import QueryBudget
from repro.core.cost import SigmaRegistry
from repro.core.join import approx_join
from repro.data.synthetic import overlapping_relations
from repro.runtime.join_serve import JoinRequest, JoinServer

N = scaled(1 << 13, 1 << 11)
SLOTS = 4
ROUNDS = scaled(3, 1)          # main-phase rounds of SLOTS queries per class
MAX_STRATA = 2048
B_MAX = 512
MESH_SIZES = (1, 2, 4, 8)


def _workload(seed: int):
    """Two shape classes (N and 2N rows), one tenant dataset each."""
    return {
        "small": overlapping_relations([N, N], 0.1, seed=seed),
        "large": overlapping_relations([2 * N, 2 * N], 0.1, seed=seed + 1),
    }


def _request(tenant: str, rels, q: int) -> JoinRequest:
    # query ids cycle over the batch width: sigma pipelining defers same-id
    # repeats to later steps, so id diversity is what keeps batches full
    return JoinRequest(rels=rels, budget=QueryBudget(error=0.5),
                       query_id=f"{tenant}/sum{q % SLOTS}", seed=100 + q,
                       max_strata=MAX_STRATA, b_max=B_MAX)


def run() -> list[dict]:
    datasets = _workload(seed=7)
    queries = SLOTS * ROUNDS

    # --- cold driver baseline: one approx_join per query, no reuse --------
    reg = SigmaRegistry()
    t0 = time.perf_counter()
    for q in range(queries):
        for tenant, rels in datasets.items():
            approx_join(rels, QueryBudget(error=0.5), max_strata=MAX_STRATA,
                        b_max=B_MAX, seed=100 + q, sigma_registry=reg,
                        query_id=f"{tenant}/sum")
    cold_s = time.perf_counter() - t0
    cold_n = queries * len(datasets)

    # --- server: warmup covers every (stage, class, batch) executable -----
    server = JoinServer(batch_slots=SLOTS)
    for q in range(SLOTS):
        for tenant, rels in datasets.items():
            server.submit(_request(tenant, rels, q))
    server.run()
    warm = server.diagnostics.snapshot()
    # the timed phase reuses the warmed server: clear the latency rings so
    # the reported percentiles cover ONLY the timed segment (warmup-era
    # waits include compile time and used to leak into the p95)
    server.diagnostics.reset_latencies()

    for q in range(queries):
        for tenant, rels in datasets.items():
            server.submit(_request(tenant, rels, SLOTS + q))
    t0 = time.perf_counter()
    server.run()
    serve_s = time.perf_counter() - t0
    d = server.diagnostics
    recompiles = d.compiles - warm["compiles"]
    assert recompiles == 0, \
        f"executable cache missed after warmup: {recompiles} recompiles"
    assert d.max_batch == SLOTS, d.max_batch

    served = d.queries - warm["queries"]
    snap = d.snapshot()
    return [
        row("serve", mode="cold", queries=cold_n, seconds=round(cold_s, 3),
            qps=round(cold_n / cold_s, 2)),
        row("serve", mode="server", queries=served,
            seconds=round(serve_s, 3), qps=round(served / serve_s, 2),
            compiles=d.compiles, recompiles_after_warmup=recompiles,
            cache_hits=d.cache_hits, max_batch=d.max_batch,
            queue_latency_p50_s=round(snap["queue_latency_p50_s"], 4),
            queue_latency_p95_s=round(snap["queue_latency_p95_s"], 4),
            queue_latency_max_s=round(snap["queue_latency_max_s"], 4)),
        row("serve", mode="speedup",
            x=round((served / serve_s) / (cold_n / cold_s), 2)),
    ]


# -- replayed-trace gate: async event-loop tier vs the caller-driven step
# -- loop on one arrival trace (the ISSUE-6 acceptance bench) ---------------

TRACE_Q = int(os.environ.get("REPRO_TRACE_QUERIES", scaled(1024, 48)))
TRACE_UTIL = 0.6               # arrival rate as a fraction of capacity
EXACT_EVERY = 7                # every 7th trace query is an exact budget


def _trace(queries: int) -> list[tuple]:
    """Deterministic mixed tenant trace: two shape classes interleaved,
    per-tenant query ids cycling the batch width (id diversity keeps sigma
    pipelining from starving batches), a sprinkle of exact budgets.  No
    latency budgets: their sample sizing consults the MEASURED filter time,
    so they are timing-dependent by design and would break the bit-parity
    assertion between replays."""
    trace = []
    tenants = ("small", "large")
    for q in range(queries):
        tenant = tenants[q % 2]
        budget = QueryBudget() if q % EXACT_EVERY == EXACT_EVERY - 1 \
            else QueryBudget(error=0.5)
        trace.append((tenant, dict(
            budget=budget, query_id=f"{tenant}/sum{(q // 2) % SLOTS}",
            seed=100 + q, filter_seed=7, max_strata=MAX_STRATA,
            b_max=B_MAX)))
    return trace


def _warm_for_trace(engine: JoinServer) -> None:
    """Compile every (stage, class, fill-bucket) combination the replay
    can hit: fills of 1/2/4 per tenant, each stage mix (the continuous
    batcher dispatches partial fills, so the pow2 buckets 1 and 2 matter
    as much as the full batch).  Warm ids are disjoint from trace ids, so
    both replays start with identical (empty) trace sigma state."""
    plans = ([("exact", 0)], [("err", 0)],
             [("err", 0), ("exact", 1)],
             [("err", 0), ("err", 1), ("err", 2), ("exact", 3)])
    k = 0
    for tenant in ("small", "large"):
        for plan in plans:
            for kind, j in plan:
                budget = QueryBudget() if kind == "exact" \
                    else QueryBudget(error=0.5)
                engine.submit(JoinRequest(
                    dataset=tenant, budget=budget,
                    query_id=f"{tenant}/warm{j}", seed=900 + k,
                    filter_seed=7, max_strata=MAX_STRATA, b_max=B_MAX))
                k += 1
            engine.run()


def _calibrate_qps(server: JoinServer) -> float:
    """Full-batch capacity of the warmed engine (queries/s); the trace's
    Poisson arrival rate is TRACE_UTIL of this, so the same trace loads
    fast and slow machines equally."""
    n = 0
    t0 = time.perf_counter()
    for r in range(2):
        for q in range(SLOTS):
            for tenant in ("small", "large"):
                server.submit(JoinRequest(
                    dataset=tenant, budget=QueryBudget(error=0.5),
                    query_id=f"{tenant}/cal{q}", seed=500 + SLOTS * r + q,
                    filter_seed=7, max_strata=MAX_STRATA, b_max=B_MAX))
                n += 1
        server.run()
    return n / (time.perf_counter() - t0)


def _replay_step_loop(server: JoinServer, trace: list,
                      arrivals) -> tuple[list, float]:
    """The caller-driven pattern the async tier retires: admit arrivals,
    step only once some shape class can fill a whole batch (or the trace
    is exhausted) — batch width bought with queue-latency budget."""
    from collections import Counter
    results, i = [], 0
    t0 = time.perf_counter()
    while i < len(trace) or server.queue:
        now = time.perf_counter() - t0
        while i < len(trace) and arrivals[i] <= now:
            tenant, kw = trace[i]
            results.append(server.submit(JoinRequest(dataset=tenant, **kw)))
            i += 1
        counts = Counter(r._class for r in server.queue)
        if counts and (i == len(trace)
                       or max(counts.values()) >= SLOTS):
            server.step()
        elif i < len(trace):
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.002))
    return results, time.perf_counter() - t0


def _replay_async(submit, trace: list, arrivals) -> tuple[list, float]:
    """Replay the same arrivals against an async submit(): ingestion
    returns futures immediately; the event loop batches continuously."""
    futs = []
    t0 = time.perf_counter()
    for (tenant, kw), at in zip(trace, arrivals):
        lag = at - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        futs.append(submit(JoinRequest(dataset=tenant, **kw)))
    results = [f.result(timeout=600) for f in futs]
    return results, time.perf_counter() - t0


def _latency_pcts(results: list) -> dict:
    import numpy as np
    queue = np.asarray([r.queue_latency_s for r in results], np.float64)
    e2e = np.asarray([r.e2e_latency_s for r in results], np.float64)
    return {"queue_latency_p50_s": round(float(np.percentile(queue, 50)), 4),
            "queue_latency_p95_s": round(float(np.percentile(queue, 95)), 4),
            "e2e_latency_p95_s": round(float(np.percentile(e2e, 95)), 4)}


def _assert_parity(name: str, base: list, other: list) -> None:
    """Per-trace-index bit-identity across replays: slot results never
    depend on batch composition and per-id sigma sequences are
    order-deterministic, so ANY divergence is a scheduling bug."""
    assert len(base) == len(other)
    for i, (a, b) in enumerate(zip(base, other)):
        ra, rb = a.result, b.result
        assert (float(ra.estimate) == float(rb.estimate)
                and float(ra.error_bound) == float(rb.error_bound)
                and float(ra.count) == float(rb.count)), \
            f"{name}: trace index {i} ({a.query_id}) diverged"


def run_async_trace() -> list[dict]:
    """Replayed-trace gate: the async event-loop tier must serve the SAME
    Poisson arrival trace at q/s >= the step loop with queue-latency p95
    STRICTLY below it, bit-identically per query — all asserted.  A
    2-replica front-door leg (tenant sharding + work stealing) replays the
    trace too, also bit-identically.  Smoke-scaled in CI; set
    REPRO_TRACE_QUERIES for large (e.g. million-query) replays."""
    import numpy as np
    from repro.runtime.async_serve import AsyncJoinFrontDoor, AsyncJoinServer

    datasets = _workload(seed=7)
    trace = _trace(TRACE_Q)

    # --- step-loop baseline ------------------------------------------------
    sync = JoinServer(batch_slots=SLOTS)
    for tenant, rels in datasets.items():
        sync.register_dataset(tenant, rels)
    _warm_for_trace(sync)
    rate = TRACE_UTIL * _calibrate_qps(sync)
    arrivals = np.random.default_rng(11).exponential(
        1.0 / rate, size=len(trace)).cumsum()
    compiles0 = sync.diagnostics.compiles
    sync_res, sync_s = _replay_step_loop(sync, trace, arrivals)
    assert sync.diagnostics.compiles == compiles0, "step loop recompiled"

    # --- async event loop, same engine configuration -----------------------
    with AsyncJoinServer(JoinServer(batch_slots=SLOTS)) as srv:
        for tenant, rels in datasets.items():
            srv.register_dataset(tenant, rels)
        srv.call(lambda: _warm_for_trace(srv.engine)).result()
        compiles0 = srv.snapshot()["compiles"]
        async_res, async_s = _replay_async(srv.submit, trace, arrivals)
        snap = srv.snapshot()
    assert snap["compiles"] == compiles0, "async tier recompiled"
    _assert_parity("async-vs-sync", sync_res, async_res)

    # --- 2-replica front door: tenant sharding + work stealing -------------
    with AsyncJoinFrontDoor(replicas=2, batch_slots=SLOTS) as fd:
        for tenant, rels in datasets.items():
            fd.register_dataset(tenant, rels)
        for rep in fd.replicas:
            rep.call(lambda eng=rep.engine: _warm_for_trace(eng)).result()
        fd_res, fd_s = _replay_async(fd.submit, trace, arrivals)
        steals = fd.steals
    _assert_parity("front-door-vs-sync", sync_res, fd_res)

    sync_p, async_p, fd_p = (_latency_pcts(r)
                             for r in (sync_res, async_res, fd_res))
    sync_qps = len(trace) / sync_s
    async_qps = len(trace) / async_s
    assert async_qps >= sync_qps, \
        f"async tier lost throughput: {async_qps:.2f} < {sync_qps:.2f} q/s"
    assert async_p["queue_latency_p95_s"] < sync_p["queue_latency_p95_s"], \
        (f"async queue p95 not below step loop: {async_p} vs {sync_p}")
    return [
        row("async", mode="step-loop", queries=len(trace),
            seconds=round(sync_s, 3), qps=round(sync_qps, 2), **sync_p),
        row("async", mode="event-loop", queries=len(trace),
            seconds=round(async_s, 3), qps=round(async_qps, 2), **async_p,
            backfilled=snap["backfilled"], recompiles_after_warmup=0),
        row("async", mode="front-door2", queries=len(trace),
            seconds=round(fd_s, 3), qps=round(len(trace) / fd_s, 2),
            **fd_p, steals=steals),
        row("async", mode="speedup",
            x=round(async_qps / sync_qps, 3),
            p95_ratio=round(sync_p["queue_latency_p95_s"]
                            / max(async_p["queue_latency_p95_s"], 1e-9), 2)),
    ]


def run_trace() -> list[dict]:
    """Tracing-overhead gate: the SAME warmed server serves the same query
    stream with tracing off (``NULL_TRACER``) and on (a fresh enabled
    ``Tracer`` per segment), best-of-3 each; tracing must cost < 5% q/s —
    asserted.  The traced segments must also produce a complete artifact:
    a validating Chrome trace export and one byte-reconciliation record
    per served query.  Writes ``BENCH_trace.json``."""
    from repro.runtime.telemetry import (NULL_TRACER, Tracer, chrome_trace,
                                         validate_chrome_trace)

    server = JoinServer(batch_slots=SLOTS)
    for tenant, rels in _workload(seed=7).items():
        server.register_dataset(tenant, rels)

    def submit(q):
        # one filter seed: dataset words build once; ids cycle the batch
        # width so sigma pipelining keeps every segment's batches full
        for tenant in ("small", "large"):
            server.submit(JoinRequest(
                dataset=tenant, budget=QueryBudget(error=0.5),
                query_id=f"{tenant}/sum{q % SLOTS}", seed=100 + q,
                filter_seed=7, max_strata=MAX_STRATA, b_max=B_MAX))

    for q in range(SLOTS):               # warmup: compile every executable
        submit(q)
    server.run()
    warm = server.diagnostics.snapshot()

    queries = SLOTS * max(ROUNDS, 2)     # per-segment width (noise guard)
    segments = 3                         # best-of-3 per mode
    best, tracer = {}, None
    for mode in ("off", "on"):
        best[mode] = float("inf")
        for _seg in range(segments):
            server.tracer = NULL_TRACER if mode == "off" \
                else Tracer(enabled=True)
            server.diagnostics.reset_latencies()
            for q in range(queries):
                submit(SLOTS + q)
            t0 = time.perf_counter()
            server.run()
            best[mode] = min(best[mode], time.perf_counter() - t0)
            if mode == "on":
                tracer = server.tracer
    server.tracer = NULL_TRACER
    d = server.diagnostics
    assert d.compiles == warm["compiles"], "trace segments recompiled"

    served = 2 * queries                 # per segment
    # the traced segment produced the full artifact, not just counters
    n_events = validate_chrome_trace(chrome_trace(tracer))
    assert len(tracer.recon) == served, (len(tracer.recon), served)

    qps_off = served / best["off"]
    qps_on = served / best["on"]
    overhead = qps_on / qps_off
    assert overhead >= 0.95, \
        (f"tracing overhead above 5% q/s: {qps_on:.2f} traced vs "
         f"{qps_off:.2f} untraced")
    return [
        row("trace", mode="off", queries=served,
            seconds=round(best["off"], 3), qps=round(qps_off, 2)),
        row("trace", mode="on", queries=served,
            seconds=round(best["on"], 3), qps=round(qps_on, 2),
            events=n_events, recon_records=len(tracer.recon)),
        row("trace", mode="overhead", x=round(overhead, 3)),
    ]


def run_kernels() -> list[dict]:
    """Batched Pallas serving vs the retired per-query kernel loop.

    The baseline is exactly what ``JoinServer._run_kernel`` used to do: one
    direct ``approx_join(use_kernels=True)`` per query.  The engine must
    (a) beat it on q/s by batching kernel queries through the stacked
    ``(batch_slot, ...)`` grids, (b) show ZERO recompiles and ZERO filter
    rebuilds after warmup across a mixed-seed sweep and mixed batch fills
    (seeds are runtime kernel operands), and (c) stay bit-identical to the
    per-query driver for every slot of a mixed-seed batch — all asserted
    here, making this bench the kernel-path regression gate.
    """
    rels = _workload(seed=7)["small"]
    queries = SLOTS * ROUNDS
    segments = 3                          # best-of-3 (timing noise guard)

    # --- per-query kernel baseline ----------------------------------------
    # two warm calls off the clock: the first compiles the kernel wrappers
    # (pilot round), the second the sigma-fed decide path (t-quantile etc.)
    reg = SigmaRegistry()
    for s in (98, 99):
        approx_join(rels, QueryBudget(error=0.5), max_strata=MAX_STRATA,
                    b_max=B_MAX, seed=s, use_kernels=True,
                    sigma_registry=reg, query_id="warm")
    perq_s = float("inf")
    for seg in range(segments):
        t0 = time.perf_counter()
        for q in range(queries):
            approx_join(rels, QueryBudget(error=0.5), max_strata=MAX_STRATA,
                        b_max=B_MAX, seed=100 + q, use_kernels=True,
                        sigma_registry=reg, query_id=f"k/sum{q % SLOTS}")
        perq_s = min(perq_s, time.perf_counter() - t0)

    # --- batched kernel server --------------------------------------------
    server = JoinServer(batch_slots=SLOTS)
    server.register_dataset("k", rels)

    def submit(q, qid=None):
        # fixed filter_seed + per-query sampling seeds: the dataset words
        # build once, every seed rides the same compiled executables
        return server.submit(JoinRequest(
            dataset="k", budget=QueryBudget(error=0.5),
            query_id=qid or f"k/sum{q % SLOTS}", seed=100 + q, filter_seed=7,
            max_strata=MAX_STRATA, b_max=B_MAX, use_kernels=True))

    for r in range(2):                   # full fills: pilot + sigma rounds
        for q in range(SLOTS):
            submit(8 * r + q)
        server.run()
    submit(0, "odd0"), submit(1, "odd1")  # partial (2-wide) fill
    server.run()
    warm = server.diagnostics.snapshot()

    serve_s, served_seg = float("inf"), 0
    for seg in range(segments):
        # one warmed server serves all three segments: reset the latency
        # rings per segment so no segment's percentiles mix earlier samples
        server.diagnostics.reset_latencies()
        for q in range(queries):
            submit(SLOTS + q)
        for q in range(2):               # mixed fills in the timed phase
            submit(SLOTS + queries + q, f"odd{q}")
        t0 = time.perf_counter()
        server.run()
        dt = time.perf_counter() - t0
        if dt < serve_s:
            serve_s, served_seg = dt, queries + 2
    d = server.diagnostics
    recompiles = d.compiles - warm["compiles"]
    assert recompiles == 0, \
        f"kernel classes recompiled after warmup: {recompiles}"
    assert d.filter_builds == warm["filter_builds"], \
        "seed sweep rebuilt dataset filter words"
    assert d.kernel_gather_bytes == 0.0, d.kernel_gather_bytes
    served = served_seg

    # --- per-slot bit-identity of one mixed-seed batch --------------------
    seeds = (301, 17, 301, 995)
    bq = [server.submit(JoinRequest(
        rels=rels, budget=QueryBudget(error=0.5), query_id=f"bit{i}",
        seed=s, max_strata=MAX_STRATA, b_max=B_MAX, use_kernels=True))
        for i, s in enumerate(seeds)]
    assert server.step() == len(seeds)
    for req, s in zip(bq, seeds):
        direct = approx_join(rels, QueryBudget(error=0.5),
                             max_strata=MAX_STRATA, b_max=B_MAX, seed=s,
                             use_kernels=True)
        assert (float(req.result.estimate) == float(direct.estimate)
                and float(req.result.error_bound)
                == float(direct.error_bound)
                and float(req.result.count) == float(direct.count)), \
            f"slot seed {s} diverged from per-query approx_join"

    perq_qps = queries / perq_s
    serve_qps = served / serve_s
    assert serve_qps > perq_qps, \
        f"batched kernel path lost to per-query: {serve_qps} <= {perq_qps}"
    return [
        row("serve", mode="kernel/per-query", queries=queries,
            seconds=round(perq_s, 3), qps=round(perq_qps, 2)),
        row("serve", mode="kernel/batched", queries=served,
            seconds=round(serve_s, 3), qps=round(serve_qps, 2),
            recompiles_after_warmup=recompiles,
            filter_builds=d.filter_builds,
            kernel_gather_bytes=round(d.kernel_gather_bytes),
            max_batch=d.max_batch),
        row("serve", mode="kernel/speedup",
            x=round(serve_qps / perq_qps, 2)),
    ]


def run_plans() -> list[dict]:
    """Query-plan serving gate: compiled multi-way plans vs composed calls.

    One 2-node plan (a 2-way stage plus a fused 3-way stage referencing it)
    is served two ways over the same id-cycled stream: composed direct
    ``approx_join`` calls per node, and ``JoinServer.submit_plan`` batching
    node queries through the warmed executables.  Asserted: (a) the
    compiled byte model shows the cascaded-intersection pushdown strictly
    reducing modeled shuffle bytes vs the left-deep binary tree on the
    3-way node, (b) one plan compile + cache hits for every resubmission,
    (c) ZERO executable recompiles after warmup, (d) per-node bit-identity
    of a served plan vs the composed direct calls, (e) the batched plan
    path beats the composed driver loop on q/s.
    """
    from repro.core.plan import Plan, PlanNode

    a, b, c = overlapping_relations([N, N, N], 0.1, seed=7)
    server = JoinServer(batch_slots=SLOTS)
    for name, rel in zip("abc", (a, b, c)):
        server.register_dataset(name, [rel])
    plan = Plan((
        PlanNode("ab", ("a", "b"), budget=QueryBudget(error=0.5),
                 max_strata=MAX_STRATA, b_max=B_MAX),
        PlanNode("abc", ("ab", "c"), budget=QueryBudget(error=0.5),
                 max_strata=MAX_STRATA, b_max=B_MAX),
    ))

    # --- pushdown byte model: the point of fusing to one n-way stage ------
    compiled = server.compile_plan(plan)
    m3 = compiled.bytes_model["abc"]
    assert m3["bytes_pushdown"] < m3["bytes_binary"], m3
    assert m3["reduction_x"] > 1.0, m3
    assert compiled.bytes_model["ab"]["reduction_x"] == 1.0  # 2-way: equal

    plans = SLOTS * ROUNDS
    composed = (("ab", [a, b]), ("abc", [a, b, c]))

    # --- composed-driver baseline: one approx_join per node per plan ------
    reg = SigmaRegistry()
    for name, rels in composed:          # warm round off the clock
        approx_join(rels, QueryBudget(error=0.5), max_strata=MAX_STRATA,
                    b_max=B_MAX, seed=90, sigma_registry=reg,
                    query_id=f"warm/{name}")
    t0 = time.perf_counter()
    for q in range(plans):
        for name, rels in composed:
            approx_join(rels, QueryBudget(error=0.5), max_strata=MAX_STRATA,
                        b_max=B_MAX, seed=100 + q, sigma_registry=reg,
                        query_id=f"p{q % SLOTS}/{name}")
    direct_s = time.perf_counter() - t0
    direct_n = plans * len(composed)

    # --- plan server: warmup (pilot + sigma rounds), then the timed phase -
    for r in range(2):
        for q in range(SLOTS):
            server.submit_plan(plan, query_id=f"p{q % SLOTS}",
                               seed=100 + SLOTS * r + q)
        server.run()
    warm = server.diagnostics.snapshot()
    server.diagnostics.reset_latencies()

    for q in range(plans):
        server.submit_plan(plan, query_id=f"p{q % SLOTS}",
                           seed=200 + q)
    t0 = time.perf_counter()
    server.run()
    serve_s = time.perf_counter() - t0
    d = server.diagnostics
    recompiles = d.compiles - warm["compiles"]
    assert recompiles == 0, \
        f"plan stages recompiled after warmup: {recompiles}"
    served = d.queries - warm["queries"]

    # --- per-node bit-identity of one served plan vs the composed calls ---
    handle = server.submit_plan(plan, query_id="bit", seed=993)
    server.run()
    assert handle.done
    for name, rels in composed:
        direct = approx_join(rels, QueryBudget(error=0.5),
                             max_strata=MAX_STRATA, b_max=B_MAX, seed=993,
                             query_id=f"bit/{name}")
        got = handle.results()[name]
        assert (float(got.estimate) == float(direct.estimate)
                and float(got.error_bound) == float(direct.error_bound)
                and float(got.count) == float(direct.count)), \
            f"plan node {name} diverged from the composed direct call"

    # one compile for the plan signature; every resubmission was a cache hit
    assert d.plan_compiles == 1, d.plan_compiles
    assert d.plan_cache_hits == 2 * SLOTS + plans + 1, d.plan_cache_hits

    direct_qps = direct_n / direct_s
    serve_qps = served / serve_s
    assert serve_qps > direct_qps, \
        f"plan serving lost to composed driver: {serve_qps} <= {direct_qps}"
    return [
        row("plan", mode="composed-direct", queries=direct_n,
            seconds=round(direct_s, 3), qps=round(direct_qps, 2)),
        row("plan", mode="server", queries=served,
            seconds=round(serve_s, 3), qps=round(serve_qps, 2),
            recompiles_after_warmup=recompiles,
            plan_compiles=d.plan_compiles,
            plan_cache_hits=d.plan_cache_hits, max_batch=d.max_batch),
        row("plan", mode="pushdown-model", n=m3["n"],
            bytes_pushdown=m3["bytes_pushdown"],
            bytes_binary=m3["bytes_binary"],
            reduction_x=round(m3["reduction_x"], 3),
            overlap=round(m3["overlap"], 4)),
        row("plan", mode="speedup", x=round(serve_qps / direct_qps, 2)),
    ]


def _run_distributed_leg(devices: int,
                         serve_mode: str = "exact-parity") -> dict:
    """Serve one dataset-handle workload on a ``devices``-wide mesh."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:devices]), ("data",))
    server = JoinServer(batch_slots=SLOTS, mesh=mesh, serve_mode=serve_mode)
    for tenant, rels in _workload(seed=7).items():
        server.register_dataset(tenant, rels)

    def submit(tenant, q):
        # one seed for the whole run: the per-dataset filter words must be
        # built once per relation and reused every subsequent step; ids
        # cycle so sigma pipelining keeps the batches full
        server.submit(JoinRequest(dataset=tenant,
                                  budget=QueryBudget(error=0.5),
                                  query_id=f"{tenant}/sum{q % SLOTS}",
                                  seed=100, max_strata=MAX_STRATA,
                                  b_max=B_MAX))

    for q in range(SLOTS):               # warmup: compile every executable
        for tenant in ("small", "large"):
            submit(tenant, q)
    server.run()
    warm = server.diagnostics.snapshot()

    queries = SLOTS * ROUNDS
    for q in range(queries):
        for tenant in ("small", "large"):
            submit(tenant, q)
    t0 = time.perf_counter()
    server.run()
    dt = time.perf_counter() - t0
    d = server.diagnostics
    recompiles = d.compiles - warm["compiles"]
    assert recompiles == 0, \
        f"mesh[{devices}] recompiled after warmup: {recompiles}"
    # Bloom-filter reuse: one build per registered relation (2 datasets x 2
    # relations at seed 100) across the whole multi-step run
    assert d.filter_builds == 4, d.filter_builds
    assert d.filter_cache_hits > 0
    served = d.queries - warm["queries"]
    return row("serve", mode=f"mesh{devices}/{serve_mode}", queries=served,
               seconds=round(dt, 3), qps=round(served / dt, 2),
               recompiles_after_warmup=recompiles,
               filter_builds=d.filter_builds,
               filter_cache_hits=d.filter_cache_hits,
               shuffled_bytes_total=round(d.dist_shuffled_tuple_bytes),
               per_device_shuffled_bytes=[
                   int(round(float(b))) for b in d.per_device_shuffled_bytes],
               wire_bytes_model=round(d.dist_wire_bytes_model),
               dropped_tuples=round(d.dist_dropped_tuples),
               per_device_dropped_tuples=[
                   int(round(float(b)))
                   for b in d.per_device_dropped_tuples])


def _all_distributed_legs() -> list[dict]:
    return [_run_distributed_leg(devices, serve_mode)
            for devices in MESH_SIZES
            for serve_mode in ("exact-parity", "psum")]


def _check_psum_beats_gather(rows: list[dict]) -> None:
    """The capacity-planned psum path must put strictly fewer bytes on the
    wire than the gather-merge path at every mesh size > 1, without
    uncounted losses (exact-parity legs may never drop)."""
    by_mode = {r["mode"]: r for r in rows if r["mode"].startswith("mesh")}
    for devices in MESH_SIZES:
        gather = by_mode[f"mesh{devices}/exact-parity"]
        psum = by_mode[f"mesh{devices}/psum"]
        assert gather["dropped_tuples"] == 0, gather
        if devices > 1:
            assert psum["wire_bytes_model"] < gather["wire_bytes_model"], \
                (devices, psum["wire_bytes_model"],
                 gather["wire_bytes_model"])


def run_distributed() -> list[dict]:
    """q/s + shuffle meters at 1/2/4/8 host devices, both serve modes.

    Spawns a child with ``--xla_force_host_platform_device_count=8`` when
    this process has fewer devices (the flag must precede jax init); the
    child emits one JSON row per (mesh size, serve mode) on stdout.
    """
    import jax
    if jax.device_count() < max(MESH_SIZES):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                            "--xla_force_host_platform_device_count="
                            f"{max(MESH_SIZES)}").strip()
        env.setdefault("JAX_PLATFORMS", "cpu")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_bench",
             "--distributed-child"],
            env=env, capture_output=True, text=True, timeout=3600)
        assert out.returncode == 0, out.stderr[-3000:]
        rows = [json.loads(line) for line in out.stdout.splitlines()
                if line.startswith("{")]
    else:
        rows = _all_distributed_legs()
    _check_psum_beats_gather(rows)
    return rows


def main() -> None:
    from benchmarks.common import print_rows
    if "--distributed-child" in sys.argv:
        for r in _all_distributed_legs():
            print(json.dumps(r), flush=True)
        return
    if "--async-trace" in sys.argv:
        # replayed-trace gate: async tier q/s >= step loop, queue p95
        # strictly below, per-query bit-parity — asserted in
        # run_async_trace; the artifact feeds check_trajectory
        arows = run_async_trace()
        with open("BENCH_async.json", "w") as fh:
            json.dump(arows, fh, indent=1)
        print("wrote BENCH_async.json")
        print_rows(arows)
        return
    if "--plans" in sys.argv:
        # query-plan regression gate: compiled plans must be bit-identical
        # to the composed driver calls, beat them on q/s with zero
        # recompiles, and the cascaded pushdown must strictly reduce
        # modeled shuffle bytes — all asserted in run_plans
        prows = run_plans()
        with open("BENCH_plan.json", "w") as fh:
            json.dump(prows, fh, indent=1)
        print("wrote BENCH_plan.json")
        print_rows(prows)
        return
    if "--trace" in sys.argv:
        # tracing-overhead gate: < 5% q/s vs tracing-off on the same warmed
        # server, with a validating chrome export and per-query recon
        # records — asserted in run_trace; the artifact feeds
        # check_trajectory against the committed trace.json baseline
        trows = run_trace()
        with open("BENCH_trace.json", "w") as fh:
            json.dump(trows, fh, indent=1)
        print("wrote BENCH_trace.json")
        print_rows(trows)
        return
    if "--kernels" in sys.argv:
        # kernel-path regression gate: batched Pallas serving must beat the
        # per-query kernel baseline, bit-identically, with zero recompiles;
        # its own artifact rides beside BENCH_serve.json in CI
        krows = run_kernels()
        with open("BENCH_kernel.json", "w") as fh:
            json.dump(krows, fh, indent=1)
        print("wrote BENCH_kernel.json")
        print_rows(krows)
        return
    rows = run()
    if "--distributed" in sys.argv:
        rows += run_distributed()
        # the artifact that records the serving perf trajectory per run:
        # q/s, per-device shuffled bytes, wire-model bytes, dropped tuples
        with open("BENCH_serve.json", "w") as fh:
            json.dump(rows, fh, indent=1)
        print("wrote BENCH_serve.json")
    print_rows(rows)


if __name__ == "__main__":
    main()
