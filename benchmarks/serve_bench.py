"""JoinServer serving throughput: batched multi-tenant engine vs cold
approx_join driver calls on the same query stream.

Two capacity shape classes are interleaved (the worst case for batching);
the engine must (a) batch same-class queries into fused dispatches and
(b) show ZERO executable-cache compiles after the warmup phase — asserted
here, which makes this bench the compiled-executable-reuse regression gate.
"""

from __future__ import annotations

import time

from benchmarks.common import row, scaled
from repro.core.budget import QueryBudget
from repro.core.cost import SigmaRegistry
from repro.core.join import approx_join
from repro.data.synthetic import overlapping_relations
from repro.runtime.join_serve import JoinRequest, JoinServer

N = scaled(1 << 13, 1 << 11)
SLOTS = 4
ROUNDS = scaled(3, 1)          # main-phase rounds of SLOTS queries per class
MAX_STRATA = 2048
B_MAX = 512


def _workload(seed: int):
    """Two shape classes (N and 2N rows), one tenant dataset each."""
    return {
        "small": overlapping_relations([N, N], 0.1, seed=seed),
        "large": overlapping_relations([2 * N, 2 * N], 0.1, seed=seed + 1),
    }


def _request(tenant: str, rels, q: int) -> JoinRequest:
    return JoinRequest(rels=rels, budget=QueryBudget(error=0.5),
                       query_id=f"{tenant}/sum", seed=100 + q,
                       max_strata=MAX_STRATA, b_max=B_MAX)


def run() -> list[dict]:
    datasets = _workload(seed=7)
    queries = SLOTS * ROUNDS

    # --- cold driver baseline: one approx_join per query, no reuse --------
    reg = SigmaRegistry()
    t0 = time.perf_counter()
    for q in range(queries):
        for tenant, rels in datasets.items():
            approx_join(rels, QueryBudget(error=0.5), max_strata=MAX_STRATA,
                        b_max=B_MAX, seed=100 + q, sigma_registry=reg,
                        query_id=f"{tenant}/sum")
    cold_s = time.perf_counter() - t0
    cold_n = queries * len(datasets)

    # --- server: warmup covers every (stage, class, batch) executable -----
    server = JoinServer(batch_slots=SLOTS)
    for q in range(SLOTS):
        for tenant, rels in datasets.items():
            server.submit(_request(tenant, rels, q))
    server.run()
    warm = server.diagnostics.snapshot()

    for q in range(queries):
        for tenant, rels in datasets.items():
            server.submit(_request(tenant, rels, SLOTS + q))
    t0 = time.perf_counter()
    server.run()
    serve_s = time.perf_counter() - t0
    d = server.diagnostics
    recompiles = d.compiles - warm["compiles"]
    assert recompiles == 0, \
        f"executable cache missed after warmup: {recompiles} recompiles"
    assert d.max_batch == SLOTS, d.max_batch

    served = d.queries - warm["queries"]
    return [
        row("serve", mode="cold", queries=cold_n, seconds=round(cold_s, 3),
            qps=round(cold_n / cold_s, 2)),
        row("serve", mode="server", queries=served,
            seconds=round(serve_s, 3), qps=round(served / serve_s, 2),
            compiles=d.compiles, recompiles_after_warmup=recompiles,
            cache_hits=d.cache_hits, max_batch=d.max_batch),
        row("serve", mode="speedup",
            x=round((served / serve_s) / (cold_n / cold_s), 2)),
    ]
