"""Shared benchmark helpers: timed execution, workload construction, CSV
row emission.  Bench scale is CPU-sized (2^13-2^15 rows); the paper's
cluster-scale claims are reproduced as *ratios* (latency ratios, shuffle
ratios, accuracy curves), which is what the figures plot."""

from __future__ import annotations

import os
import time

import jax
import numpy as np

# Smoke mode (CI gate): every figure script runs end-to-end at reduced scale.
# Set by `python -m benchmarks.run --smoke` before the figure modules import.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def scaled(full, smoke):
    """Pick the smoke-mode value when REPRO_BENCH_SMOKE is set."""
    return smoke if SMOKE else full


def timed(fn, *args, repeats: int = 3, **kw):
    """(median seconds, result) with a warmup call."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def pair_with_overlap(n: int, overlap: float, seed: int = 0,
                      keys_per_dataset: int = 2048):
    from repro.data.synthetic import overlapping_relations
    return overlapping_relations([n, n], overlap, seed=seed,
                                 keys_per_dataset=keys_per_dataset)


def row(bench: str, **fields) -> dict:
    return {"bench": bench, **fields}


def print_rows(rows) -> None:
    for r in rows:
        bench = r.pop("bench")
        body = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{bench},{body}")
