"""StreamJoin sustained throughput: incremental windowing vs a
re-register-every-window baseline on an identical micro-batch stream.

Both sides serve the SAME sliding windows with the SAME seeds, budgets and
sigma history — asserted bit-identical per window, so the comparison is
pure mechanism: the incremental session builds one new sub-window filter
per input per slide (survivors hit the filter-word cache) and fingerprints
only the arriving micro-batch, while the baseline re-registers every window
as a fresh dataset (full-window fingerprint + full-window filter build,
every time).  The incremental path must win on sustained tuples/sec —
asserted, that is the subsystem's reason to exist — and zero executable
recompiles after warmup is asserted on the streaming side (the steady-state
contract).

Reports sustained tuples/sec and per-window serve latency (mean/p95), plus
the filter build/reuse counters and the server queue-latency percentiles.
The row set is written to ``BENCH_stream.json`` (uploaded by CI next to
``BENCH_serve.json``), recording the streaming perf trajectory per run.

  PYTHONPATH=src python -m benchmarks.stream_bench [--smoke]
"""

from __future__ import annotations

import json
import os
import sys
import time
from collections import deque


def _config():
    from benchmarks.common import scaled
    return {
        "sub_rows": scaled(2048, 512),
        "window": 16,              # sub-windows per window, slide 1
        "timed": scaled(16, 6),    # timed arrivals per segment
        "segments": 3,             # best-of-N timed segments (noise guard)
        "max_strata": 2048,
        "b_max": 512,
        "seed": 9,
    }


def _stream(cfg, arrivals: int):
    """Pre-generated micro-batch pairs (host work off the clock)."""
    from repro.data.synthetic import overlapping_relations
    return [overlapping_relations([cfg["sub_rows"]] * 2, 0.1,
                                  seed=1000 + i)
            for i in range(arrivals)]


def _budget():
    from repro.core.budget import QueryBudget
    return QueryBudget(error=0.5)


def _timed_segments(cfg, batches, serve_one):
    """Drive the timed arrivals in ``segments`` equal slices; return
    (per-window latencies, best-segment tuples/sec) — best-of-N so a noisy
    CI neighbour cannot decide the incremental-vs-baseline comparison."""
    warm_n = cfg["window"] + 1
    seg_len = cfg["timed"]
    lat, seg_tps = [], []
    for s in range(cfg["segments"]):
        seg = batches[warm_n + s * seg_len: warm_n + (s + 1) * seg_len]
        t0 = time.perf_counter()
        for mb in seg:
            t = time.perf_counter()
            serve_one(mb)
            lat.append(time.perf_counter() - t)
        dt = time.perf_counter() - t0
        seg_tps.append(len(seg) * 2 * cfg["sub_rows"] / dt)
    return lat, max(seg_tps)


def _lat_row(lat):
    return dict(
        window_ms_mean=round(1e3 * sum(lat) / len(lat), 2),
        window_ms_p95=round(1e3 * sorted(lat)[int(0.95 * (len(lat) - 1))],
                            2))


def run_incremental(cfg, batches):
    from benchmarks.common import row
    from repro.core.window import WindowSpec
    from repro.runtime.stream_join import StreamJoinServer

    srv = StreamJoinServer(batch_slots=1)
    sess = srv.open_stream(
        "bench", WindowSpec(cfg["window"], 1, cfg["sub_rows"]),
        budget=_budget(), max_strata=cfg["max_strata"], b_max=cfg["b_max"],
        seed=cfg["seed"])
    warm_n = cfg["window"] + 1     # first window compiles; one slide warms
    for mb in batches[:warm_n]:
        sess.push(mb)
        srv.run()
    warm = srv.diagnostics.snapshot()

    def serve_one(mb):
        sess.push(mb)
        srv.run()

    lat, tps = _timed_segments(cfg, batches, serve_one)
    d = srv.diagnostics.snapshot()
    recompiles = d["compiles"] - warm["compiles"]
    assert recompiles == 0, \
        f"stream steady state recompiled: {recompiles}"
    results = {r.window_id: r for r in sess.drain()}
    return results, row(
        "stream", mode="incremental", windows=len(lat),
        tuples_per_s=round(tps), **_lat_row(lat),
        recompiles_after_warmup=recompiles,
        filter_builds=d["filter_builds"],
        filter_cache_hits=d["filter_cache_hits"],
        queue_latency_p50_s=round(d["queue_latency_p50_s"], 4),
        queue_latency_p95_s=round(d["queue_latency_p95_s"], 4))


def run_reregister(cfg, batches):
    from benchmarks.common import row
    from repro.core.relation import bucket_to_pow2, concatenate
    from repro.runtime.join_serve import JoinRequest, JoinServer

    srv = JoinServer(batch_slots=1)
    ring: deque = deque(maxlen=cfg["window"])
    w = 0

    def serve_window():
        nonlocal w
        wid = w
        rels = [bucket_to_pow2(concatenate([mb[side] for mb in ring]))
                for side in range(2)]
        srv.register_dataset(f"w{wid}", rels)
        q = srv.submit(JoinRequest(
            dataset=f"w{wid}", budget=_budget(), query_id="bench/stream",
            seed=cfg["seed"] + 1 + wid, filter_seed=cfg["seed"],
            max_strata=cfg["max_strata"], b_max=cfg["b_max"]))
        srv.run()
        w += 1
        return wid, q

    warm_n = cfg["window"] + 1
    results = {}
    for mb in batches[:warm_n]:
        ring.append(mb)
        if len(ring) == cfg["window"]:
            wid, q = serve_window()
            results[wid] = q

    def serve_one(mb):
        ring.append(mb)
        wid, q = serve_window()
        srv.datasets.pop(f"w{wid}")           # streaming parity: no hoard
        results[wid] = q

    lat, tps = _timed_segments(cfg, batches, serve_one)
    d = srv.diagnostics.snapshot()
    return results, row(
        "stream", mode="reregister", windows=len(lat),
        tuples_per_s=round(tps), **_lat_row(lat),
        filter_builds=d["filter_builds"],
        filter_cache_hits=d["filter_cache_hits"])


def run() -> list[dict]:
    from benchmarks.common import row
    cfg = _config()
    batches = _stream(cfg, cfg["window"] + 1
                      + cfg["segments"] * cfg["timed"])
    inc_results, inc = run_incremental(cfg, batches)
    rr_results, rr = run_reregister(cfg, batches)
    # same stream, same seeds -> the two paths must serve identical windows
    # (this is what makes the throughput comparison mechanism-only)
    for wid, q in rr_results.items():
        r = inc_results.get(wid)
        if r is None:
            continue
        assert float(r.result.estimate) == float(q.result.estimate), wid
        assert float(r.result.error_bound) == float(q.result.error_bound), wid
    assert inc["tuples_per_s"] > rr["tuples_per_s"], \
        (inc["tuples_per_s"], rr["tuples_per_s"])
    return [inc, rr,
            row("stream", mode="speedup",
                x=round(inc["tuples_per_s"] / rr["tuples_per_s"], 2))]


def main() -> None:
    if "--smoke" in sys.argv:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    from benchmarks.common import print_rows
    rows = run()
    with open("BENCH_stream.json", "w") as fh:
        json.dump(rows, fh, indent=1)
    print("wrote BENCH_stream.json")
    print_rows(rows)


if __name__ == "__main__":
    main()
