"""Quickstart: the paper's query surface in 40 lines.

    SELECT SUM(R1.V + R2.V) FROM R1, R2 WHERE R1.A = R2.A
    ERROR 0.01 CONFIDENCE 95%

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import QueryBudget, approx_join, native_join, parse_budget
from repro.core.relation import relation

rng = np.random.default_rng(0)
N = 1 << 14

# Two inputs with partially overlapping keys (only the shared keys join).
r1 = relation(rng.integers(0, 1000, N).astype(np.uint32),
              rng.normal(10.0, 2.0, N).astype(np.float32))
r2 = relation(rng.integers(800, 1800, N).astype(np.uint32),
              rng.normal(5.0, 1.0, N).astype(np.float32))

# --- exact join (no budget): Bloom-filtered, sufficient-statistics path ---
exact = approx_join([r1, r2])
print(f"exact    SUM = {float(exact.estimate):14.1f}   "
      f"join size = {int(exact.count)}")
print(f"         overlap fraction = "
      f"{float(exact.diagnostics.overlap_fraction):.3f}, "
      f"shuffle {int(exact.diagnostics.shuffled_bytes_filtered)} B vs "
      f"{int(exact.diagnostics.shuffled_bytes_repartition)} B unfiltered")

# --- approximate join under the paper's budget clause ---
budget = parse_budget("ERROR 0.01 CONFIDENCE 95%")
approx = approx_join([r1, r2], budget, max_strata=2048, b_max=1024, seed=1)
err = abs(float(approx.estimate) - float(exact.estimate)) \
    / float(exact.estimate)
print(f"sampled  SUM = {float(approx.estimate):14.1f} "
      f"+/- {float(approx.error_bound):10.1f}   "
      f"(draws = {int(approx.diagnostics.sample_draws)}, "
      f"true rel err = {err:.5f})")

# --- sanity: the unfiltered baseline agrees ---
base = native_join([r1, r2])
assert abs(float(base.estimate) - float(exact.estimate)) \
    / float(exact.estimate) < 1e-5
print("native join agrees with the filtered exact path  [OK]")
