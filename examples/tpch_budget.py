"""Paper §5.5: TPC-H CUSTOMER |><| ORDERS — 'how much money did customers
have before ordering?' — under a LATENCY budget, with the cost function
picking the sample size and the sigma feedback loop tightening the second
run (§3.2).

Run:  PYTHONPATH=src python examples/tpch_budget.py
"""

import time

import jax

from repro.core import QueryBudget, SigmaRegistry, approx_join
from repro.core.cost import calibrate_beta
from repro.data import tpch

t = tpch.generate(scale=0.01, seed=3)
rels = tpch.q_customer_orders(t)
print(f"CUSTOMER rows = {len(t.customer_key)}, "
      f"ORDERS rows = {len(t.orders_key)}")

print("calibrating beta_compute (paper Fig. 5 offline profiling)...")
cost = calibrate_beta()
print(f"  beta = {cost.beta_compute:.3e} s/edge, "
      f"eps = {cost.epsilon:.3e} s")

exact = approx_join(rels, QueryBudget(), max_strata=1 << 14)
print(f"exact SUM(o_totalprice + c_acctbal) = {float(exact.estimate):.6g}")

for budget_s in (0.1, 0.3):
    t0 = time.perf_counter()
    res = approx_join(rels, QueryBudget(latency_s=budget_s),
                      cost_model=cost, max_strata=1 << 14, b_max=2048,
                      seed=4)
    jax.block_until_ready(res.estimate)
    took = time.perf_counter() - t0
    err = abs(float(res.estimate) - float(exact.estimate)) \
        / float(exact.estimate)
    mode = "sampled" if res.diagnostics.sampled else "exact-fastpath"
    print(f"WITHIN {budget_s:.2f} SECONDS -> {took:.3f}s ({mode}), "
          f"estimate {float(res.estimate):.6g}, rel err {err:.5f}")

# error-budget with the feedback loop: run 1 pilots, run 2 uses stored sigma
reg = SigmaRegistry()
for attempt in (1, 2):
    res = approx_join(rels, QueryBudget(error=50.0), max_strata=1 << 14,
                      b_max=2048, sigma_registry=reg, query_id="money",
                      seed=4 + attempt)
    print(f"ERROR 50 run {attempt}: estimate {float(res.estimate):.6g} "
          f"+/- {float(res.error_bound):.4g} "
          f"(draws {int(res.diagnostics.sample_draws)})")
