"""End-to-end driver (deliverable b): train a ~100M-parameter qwen3-family
model for a few hundred steps on the deterministic structured stream, with
checkpointing + elastic restore, and an ApproxJoin-planned batch mixture
feeding the pipeline.

At full width this is ~100M params on CPU — takes a while; pass --small to
demo the identical codepath at toy width.

Run:  PYTHONPATH=src python examples/train_lm.py [--small] [--steps N]
"""

import argparse
import dataclasses

import numpy as np

from repro.core import QueryBudget
from repro.core.relation import relation
from repro.data.pipeline import mixture_shard_counts, plan_batch_mixture
from repro.launch.train import run as train_run
from repro.models.config import ARCHS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # 1) plan the batch mixture with the paper's operator: join a document
    #    weight table against a domain table within an error budget.
    rng = np.random.default_rng(0)
    docs = relation(rng.integers(0, 16, 8192).astype(np.uint32),
                    rng.random(8192).astype(np.float32))
    domains = relation(np.arange(16, dtype=np.uint32),
                       np.ones(16, np.float32))
    plan = plan_batch_mixture(docs, domains, QueryBudget(error=0.05))
    counts = mixture_shard_counts(plan, batch=8)
    print(f"[mixture] {len(plan.weights)} domains via ApproxJoin "
          f"(estimate {plan.estimate:.1f} +/- {plan.error_bound:.1f}); "
          f"per-batch seq counts = {counts.tolist()}")

    # 2) train: ~100M params (d=512, 12 layers, vocab 32k) or toy width.
    import repro.launch.train as T

    if args.small:
        out = train_run("qwen3-1.7b", steps=args.steps, batch=8, seq=64,
                        reduced=True, ckpt_dir=args.ckpt_dir + "-small",
                        ckpt_every=100)
    else:
        # patch a ~100M config in: same family, reduced dims
        cfg = ARCHS["qwen3-1.7b"]
        cfg100m = dataclasses.replace(
            cfg, n_layers=12, d_model=640, n_heads=10, n_kv_heads=5,
            head_dim=64, d_ff=2560, vocab=32768, attn_chunk=None)
        orig = dict(ARCHS)
        ARCHS["qwen3-100m"] = cfg100m
        try:
            out = train_run("qwen3-100m", steps=args.steps, batch=4,
                            seq=128, reduced=False,
                            ckpt_dir=args.ckpt_dir, ckpt_every=100,
                            log_every=10)
        finally:
            ARCHS.clear()
            ARCHS.update(orig)
    print(f"[train_lm] loss {out['first_loss']:.4f} -> "
          f"{out['final_loss']:.4f} over {args.steps} steps")
    assert out["final_loss"] < out["first_loss"], "loss must decrease"


if __name__ == "__main__":
    main()
