"""Paper §6.1: 'What is the total size of the flows that appeared in all
TCP, UDP and ICMP traffic?' — a 3-way join over CAIDA-like flow tables,
exact vs budgeted-approximate, with the shuffle-volume meters.

Run:  PYTHONPATH=src python examples/network_flows.py
"""

import time

import jax

from repro.core import QueryBudget, approx_join
from repro.data.flows import flow_tables

tcp, udp, icmp = flow_tables(scale=8192, shared_fraction=0.03, seed=7)
rels = [icmp, udp, tcp]   # lead with the smallest input (fewest strata)
print(f"flows: tcp={int(tcp.count())} udp={int(udp.count())} "
      f"icmp={int(icmp.count())}")

t0 = time.perf_counter()
exact = approx_join(rels, QueryBudget(), max_strata=8192)
jax.block_until_ready(exact.estimate)
t_exact = time.perf_counter() - t0
d = exact.diagnostics
print(f"exact:   total bytes = {float(exact.estimate):.4g}  "
      f"({int(exact.count)} joined flow triples, {t_exact:.2f}s)")
print(f"         shuffle reduction: "
      f"{float(d.shuffled_bytes_repartition) / float(d.shuffled_bytes_filtered):.1f}x "
      f"less data on the wire than a repartition join")

t0 = time.perf_counter()
approx = approx_join(rels, QueryBudget(error=0.02, pilot_fraction=0.1),
                     max_strata=8192, b_max=256, seed=1)
jax.block_until_ready(approx.estimate)
t_approx = time.perf_counter() - t0
err = abs(float(approx.estimate) - float(exact.estimate)) \
    / float(exact.estimate)
print(f"sampled: total bytes = {float(approx.estimate):.4g} "
      f"+/- {float(approx.error_bound):.3g}  "
      f"({t_approx:.2f}s, true rel err {err:.4f})")
