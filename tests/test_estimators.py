"""Estimator correctness: t-quantiles, CLT coverage, Horvitz-Thompson
unbiasedness, distributed-merge equivalence."""

import jax.numpy as jnp
import numpy as np
from conftest import hypothesis_or_stubs
from repro.core.estimators import (StratumStats, clt_count, clt_finish,
                                   clt_sum, clt_sum_parts,
                                   horvitz_thompson_sum,
                                   inclusion_probability, t_quantile)

given, settings, st = hypothesis_or_stubs()

# two-sided 97.5% t quantiles (scipy.stats.t.ppf(0.975, df))
_T975 = {5: 2.5706, 10: 2.2281, 30: 2.0423, 100: 1.9840, 1000: 1.9623}


def test_t_quantile_known_values():
    for df, want in _T975.items():
        got = float(t_quantile(0.975, df))
        assert abs(got - want) < 2e-2, (df, got, want)
    assert abs(float(t_quantile(0.975, 1e6)) - 1.95996) < 1e-3


def test_t_quantile_monotone_in_confidence():
    df = 20.0
    qs = [float(t_quantile(p, df)) for p in (0.9, 0.95, 0.975, 0.995)]
    assert qs == sorted(qs)


def _stats_from_population(rng, pops, sample_frac):
    """Draw with replacement from synthetic strata; return stats + truth."""
    valid, B, b, sf, sf2 = [], [], [], [], []
    truth = 0.0
    for i, n in enumerate(pops):
        vals = rng.normal(5.0 + i, 1.0 + 0.2 * i, size=n)
        truth += vals.sum()
        k = max(int(n * sample_frac), 2)
        pick = rng.choice(vals, size=k, replace=True)
        valid.append(True)
        B.append(float(n))
        b.append(float(k))
        sf.append(float(pick.sum()))
        sf2.append(float((pick ** 2).sum()))
    stats = StratumStats(jnp.asarray(valid), jnp.asarray(B, jnp.float32),
                         jnp.asarray(b, jnp.float32),
                         jnp.asarray(sf, jnp.float32),
                         jnp.asarray(sf2, jnp.float32))
    return stats, truth


def test_clt_coverage():
    """95% CI covers the truth at roughly the nominal rate."""
    rng = np.random.default_rng(7)
    pops = [50, 200, 1000, 3000]
    hits = 0
    trials = 120
    for _ in range(trials):
        stats, truth = _stats_from_population(rng, pops, 0.1)
        est = clt_sum(stats, 0.95)
        hits += bool(est.lo <= truth <= est.hi)
    assert hits / trials >= 0.85, hits / trials


def test_clt_count_exact():
    stats = StratumStats(jnp.asarray([True, True, False]),
                         jnp.asarray([10.0, 20.0, 99.0]),
                         jnp.asarray([2.0, 2.0, 0.0]),
                         jnp.zeros(3), jnp.zeros(3))
    assert float(clt_count(stats)) == 30.0


def test_parts_merge_equals_direct():
    """psum-style merge of per-shard parts == single-shot estimate."""
    rng = np.random.default_rng(3)
    s1, t1 = _stats_from_population(rng, [100, 500], 0.2)
    s2, t2 = _stats_from_population(rng, [300, 50], 0.2)
    merged = clt_sum_parts(s1)
    p2 = clt_sum_parts(s2)
    merged = type(merged)(*[a + b for a, b in zip(merged, p2)])
    est_merged = clt_finish(merged)
    whole = StratumStats(*[jnp.concatenate([a, b])
                           for a, b in zip(s1, s2)])
    est_whole = clt_sum(whole)
    np.testing.assert_allclose(float(est_merged.estimate),
                               float(est_whole.estimate), rtol=1e-6)
    np.testing.assert_allclose(float(est_merged.error_bound),
                               float(est_whole.error_bound), rtol=1e-5)


def test_inclusion_probability_limits():
    # tiny sample of a huge stratum: pi ~ b/B
    pi = float(inclusion_probability(jnp.asarray(1e6), jnp.asarray(10.0)))
    assert abs(pi - 1e-5) / 1e-5 < 0.01
    # sampling B-with-replacement draws: pi -> 1 - 1/e
    pi = float(inclusion_probability(jnp.asarray(100.0), jnp.asarray(100.0)))
    assert abs(pi - (1 - np.exp(-1))) < 0.01


def test_horvitz_thompson_unbiased():
    """HT over deduplicated draws averages to the truth."""
    rng = np.random.default_rng(11)
    B = 200
    vals = rng.normal(3.0, 1.0, size=B)
    truth = vals.sum()
    ests = []
    for _ in range(200):
        k = 60
        idx = rng.integers(0, B, size=k)
        uniq = np.unique(idx)
        stats = StratumStats(jnp.asarray([True]),
                             jnp.asarray([float(B)]),
                             jnp.asarray([float(k)]),
                             jnp.asarray([0.0]), jnp.asarray([0.0]))
        est = horvitz_thompson_sum(stats,
                                   jnp.asarray([float(vals[uniq].sum())]),
                                   jnp.asarray([float(len(uniq))]))
        ests.append(float(est.estimate))
    assert abs(np.mean(ests) - truth) / abs(truth) < 0.03


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(1, 1e4), min_size=1, max_size=8),
       st.floats(0.01, 1.0))
def test_clt_variance_nonnegative(pops, frac):
    rng = np.random.default_rng(0)
    stats, _ = _stats_from_population(rng, [max(int(p), 3) for p in pops],
                                      max(frac, 0.05))
    est = clt_sum(stats)
    assert float(est.variance) >= 0.0
    assert float(est.error_bound) >= 0.0
