"""Estimator correctness: t-quantiles, CLT coverage, Horvitz-Thompson
unbiasedness, distributed-merge equivalence."""

import jax.numpy as jnp
import numpy as np
from conftest import hypothesis_or_stubs
from repro.core.estimators import (HTParts, StratumStats, clt_avg,
                                   clt_avg_from, clt_count, clt_finish,
                                   clt_stdev, clt_stdev_from, clt_sum,
                                   clt_sum_parts, horvitz_thompson_sum,
                                   ht_finish, ht_sum_parts,
                                   inclusion_probability,
                                   second_moment_stats, t_quantile)

given, settings, st = hypothesis_or_stubs()

# two-sided 97.5% t quantiles (scipy.stats.t.ppf(0.975, df))
_T975 = {5: 2.5706, 10: 2.2281, 30: 2.0423, 100: 1.9840, 1000: 1.9623}


def test_t_quantile_known_values():
    for df, want in _T975.items():
        got = float(t_quantile(0.975, df))
        assert abs(got - want) < 2e-2, (df, got, want)
    assert abs(float(t_quantile(0.975, 1e6)) - 1.95996) < 1e-3


def test_t_quantile_monotone_in_confidence():
    df = 20.0
    qs = [float(t_quantile(p, df)) for p in (0.9, 0.95, 0.975, 0.995)]
    assert qs == sorted(qs)


def _stats_from_population(rng, pops, sample_frac):
    """Draw with replacement from synthetic strata; return stats + truth."""
    valid, B, b, sf, sf2 = [], [], [], [], []
    truth = 0.0
    for i, n in enumerate(pops):
        vals = rng.normal(5.0 + i, 1.0 + 0.2 * i, size=n)
        truth += vals.sum()
        k = max(int(n * sample_frac), 2)
        pick = rng.choice(vals, size=k, replace=True)
        valid.append(True)
        B.append(float(n))
        b.append(float(k))
        sf.append(float(pick.sum()))
        sf2.append(float((pick ** 2).sum()))
    stats = StratumStats(jnp.asarray(valid), jnp.asarray(B, jnp.float32),
                         jnp.asarray(b, jnp.float32),
                         jnp.asarray(sf, jnp.float32),
                         jnp.asarray(sf2, jnp.float32))
    return stats, truth


def test_clt_coverage():
    """95% CI covers the truth at roughly the nominal rate."""
    rng = np.random.default_rng(7)
    pops = [50, 200, 1000, 3000]
    hits = 0
    trials = 120
    for _ in range(trials):
        stats, truth = _stats_from_population(rng, pops, 0.1)
        est = clt_sum(stats, 0.95)
        hits += bool(est.lo <= truth <= est.hi)
    assert hits / trials >= 0.85, hits / trials


def test_clt_count_exact():
    stats = StratumStats(jnp.asarray([True, True, False]),
                         jnp.asarray([10.0, 20.0, 99.0]),
                         jnp.asarray([2.0, 2.0, 0.0]),
                         jnp.zeros(3), jnp.zeros(3))
    assert float(clt_count(stats)) == 30.0


def test_parts_merge_equals_direct():
    """psum-style merge of per-shard parts == single-shot estimate."""
    rng = np.random.default_rng(3)
    s1, t1 = _stats_from_population(rng, [100, 500], 0.2)
    s2, t2 = _stats_from_population(rng, [300, 50], 0.2)
    merged = clt_sum_parts(s1)
    p2 = clt_sum_parts(s2)
    merged = type(merged)(*[a + b for a, b in zip(merged, p2)])
    est_merged = clt_finish(merged)
    whole = StratumStats(*[jnp.concatenate([a, b])
                           for a, b in zip(s1, s2)])
    est_whole = clt_sum(whole)
    np.testing.assert_allclose(float(est_merged.estimate),
                               float(est_whole.estimate), rtol=1e-6)
    np.testing.assert_allclose(float(est_merged.error_bound),
                               float(est_whole.error_bound), rtol=1e-5)


def test_inclusion_probability_limits():
    # tiny sample of a huge stratum: pi ~ b/B
    pi = float(inclusion_probability(jnp.asarray(1e6), jnp.asarray(10.0)))
    assert abs(pi - 1e-5) / 1e-5 < 0.01
    # sampling B-with-replacement draws: pi -> 1 - 1/e
    pi = float(inclusion_probability(jnp.asarray(100.0), jnp.asarray(100.0)))
    assert abs(pi - (1 - np.exp(-1))) < 0.01


def test_horvitz_thompson_unbiased():
    """HT over deduplicated draws averages to the truth."""
    rng = np.random.default_rng(11)
    B = 200
    vals = rng.normal(3.0, 1.0, size=B)
    truth = vals.sum()
    ests = []
    for _ in range(200):
        k = 60
        idx = rng.integers(0, B, size=k)
        uniq = np.unique(idx)
        stats = StratumStats(jnp.asarray([True]),
                             jnp.asarray([float(B)]),
                             jnp.asarray([float(k)]),
                             jnp.asarray([0.0]), jnp.asarray([0.0]))
        est = horvitz_thompson_sum(stats,
                                   jnp.asarray([float(vals[uniq].sum())]),
                                   jnp.asarray([float(len(uniq))]))
        ests.append(float(est.estimate))
    assert abs(np.mean(ests) - truth) / abs(truth) < 0.03


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(1, 1e4), min_size=1, max_size=8),
       st.floats(0.01, 1.0))
def test_clt_variance_nonnegative(pops, frac):
    rng = np.random.default_rng(0)
    stats, _ = _stats_from_population(rng, [max(int(p), 3) for p in pops],
                                      max(frac, 0.05))
    est = clt_sum(stats)
    assert float(est.variance) >= 0.0
    assert float(est.error_bound) >= 0.0


def _moment_stats(B, b, mu, sd):
    """Stats with EXACT per-stratum sample moments (mean mu, variance sd^2);
    isolates the estimator's analytic shape from sampling noise."""
    B = np.asarray(B, np.float32)
    b = np.asarray(b, np.float32)
    mu = np.asarray(mu, np.float32)
    sd = np.asarray(sd, np.float32)
    return StratumStats(jnp.asarray(B > 0), jnp.asarray(B), jnp.asarray(b),
                        jnp.asarray(b * mu),
                        jnp.asarray(b * (sd**2 + mu**2)))


@settings(max_examples=30, deadline=None)
@given(st.integers(50, 100_000), st.floats(-50, 50), st.floats(0.1, 20),
       st.integers(2, 30), st.integers(1, 40))
def test_ci_width_shrinks_monotonically_with_sample_size(B, mu, sd, b1, step):
    """More draws at the same sample moments never widen the interval:
    the FPC factor (B-b)/(b-1) and the t quantile both fall with b."""
    b2 = min(b1 + step, B)
    b1 = min(b1, B)
    w1 = float(clt_sum(_moment_stats([B], [b1], [mu], [sd])).error_bound)
    w2 = float(clt_sum(_moment_stats([B], [b2], [mu], [sd])).error_bound)
    assert np.isfinite(w1) and np.isfinite(w2)
    assert w2 <= w1 * (1 + 1e-6), (b1, b2, w1, w2)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_estimates_invariant_to_stratum_permutation(n_strata, perm_seed):
    """Slot order is an implementation detail (canonical key-sorted [S] vs
    the psum path's concatenated per-device layout): every estimator must
    give the same answer, up to float reassociation of the sums."""
    rng = np.random.default_rng(0)
    pops = list(rng.integers(10, 500, size=n_strata))
    stats, _ = _stats_from_population(rng, pops, 0.2)
    uf = jnp.asarray(rng.normal(5.0, 1.0, n_strata).astype(np.float32))
    uc = jnp.asarray(np.maximum(rng.integers(1, 10, n_strata), 1)
                     .astype(np.float32))
    perm = np.random.default_rng(perm_seed).permutation(n_strata)
    p_stats = StratumStats(*[jnp.asarray(np.asarray(x)[perm])
                             for x in stats])
    for fn, args, pargs in (
            (clt_sum, (stats,), (p_stats,)),
            (clt_avg, (stats,), (p_stats,)),
            (clt_stdev, (stats,), (p_stats,)),
            (horvitz_thompson_sum, (stats, uf, uc),
             (p_stats, uf[perm], uc[perm]))):
        a, b = fn(*args), fn(*pargs)
        np.testing.assert_allclose(float(a.estimate), float(b.estimate),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(a.error_bound),
                                   float(b.error_bound), rtol=1e-4,
                                   atol=1e-5)
        assert float(a.dof) == float(b.dof)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 10), st.integers(0, 2**31 - 1))
def test_zero_sample_strata_give_finite_bounds(n_strata, seed):
    """Strata that drew nothing (and empty strata) must yield finite — not
    NaN/inf — estimates and bounds from every estimator."""
    rng = np.random.default_rng(seed)
    B = rng.integers(0, 200, n_strata).astype(np.float32)
    b = np.where(rng.random(n_strata) < 0.5, 0.0,
                 rng.integers(0, 5, n_strata)).astype(np.float32)
    b = np.minimum(b, B)
    mu = rng.normal(3.0, 2.0, n_strata).astype(np.float32)
    sd = np.abs(rng.normal(0.0, 2.0, n_strata)).astype(np.float32)
    stats = _moment_stats(B, b, mu, sd)
    uf = jnp.asarray(np.where(b > 0, mu, 0.0).astype(np.float32))
    uc = jnp.asarray(np.minimum(b, 3.0).astype(np.float32))
    for est in (clt_sum(stats), clt_avg(stats), clt_stdev(stats),
                horvitz_thompson_sum(stats, uf, uc)):
        for v in (est.estimate, est.error_bound, est.variance, est.dof):
            assert np.isfinite(float(v)), (est, B, b)


def test_ht_parts_merge_equals_direct():
    """psum-style merge of per-shard HT parts == single-shot HT estimate
    (the psum serve path's dedup estimator)."""
    rng = np.random.default_rng(5)
    s1, _ = _stats_from_population(rng, [100, 400], 0.3)
    s2, _ = _stats_from_population(rng, [250, 60], 0.3)
    ufs = [jnp.asarray(rng.normal(4, 1, 2).astype(np.float32))
           for _ in range(2)]
    ucs = [jnp.asarray(rng.integers(1, 8, 2).astype(np.float32))
           for _ in range(2)]
    p1 = ht_sum_parts(s1, ufs[0], ucs[0])
    p2 = ht_sum_parts(s2, ufs[1], ucs[1])
    merged = ht_finish(HTParts(*[a + b for a, b in zip(p1, p2)]))
    whole = horvitz_thompson_sum(
        StratumStats(*[jnp.concatenate([a, b]) for a, b in zip(s1, s2)]),
        jnp.concatenate(ufs), jnp.concatenate(ucs))
    np.testing.assert_allclose(float(merged.estimate), float(whole.estimate),
                               rtol=1e-6)
    np.testing.assert_allclose(float(merged.error_bound),
                               float(whole.error_bound), rtol=1e-5)


def test_avg_stdev_parts_merge_equals_direct():
    """AVG and STDEV finish from psum'd parts == whole-array estimates."""
    rng = np.random.default_rng(9)
    s1, _ = _stats_from_population(rng, [150, 700], 0.2)
    s2, _ = _stats_from_population(rng, [80, 900], 0.2)
    whole = StratumStats(*[jnp.concatenate([a, b])
                           for a, b in zip(s1, s2)])
    parts = clt_sum_parts(s1)
    parts = type(parts)(*[a + b for a, b in zip(parts, clt_sum_parts(s2))])
    a_merged, a_whole = clt_avg_from(parts), clt_avg(whole)
    np.testing.assert_allclose(float(a_merged.estimate),
                               float(a_whole.estimate), rtol=1e-6)
    tau2 = (clt_sum_parts(second_moment_stats(s1)).tau
            + clt_sum_parts(second_moment_stats(s2)).tau)
    s_merged, s_whole = clt_stdev_from(parts, tau2), clt_stdev(whole)
    np.testing.assert_allclose(float(s_merged.estimate),
                               float(s_whole.estimate), rtol=1e-5)
    np.testing.assert_allclose(float(s_merged.error_bound),
                               float(s_whole.error_bound), rtol=1e-4)
