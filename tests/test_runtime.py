"""Runtime: training loop, checkpoint atomicity + bit-exact resume,
fault-tolerance paths, gradient compression.  (The join serving engine
has its own test modules: test_join_serve*, test_stream_join,
test_async_serve.)"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import lm_batch
from repro.models import ARCHS, Model
from repro.optim.compress import compress_int8, decompress_int8
from repro.runtime.checkpoint import (CheckpointCorruptError, latest_step,
                                      restore_checkpoint, save_checkpoint)
from repro.runtime.fault import (Heartbeat, StragglerMonitor, elastic_restore,
                                 guarded_step)
from repro.runtime.train import make_train_step, train_state_init


def _setup(arch="qwen2-0.5b", steps=10):
    cfg = ARCHS[arch].reduced(vocab=128)
    model = Model(cfg)
    step = jax.jit(make_train_step(model, total_steps=steps, warmup=2))
    state = train_state_init(model, jax.random.key(0))
    batches = [lm_batch(i, 0, batch=4, seq=32, vocab=cfg.vocab,
                        structured=True) for i in range(steps)]
    return model, step, state, batches


def test_training_loss_decreases():
    model, step, state, batches = _setup(steps=30)
    batches = [lm_batch(i, 0, batch=8, seq=64, vocab=128, structured=True)
               for i in range(30)]
    losses = []
    for b in batches:
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses[:3] + losses[-3:]


def test_checkpoint_bit_exact_resume(tmp_path):
    """Train 6 straight vs 3 + save/restore + 3: identical parameters."""
    _, step, state, batches = _setup(steps=6)
    s_straight = state
    for b in batches:
        s_straight, _ = step(s_straight, b)

    s_resume = _setup(steps=6)[2]
    for b in batches[:3]:
        s_resume, _ = step(s_resume, b)
    save_checkpoint(str(tmp_path), 3, s_resume)
    assert latest_step(str(tmp_path)) == 3
    restored, _ = restore_checkpoint(str(tmp_path), 3, s_resume)
    for b in batches[3:]:
        restored, _ = step(restored, b)

    for a, c in zip(jax.tree.leaves(s_straight.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_checkpoint_checksum_guard(tmp_path):
    _, _, state, _ = _setup()
    save_checkpoint(str(tmp_path), 1, state)
    # corrupt one leaf on disk
    d = tmp_path / "step_00000001"
    target = next(f for f in os.listdir(d) if f.endswith(".npy")
                  and "embed" in f)
    a = np.load(d / target)
    a = a + 1.0
    np.save(d / target, a)
    with pytest.raises(CheckpointCorruptError, match="checksum"):
        restore_checkpoint(str(tmp_path), 1, state)


def test_checkpoint_async_and_atomic(tmp_path):
    _, _, state, _ = _setup()
    th = save_checkpoint(str(tmp_path), 2, state, sync=False)
    th.join(60)
    assert latest_step(str(tmp_path)) == 2
    # no stray tmp dirs survive
    assert not [d for d in os.listdir(tmp_path) if ".tmp-" in d]


def test_elastic_restore_cold_and_warm(tmp_path):
    _, _, state, _ = _setup()
    s, step0, _ = elastic_restore(str(tmp_path), state)
    assert step0 == 0
    save_checkpoint(str(tmp_path), 7, state, extra={"note": "x"})
    s, step7, extra = elastic_restore(str(tmp_path), state)
    assert step7 == 7 and extra["note"] == "x"


def test_guarded_step_retries():
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("injected device loss")
        return state, {"ok": True}

    _, metrics = guarded_step(flaky, None, None, retries=3)
    assert metrics["ok"] and calls["n"] == 3
    with pytest.raises(RuntimeError, match="failed after"):
        guarded_step(lambda s, b: 1 / 0, None, None, retries=1)


def test_straggler_and_heartbeat():
    mon = StragglerMonitor(threshold=2.0)
    for host, t in [("a", 1.0), ("b", 1.1), ("c", 1.0), ("d", 5.0)]:
        for _ in range(5):
            mon.record(host, t)
    assert mon.stragglers() == ["d"]
    hb = Heartbeat(timeout_s=10.0)
    hb.beat("a", now=0.0)
    hb.beat("b", now=95.0)
    assert hb.dead_hosts(now=100.0) == ["a"]


def test_data_regeneration_deterministic():
    a = lm_batch(5, 2, batch=4, seq=16, vocab=97)
    b = lm_batch(5, 2, batch=4, seq=16, vocab=97)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = lm_batch(6, 2, batch=4, seq=16, vocab=97)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_int8_compression_roundtrip_and_ef():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1e-3, (128, 64)).astype(np.float32))
    codes, scale = compress_int8(g)
    back = decompress_int8(codes, scale)
    assert float(jnp.abs(back - g).max()) <= float(scale) * 0.51
    # error feedback drives the accumulated residual's effect to zero:
    # sum of (approx_t) over steps ~ sum of g_t
    err = jnp.zeros_like(g)
    acc_true = jnp.zeros_like(g)
    acc_q = jnp.zeros_like(g)
    for _ in range(50):
        x = g + err
        codes, scale = compress_int8(x)
        approx = decompress_int8(codes, scale)
        err = x - approx
        acc_true += g
        acc_q += approx
    rel = float(jnp.abs(acc_q - acc_true).max() / jnp.abs(acc_true).max())
    assert rel < 0.05, rel
