"""Data substrates + ApproxJoin-driven batch mixture + baseline quality
ordering (Fig. 1 property)."""

import numpy as np

from repro.core import (QueryBudget, accuracy_loss, approx_join, native_join,
                        postjoin_sampling, prejoin_sampling)
from repro.core.relation import relation
from repro.data import flows, netflix, pipeline, synthetic, tpch


def test_overlap_fraction_control():
    for target in (0.01, 0.05, 0.2):
        rels = synthetic.overlapping_relations([4096, 4096], target, seed=1)
        res = approx_join(rels, QueryBudget(), max_strata=2048)
        got = float(res.diagnostics.overlap_fraction)
        assert abs(got - target) < max(0.3 * target, 0.01), (target, got)


def test_fig1_accuracy_ordering():
    """Pre-join sampling is far less accurate than sampling during the join
    at equal fraction (the paper's motivating figure)."""
    rng = np.random.default_rng(1)
    n = 1 << 13
    r1 = relation(rng.integers(0, 500, n).astype(np.uint32),
                  rng.normal(10, 2, n).astype(np.float32))
    r2 = relation(rng.integers(400, 900, n).astype(np.uint32),
                  rng.normal(5, 1, n).astype(np.float32))
    exact = float(native_join([r1, r2]).estimate)
    frac = 0.05
    pre = prejoin_sampling([r1, r2], frac, seed=3)
    dur = approx_join([r1, r2],
                      QueryBudget(error=1.0, pilot_fraction=frac),
                      max_strata=1024, b_max=2048, seed=3)
    err_pre = abs(float(accuracy_loss(pre.estimate, exact)))
    err_dur = abs(float(accuracy_loss(dur.estimate, exact)))
    assert err_dur < err_pre / 5, (err_pre, err_dur)
    post = postjoin_sampling([r1, r2], frac, seed=3, max_strata=1024)
    err_post = abs(float(accuracy_loss(post.estimate, exact)))
    # during-join ~ post-join accuracy (same stratified estimator)
    assert err_dur < 5 * max(err_post, 1e-4)


def test_tpch_generator_invariants():
    t = tpch.generate(scale=0.005, seed=2)
    assert len(t.customer_key) == len(set(t.customer_key.tolist()))
    assert set(t.orders_custkey.tolist()) <= set(t.customer_key.tolist())
    assert set(t.lineitem_orderkey.tolist()) <= set(t.orders_key.tolist())
    # the paper's CUSTOMER |><| ORDERS query runs end to end
    rels = tpch.q_customer_orders(t)
    res = approx_join(rels, QueryBudget(), max_strata=1 << 13)
    assert float(res.count) == len(t.orders_custkey)  # FK join: 1 cust/order


def test_flows_ratios_and_query():
    rels = flows.flow_tables(scale=2048, shared_fraction=0.05, seed=0)
    sizes = [int(r.count()) for r in rels]
    assert sizes[0] > sizes[1] > sizes[2]
    assert abs(sizes[0] / sizes[2] - 115_472_322 / 2_801_002) < 2.0
    res = approx_join(rels[::-1], QueryBudget(), max_strata=4096)
    assert float(res.count) > 0


def test_netflix_skew():
    qual, train = netflix.ratings_tables(1 << 14, 1 << 11, seed=1)
    ratings = np.asarray(train.values)
    assert set(np.unique(ratings)) <= {1.0, 2.0, 3.0, 4.0, 5.0}


def test_mixture_plan_and_counts():
    rng = np.random.default_rng(0)
    docs = relation(rng.integers(0, 32, 2048).astype(np.uint32),
                    rng.random(2048).astype(np.float32))
    doms = relation(np.arange(32, dtype=np.uint32),
                    np.ones(32, np.float32))
    plan = pipeline.plan_batch_mixture(docs, doms, QueryBudget(error=0.1))
    assert abs(plan.weights.sum() - 1.0) < 1e-5
    counts = pipeline.mixture_shard_counts(plan, batch=64)
    assert counts.sum() == 64 and (counts >= 0).all()


def test_structured_stream_is_learnable():
    """The affine chain: next token is deterministic on ~7/8 of positions."""
    b = pipeline.lm_batch(0, 0, batch=4, seq=256, vocab=97, structured=True)
    t = np.asarray(b["tokens"])
    nxt = np.asarray(b["targets"])
    pred = (t * 3 + 7) % 97
    frac = float((pred == nxt).mean())
    assert frac > 0.8, frac
