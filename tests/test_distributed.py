"""Distributed join pipeline on 8 placeholder host devices.

Runs in a SUBPROCESS with --xla_force_host_platform_device_count=8 so the
rest of the suite keeps the real single-device backend (per the assignment's
instruction not to set XLA_FLAGS globally)."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core.relation import relation
from repro.core.distributed import distributed_approx_join
from repro.core.join import approx_join
from repro.core.budget import QueryBudget

mesh = jax.make_mesh((8,), ('data',))
rng = np.random.default_rng(0)
N = 1 << 14
r1 = relation(rng.integers(0, 1000, N).astype(np.uint32),
              rng.normal(10, 2, N).astype(np.float32))
r2 = relation(rng.integers(800, 1800, N).astype(np.uint32),
              rng.normal(5, 1, N).astype(np.float32))

single = approx_join([r1, r2], QueryBudget())
dist = distributed_approx_join(mesh, [r1, r2], mode='exact')
assert abs(float(dist.estimate) - float(single.estimate)) \
    / abs(float(single.estimate)) < 1e-5, 'exact mismatch'
assert float(dist.count) == float(single.count), 'count mismatch'
assert int(dist.bucket_overflow) == 0
assert int(dist.strata_overflow) == 0

# sampling: valid CI around the exact answer
samp = distributed_approx_join(mesh, [r1, r2], mode='sample',
                               sample_fraction=0.1, b_max=512)
rel = abs(float(samp.estimate) - float(single.estimate)) \
    / abs(float(single.estimate))
assert rel < 0.02, f'sampled rel err {rel}'
assert abs(float(samp.estimate) - float(single.estimate)) \
    <= 4 * float(samp.error_bound)

# filtering shrinks the measured wire bytes vs repartition (no filter)
rep = distributed_approx_join(mesh, [r1, r2], mode='exact',
                              filter_stage=False)
assert abs(float(rep.estimate) - float(single.estimate)) \
    / abs(float(single.estimate)) < 1e-5, 'repartition exact mismatch'
assert float(dist.shuffled_tuple_bytes) < 0.35 * float(
    rep.shuffled_tuple_bytes), (float(dist.shuffled_tuple_bytes),
                                float(rep.shuffled_tuple_bytes))

# 3-way multiway join
from repro.data.synthetic import overlapping_relations
rels = overlapping_relations([1 << 13] * 3, 0.05, seed=2)
s3 = approx_join(rels, QueryBudget(), max_strata=4096)
d3 = distributed_approx_join(mesh, rels, mode='exact', max_strata=4096)
assert abs(float(d3.estimate) - float(s3.estimate)) \
    / max(abs(float(s3.estimate)), 1) < 1e-5, '3-way mismatch'

# multi-axis mesh: join over ('pod','data') with a model axis present
mesh2 = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
d2 = distributed_approx_join(mesh2, [r1, r2], mode='exact',
                             join_axes=('pod', 'data'))
assert abs(float(d2.estimate) - float(single.estimate)) \
    / abs(float(single.estimate)) < 1e-5, 'multi-pod mismatch'
print('DISTRIBUTED-OK')
"""


@pytest.mark.slow
def test_distributed_join_8dev():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DISTRIBUTED-OK" in out.stdout


_EP_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np, jax
from repro.models import ARCHS, Model
from repro.sharding.specs import logical_rules

# shard_map EP MoE == GSPMD MoE (bit-identical logits)
mesh_m = jax.make_mesh((2, 4), ('data', 'model'))
cfg = ARCHS['qwen2-moe-a2.7b'].reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=8.0))
toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
outs = {}
for impl in ('gspmd', 'ep'):
    mdl = Model(dataclasses.replace(cfg, moe_impl=impl))
    prm = mdl.init(jax.random.key(0))
    with logical_rules(mesh_m):
        lg, _ = jax.jit(mdl.forward)(prm, {'tokens': toks})
    outs[impl] = np.asarray(lg, np.float32)
dmax = np.abs(outs['gspmd'] - outs['ep']).max()
assert dmax / np.abs(outs['gspmd']).max() < 2e-2, f'EP parity: {dmax}'
print('EP-MOE-OK')
"""


@pytest.mark.slow
@pytest.mark.xfail(strict=False,
                   reason="pre-existing EP-MoE vs GSPMD-MoE divergence "
                          "(dmax/|logits| ~ 1.24): an LM-stack dispatch or "
                          "routing-drift issue, not a join issue — see "
                          "ROADMAP.md 'Known failures'")
def test_ep_moe_parity_8dev():
    """EP-vs-GSPMD MoE parity, split out of test_distributed_join_8dev so
    the (passing) join assertions gate CI while this known LM-stack failure
    stays visible without failing the suite."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _EP_MOE], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "EP-MOE-OK" in out.stdout


_ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.data.pipeline import lm_batch
from repro.models import ARCHS, Model
from repro.runtime.checkpoint import restore_checkpoint, save_checkpoint
from repro.runtime.train import make_train_step, train_state_init
from repro.sharding.axes import param_axes
from repro.sharding.specs import logical_rules, param_specs
from repro.optim.adamw import AdamWState
from repro.runtime.train import TrainState
import tempfile

cfg = ARCHS['qwen2-0.5b'].reduced(vocab=128, d_model=64, d_ff=128)
model = Model(cfg)
step = make_train_step(model, total_steps=6, warmup=2)
batches = [lm_batch(i, 0, batch=8, seq=32, vocab=cfg.vocab, structured=True)
           for i in range(6)]

def shardings_for(mesh, state):
    p_axes = param_axes(state.params, cfg)
    st_axes = TrainState(p_axes, AdamWState((), p_axes, p_axes), None)
    return param_specs(st_axes, state, mesh)

# phase 1: train 3 steps on a (4, 2) mesh, checkpoint
mesh_a = jax.make_mesh((4, 2), ('data', 'model'))
state = train_state_init(model, jax.random.key(0))
with logical_rules(mesh_a):
    jstep = jax.jit(step)
    for b in batches[:3]:
        state, _ = jstep(state, b)
tmp = tempfile.mkdtemp()
save_checkpoint(tmp, 3, state)

# phase 2: "node failure" -> NEW mesh topology (2, 2, 2), elastic restore
mesh_b = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
fresh = train_state_init(model, jax.random.key(0))
restored, _ = restore_checkpoint(tmp, 3, fresh,
                                 shardings=shardings_for(mesh_b, fresh))
with logical_rules(mesh_b):
    jstep_b = jax.jit(step)
    for b in batches[3:]:
        restored, metrics = jstep_b(restored, b)

# reference: straight-through on mesh A
straight = train_state_init(model, jax.random.key(0))
with logical_rules(mesh_a):
    for b in batches:
        straight, _ = jstep(straight, b)

for a, c in zip(jax.tree.leaves(straight.params),
                jax.tree.leaves(restored.params)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(c, np.float32),
                               rtol=5e-3, atol=5e-4)
assert bool(jnp.isfinite(metrics['loss']))
print('ELASTIC-OK')
"""


@pytest.mark.slow
def test_elastic_restore_across_mesh_topologies():
    """Checkpoint on a (4,2) mesh, restore onto (2,2,2) after a simulated
    membership change, continue training: parameters match the straight
    run to collective-reordering tolerance."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _ELASTIC], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC-OK" in out.stdout


_COMPRESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.data.pipeline import lm_batch
from repro.models import ARCHS, Model
from repro.runtime.train import make_train_step, train_state_init

mesh = jax.make_mesh((8,), ('data',))
cfg = ARCHS['qwen2-0.5b'].reduced(vocab=128, d_model=64, d_ff=128)
model = Model(cfg)

# compressed-DP: the whole step runs inside shard_map over 'data'; grads
# psum through the int8 error-feedback path instead of XLA's all-reduce
step = make_train_step(model, total_steps=20, warmup=2,
                       compress_axes=('data',))
state = train_state_init(model, jax.random.key(0), compress=True)

def sharded_step(state, batch):
    fn = shard_map(step, mesh=mesh,
                   in_specs=(P(), {'tokens': P('data'),
                                   'targets': P('data')}),
                   out_specs=(P(), P()),
                   check_rep=False)
    return fn(state, batch)

jstep = jax.jit(sharded_step)
losses = []
for i in range(20):
    b = lm_batch(i, 0, batch=16, seq=32, vocab=cfg.vocab, structured=True)
    state, m = jstep(state, b)
    losses.append(float(m['loss']))
assert np.isfinite(losses).all(), losses
assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses
# error-feedback buffers are live (non-zero residuals)
res = sum(float(jnp.abs(e).sum()) for e in jax.tree.leaves(state.ef_error))
assert res > 0
print('COMPRESS-OK', losses[0], '->', losses[-1])
"""


@pytest.mark.slow
def test_int8_ef_compressed_dp_training():
    """Training with int8 error-feedback gradient compression over an
    8-way DP axis: loss decreases, EF residuals are live."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _COMPRESS], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "COMPRESS-OK" in out.stdout
