"""Shared fixtures.  NB: no XLA_FLAGS here — unit/smoke tests must see the
real single-device CPU backend; multi-device distributed tests run in
subprocesses that set --xla_force_host_platform_device_count themselves."""

import numpy as np
import pytest

from repro.core.relation import relation


def hypothesis_or_stubs():
    """(given, settings, strategies) — real, or skip-stubs when hypothesis
    is absent.  The tier-1 suite must degrade to *skips*, not collection
    errors, when the dev extra isn't installed; deterministic tests in the
    same module keep running."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies
        return given, settings, strategies
    except ModuleNotFoundError:
        def settings(**kw):
            return lambda fn: fn

        def given(*a, **k):
            def deco(fn):
                @pytest.mark.skip(reason="hypothesis not installed")
                def stub():
                    pytest.importorskip("hypothesis")
                stub.__name__ = fn.__name__
                return stub
            return deco

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        return given, settings, _Strategies()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_pair(rng, n=1 << 13, keys1=(0, 500), keys2=(400, 900),
              mu1=10.0, mu2=5.0):
    """Two overlapping relations (keys 400..499 shared)."""
    r1 = relation(rng.integers(*keys1, n).astype(np.uint32),
                  rng.normal(mu1, 2, n).astype(np.float32))
    r2 = relation(rng.integers(*keys2, n).astype(np.uint32),
                  rng.normal(mu2, 1, n).astype(np.float32))
    return r1, r2


def numpy_join_sum(r1, r2, expr="sum"):
    """Brute-force oracle: SUM over the join output of v1+v2 (or v1*v2)."""
    import collections

    from repro.core.relation import to_numpy

    k1, v1 = to_numpy(r1)
    k2, v2 = to_numpy(r2)
    d2 = collections.defaultdict(list)
    for k, v in zip(k2, v2):
        d2[int(k)].append(v)
    total, count = 0.0, 0
    d1 = collections.defaultdict(list)
    for k, v in zip(k1, v1):
        d1[int(k)].append(v)
    for k in set(d1) & set(d2):
        a = np.array(d1[k], np.float64)
        b = np.array(d2[k], np.float64)
        count += len(a) * len(b)
        if expr == "sum":
            total += len(b) * a.sum() + len(a) * b.sum()
        else:
            total += a.sum() * b.sum()
    return total, count
