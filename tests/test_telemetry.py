"""Unified telemetry layer (ISSUE 9): the metrics registry as the single
store behind every diagnostics snapshot, bounded span tracing with Chrome
trace-event export, per-path byte reconciliation (modeled vs metered), and
telemetry crash safety (snapshot/restore round trip + the failover drill).

This file is owned by the CI "async serving" leg (8 host devices) and
excluded everywhere else — keep it runnable on 1 device: multi-device
cases must skip, not fail.
"""

import json

import jax
import numpy as np
import pytest

from repro.core.budget import QueryBudget
from repro.core.plan import Plan, PlanNode
from repro.core.relation import relation
from repro.core.window import WindowSpec
from repro.launch.trace_dump import summarize
from repro.runtime.async_serve import AsyncJoinFrontDoor
from repro.runtime.fault import InjectedFault
from repro.runtime.join_serve import (JoinRequest, JoinServer,
                                      ServerDiagnostics)
from repro.runtime.stream_join import StreamJoinServer
from repro.runtime.telemetry import (NULL_SPAN, MetricsRegistry, Tracer,
                                     chrome_trace, dump_chrome_trace,
                                     latency_pcts, span_tree,
                                     validate_chrome_trace)

MS, BM = 512, 256   # max_strata / b_max used throughout
ERR = QueryBudget(error=0.5)


def _mb(seed, n=256):
    r = np.random.default_rng(seed)
    return [relation(r.integers(0, 200, n).astype(np.uint32),
                     r.normal(10, 2, n).astype(np.float32)),
            relation(r.integers(150, 350, n).astype(np.uint32),
                     r.normal(5, 1, n).astype(np.float32))]


def _req(seed, qid="t0/q", **kw):
    kw.setdefault("rels", _mb(seed))
    kw.setdefault("budget", ERR)
    return JoinRequest(query_id=qid, seed=seed, max_strata=MS, b_max=BM,
                       **kw)


def _mesh(k):
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:k]), ("data",))


def _identical(a, b):
    return (float(a.estimate) == float(b.estimate)
            and float(a.error_bound) == float(b.error_bound)
            and float(a.count) == float(b.count)
            and float(a.dof) == float(b.dof))


# -- metrics registry --------------------------------------------------------

def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    c.inc()
    c.inc(2)
    assert reg.counter("hits") is c and c.value == 3
    assert "hits" in reg and "nope" not in reg
    with pytest.raises(TypeError):
        reg.gauge("hits")          # same name, different kind
    h = reg.histogram("lat", cap=3)
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.samples == [2.0, 3.0, 4.0]      # ring bounded at cap
    assert h.count == 4 and h.total == 10.0  # cumulative survive the ring


def test_registry_to_dict_and_prometheus():
    reg = MetricsRegistry()
    reg.counter("serve_queries").inc(5)
    reg.gauge("load").set(0.5)
    reg.gauge("per_device.bytes").set(np.array([1.0, 2.0]))
    h = reg.histogram("lat")
    h.observe(1.0)
    d = reg.to_dict()
    assert d["serve_queries"] == 5 and d["load"] == 0.5
    assert d["per_device.bytes"] == [1.0, 2.0]
    assert d["lat"]["count"] == 1
    json.dumps(d)                            # JSON-able view

    text = reg.prometheus(prefix="repro")
    assert "# TYPE repro_serve_queries counter" in text
    assert "repro_serve_queries 5.0" in text
    # vector gauge -> one sample per device; dots sanitized
    assert 'repro_per_device_bytes{device="0"} 1.0' in text
    assert 'repro_per_device_bytes{device="1"} 2.0' in text
    assert 'repro_lat{quantile="0.5"} 1.0' in text
    assert "repro_lat_count 1" in text and "repro_lat_sum 1.0" in text
    # never-set scalar gauges are omitted, not exported as garbage
    reg.gauge("unset")
    assert "unset" not in reg.prometheus()


def test_latency_pcts_schema():
    z = latency_pcts([], "queue_latency")
    assert z == {"queue_latency_p50_s": 0.0, "queue_latency_p95_s": 0.0,
                 "queue_latency_max_s": 0.0}
    p = latency_pcts([1.0, 2.0, 3.0], "x")
    assert p["x_p50_s"] == 2.0 and p["x_max_s"] == 3.0


# -- tracer ------------------------------------------------------------------

def test_tracer_disabled_noop_and_ring_bounded():
    off = Tracer(enabled=False)
    assert off.span("s") is NULL_SPAN
    with off.span("s") as s:
        s.set(k=1)                            # no-op, no error
    off.instant("i")
    off.event("e", 0.0, 1.0)
    off.note_recon({"path": "x", "pairs": []})
    assert not off.events and not off.recon and off._seq == 0

    on = Tracer(enabled=True, capacity=8)
    for i in range(20):
        on.instant(f"i{i}")
    assert len(on.events) == 8                # ring bounded
    assert on._seq == 20                      # ids keep advancing
    assert [e["name"] for e in on.events][0] == "i12"


def test_tracer_state_adopt_max_merge():
    a, b = Tracer(enabled=True), Tracer(enabled=True)
    for _ in range(5):
        a.next_id()
    b.next_id()
    st = a.state()
    json.dumps(st)                            # rides snapshot meta
    b.adopt(st)
    assert b._seq == 5
    a.adopt(b.state())                        # max-merge: never regresses
    assert a._seq == 5
    assert b.next_id() == 6                   # successor ids stay unique


def test_span_tree_containment_and_zero_dur_leaves():
    tr = Tracer(enabled=True)
    tr.event("outer", 0.0, 10.0, tid="L")
    tr.event("inner", 1.0, 4.0, tid="L")
    tr.event("leaf", 2.0, 0.0, tid="L")       # zero-dur marker inside inner
    tr.event("mark", 2.0, 0.0, tid="L")       # same ts: must NOT nest in leaf
    tr.event("sibling", 6.0, 2.0, tid="L")
    tr.event("other-lane", 0.0, 1.0, tid="M")
    tr.instant("note", tid="L")               # instants are not tree nodes
    forest = span_tree(tr.events)
    roots = {n["name"] for n in forest}
    assert roots == {"outer", "other-lane"}
    outer = next(n for n in forest if n["name"] == "outer")
    assert [c["name"] for c in outer["children"]] == ["inner", "sibling"]
    inner = outer["children"][0]
    assert [c["name"] for c in inner["children"]] == ["leaf", "mark"]
    assert all(not c["children"] for c in inner["children"])


def test_chrome_trace_export_and_validation():
    tr = Tracer(enabled=True, tags={"replica": "r0"})
    tr.event("work", 1.0, 0.5, cat="serve", tid="engine", k=1)
    tr.instant("done", tid="engine")
    obj = chrome_trace(tr, reconciliation={"paths": {}, "server": [],
                                           "queries": []})
    n = validate_chrome_trace(obj)
    assert n == len(obj["traceEvents"])
    phs = {e["ph"] for e in obj["traceEvents"]}
    assert {"X", "i", "M"} <= phs             # spans, instants, metadata
    x = next(e for e in obj["traceEvents"] if e["ph"] == "X")
    assert x["ts"] == pytest.approx(1.0e6)    # microseconds
    assert x["args"]["replica"] == "r0"
    assert obj["displayTimeUnit"] == "ms"
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "Z"}]})
    with pytest.raises(ValueError):
        validate_chrome_trace([])


# -- diagnostics on the registry (satellites a, b, c) ------------------------

def test_server_snapshot_readonly_idempotent():
    srv = JoinServer(batch_slots=2)
    for s in range(3):
        srv.submit(_req(s, qid=f"t{s % 2}/q"))
    srv.run()
    snap1 = srv.diagnostics.snapshot()
    snap2 = srv.diagnostics.snapshot()
    assert snap1 == snap2                     # idempotent, mutates nothing
    assert snap1["queries"] == 3
    assert len(srv.diagnostics.queue_latencies) == 3  # rings untouched
    json.dumps(snap1)                         # JSON-able
    # the legacy attribute surface still reads through
    assert srv.diagnostics.queries == 3
    assert len(srv.diagnostics.tenant_latencies) == 2
    # the registry is the single backing store: prometheus sees it all
    text = srv.diagnostics.prometheus()
    assert "repro_serve_queries 3.0" in text
    assert "repro_serve_queue_latencies_count 3" in text
    # reset clears rings, keeps cumulative counters
    srv.diagnostics.reset_latencies()
    assert srv.diagnostics.queue_latencies == []
    assert srv.diagnostics.snapshot()["queries"] == 3


def test_tenant_rings_lru_bounded():
    d = ServerDiagnostics(tenant_cap=4)
    for i in range(4):
        d.note_latency(f"t{i}", 0.1, 0.2, cap=16)
    d.note_latency("t0", 0.1, 0.2, cap=16)    # touch t0: now most recent
    d.note_latency("t4", 0.1, 0.2, cap=16)    # evicts t1 (LRU), not t0
    per = d.tenant_latencies
    assert set(per) == {"t0", "t2", "t3", "t4"}
    assert d.tenant_evictions == 1
    for i in range(5, 10):
        d.note_latency(f"t{i}", 0.1, 0.2, cap=16)
    assert len(d.tenant_latencies) == 4
    assert d.tenant_evictions == 6
    assert len(d.snapshot()["per_tenant"]) == 4


def test_stream_diagnostics_schema_alignment():
    srv = StreamJoinServer(batch_slots=2)
    sd = srv.stream_diagnostics
    # one registry behind both diagnostics objects
    assert sd.registry is srv.diagnostics.registry
    snap = sd.snapshot()
    for k in ("window_latency_p50_s", "window_latency_p95_s",
              "window_latency_max_s"):
        assert snap[k] == 0.0                 # same pct schema as batch
    sess = srv.open_stream("t", WindowSpec(size=2, slide=1, sub_rows=256),
                           budget=ERR, max_strata=MS, b_max=BM, seed=3)
    for t in range(3):
        sess.push(_mb(100 + t))
        srv.run()
    done = sess.drain()
    assert done
    snap = sd.snapshot()
    assert snap == sd.snapshot()              # idempotent
    assert snap["windows_served"] == len(done)
    assert snap["window_latency_p95_s"] >= snap["window_latency_p50_s"] > 0
    assert "repro_stream_windows_served" in sd.registry.prometheus()


# -- end-to-end span trees + reconciliation per serving path -----------------

def _roots(srv, qid):
    forest = srv.query_trace(qid)
    return [n for n in forest if n["name"] == "query"]


def _span_names(node, acc=None):
    acc = set() if acc is None else acc
    acc.add(node["name"])
    for c in node["children"]:
        _span_names(c, acc)
    return acc


def test_single_device_span_tree_and_recon():
    tr = Tracer(enabled=True)
    srv = JoinServer(batch_slots=2, tracer=tr)
    srv.submit(_req(0, qid="t0/q"))           # error budget -> sampled
    srv.submit(_req(1, qid="t1/q", budget=QueryBudget()))   # exact
    srv.run()
    for qid, stage in (("t0/q", "sample"), ("t1/q", "exact")):
        roots = _roots(srv, qid)
        assert len(roots) == 1
        names = _span_names(roots[0])
        assert {"query", "queued", "execute", "prepare", stage} <= names
        kids = {c["name"] for c in roots[0]["children"]}
        assert {"queued", "execute"} <= kids
    # ingest + complete instants bracket every query
    for name in ("ingest", "complete"):
        assert any(e["name"] == name for e in tr.events)
    validate_chrome_trace(chrome_trace(tr))

    rep = srv.reconciliation_report()
    agg = rep["paths"]["single"]
    assert agg["filter_exchange_bytes"]["modeled"] > 0
    assert agg["live_tuple_bytes"]["measured"] is None   # no wire meter
    assert {p["name"] for p in rep["server"]} == {
        "filter_exchange_bytes", "dist_wire_bytes_model",
        "kernel_gather_bytes"}
    # always-on model counter advanced even though amortized meter is n/a
    assert srv.diagnostics.filter_exchange_bytes_model > 0


def test_tracing_off_serves_bit_identical_and_silent():
    on = JoinServer(batch_slots=2, tracer=Tracer(enabled=True))
    off = JoinServer(batch_slots=2)
    a = on.submit(_req(5, qid="t/q"))
    b = off.submit(_req(5, qid="t/q"))
    on.run()
    off.run()
    assert _identical(a.result, b.result)
    assert not off.tracer.events and not off.tracer.recon
    assert off.query_trace("t/q") == []
    assert off.reconciliation_report()["paths"] == {}


def test_kernel_path_span_tree():
    tr = Tracer(enabled=True)
    srv = JoinServer(batch_slots=2, tracer=tr)
    r = srv.submit(_req(2, qid="tk/q", use_kernels=True))
    srv.run()
    assert r.done and r.result is not None
    root = _roots(srv, "tk/q")[0]
    assert root["args"]["path"] == "kernel"
    assert {"queued", "execute"} <= _span_names(root)
    rep = srv.reconciliation_report()
    assert "kernel" in rep["paths"]
    validate_chrome_trace(chrome_trace(tr))


@pytest.mark.parametrize("mode", ["exact-parity", "psum"])
def test_mesh_span_tree_and_recon_meters(mode):
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    k = 2
    tr = Tracer(enabled=True)
    srv = JoinServer(batch_slots=2, mesh=_mesh(k), serve_mode=mode,
                     tracer=tr)
    srv.register_dataset("ds", _mb(7))
    for s in range(2):
        srv.submit(_req(s, qid="tm/q", rels=None, dataset="ds"))
    srv.run()
    assert tr.tags.get("mesh") == "2"         # mesh-tagged events
    root = _roots(srv, "tm/q")[0]
    assert root["args"]["path"] == f"mesh{k}/{mode}"
    assert "shuffle" in _span_names(root)     # metered marker present
    rep = srv.reconciliation_report()
    agg = rep["paths"][f"mesh{k}/{mode}"]
    # on a mesh the tuple-byte model has a real meter: error is reported
    assert agg["live_tuple_bytes"]["measured"] is not None
    assert agg["live_tuple_bytes"]["rel_error"] is not None
    assert agg["dist_wire_bytes_model"]["measured"] is not None
    # per-device breakdown rides each query record
    recs = [r for r in rep["queries"] if r["path"] == f"mesh{k}/{mode}"]
    assert recs and all(len(r["per_device"]["measured"]) == k for r in recs)
    # the amortized filter-exchange meter counted actual mesh word builds
    fe = next(p for p in rep["server"]
              if p["name"] == "filter_exchange_bytes")
    assert fe["measured"] is not None and fe["measured"] > 0
    validate_chrome_trace(chrome_trace(tr))


def test_stream_window_spans():
    tr = Tracer(enabled=True)
    srv = StreamJoinServer(batch_slots=2, tracer=tr)
    sess = srv.open_stream("t", WindowSpec(size=2, slide=1, sub_rows=256),
                           budget=ERR, max_strata=MS, b_max=BM, seed=3)
    for t in range(3):
        sess.push(_mb(200 + t))
        srv.run()
    done = sess.drain()
    assert done
    served = {r.window_id for r in done}
    winq = [e for e in tr.events if e["name"] == "query"
            and e["args"].get("window") is not None]
    assert {e["args"]["window"] for e in winq} == served
    assert all(e["args"]["stream"] == "t" for e in winq)
    validate_chrome_trace(chrome_trace(tr))


def test_plan_node_spans_and_node_model_recon():
    tr = Tracer(enabled=True)
    srv = JoinServer(batch_slots=4, tracer=tr)
    r = np.random.default_rng(9)
    for name in "abc":
        keys = r.integers(0, 150, 256).astype(np.uint32)
        vals = r.normal(8, 2, 256).astype(np.float32)
        srv.register_dataset(name, [relation(keys, vals)])
    plan = Plan((PlanNode("ab", ("a", "b"), budget=ERR),
                 PlanNode("abc", ("ab", "c"), budget=ERR)))
    handle = srv.submit_plan(plan, query_id="p0", seed=7)
    srv.run()
    assert handle.done
    pe = next(e for e in tr.events if e["name"] == "plan")
    assert pe["args"]["hierarchy"] == {"ab": [], "abc": ["ab"]}
    for node in ("ab", "abc"):
        root = _roots(srv, f"p0/{node}")[0]
        assert root["args"]["plan"] == "p0"
        assert root["args"]["plan_node"] == node
    rep = srv.reconciliation_report()
    nm = rep["paths"]["single"]["node_bytes_model"]
    assert nm["queries"] == 2
    # the compile-time model re-stated at serve time: metered, small error
    assert nm["rel_error"] is not None
    validate_chrome_trace(chrome_trace(tr))


# -- crash safety (satellite d) ---------------------------------------------

def test_telemetry_survives_snapshot_restore():
    tr = Tracer(enabled=True)
    srv = StreamJoinServer(batch_slots=2, tracer=tr)
    sess = srv.open_stream("t", WindowSpec(size=2, slide=1, sub_rows=256),
                           budget=ERR, max_strata=MS, b_max=BM, seed=3)
    for t in range(3):
        sess.push(_mb(300 + t))
        srv.run()
    flat, meta = srv.snapshot_state()
    assert meta["telemetry"] == {"seq": tr._seq}
    assert json.dumps(meta["stream_diag"])    # scalar form, JSON-able

    tr2 = Tracer(enabled=True)
    dst = StreamJoinServer(batch_slots=2, tracer=tr2)
    dst.restore_state(flat, meta)
    # successor span ids can never collide with the dead server's
    assert tr2._seq >= tr._seq
    assert tr2.next_id() > tr._seq
    # counters merged additively into the shared registry
    assert dst.stream_diagnostics.windows_served == \
        srv.stream_diagnostics.windows_served
    assert dst.diagnostics.queries == srv.diagnostics.queries


def test_failover_drill_keeps_ids_and_counters_consistent(tmp_path):
    """A replica killed mid-workload: the shared fleet tracer records the
    fault and the failover, every event id stays unique across the dead
    replica and its successor, and the successor's counters keep the
    tenant's history (adopted via the checkpoint's additive merge)."""
    tr = Tracer(enabled=True)
    with AsyncJoinFrontDoor(replicas=2, checkpoint_dir=str(tmp_path),
                            tracer=tr) as fd:
        for i in range(6):
            fd.submit(_req(i, qid=f"t{i % 2}/q{i}")).result(timeout=120)
        victim = fd._assign["t0"]
        victim.kill_after(0)
        victim._thread.join(60)
        assert isinstance(victim.error, InjectedFault)
        import time
        deadline = time.monotonic() + 60
        served = None
        while served is None and time.monotonic() < deadline:
            try:
                served = fd.submit(_req(99, qid="t0/q99")).result(timeout=60)
            except BaseException:             # the injected fault
                time.sleep(0.05)
        assert served is not None and served.result is not None
        snap = fd.snapshot()
    assert snap["failovers"] == 1
    names = [e["name"] for e in tr.events]
    assert "fault" in names and "failover" in names
    fo = next(e for e in tr.events if e["name"] == "failover")
    assert fo["args"]["dead"] == victim.name
    ids = [e["id"] for e in tr.events]
    assert len(ids) == len(set(ids))          # fleet-wide unique span ids
    # replica lanes stayed separate in the export
    lanes = {e["tid"] for e in tr.events if e["name"] == "step"}
    assert len(lanes) == 2
    validate_chrome_trace(chrome_trace(tr))


# -- trace_dump CLI surface --------------------------------------------------

def test_dump_and_summarize(tmp_path):
    tr = Tracer(enabled=True)
    srv = JoinServer(batch_slots=2, tracer=tr)
    srv.submit(_req(0, qid="t0/q"))
    srv.run()
    path = str(tmp_path / "trace.json")
    n = dump_chrome_trace(tr, path,
                          reconciliation=srv.reconciliation_report())
    with open(path) as fh:
        obj = json.load(fh)
    assert validate_chrome_trace(obj) == n
    text = summarize(obj)
    assert "events" in text and "by category:" in text
    assert "byte reconciliation" in text
    assert "filter_exchange_bytes" in text
