"""Property-based tests for the distributed shuffle/filter primitives.

The mesh collectives (all_gather / all_to_all / psum) are emulated with
``jax.vmap(fn, axis_name=...)`` over a leading shard dim — the standard
single-device harness for SPMD code, so hypothesis can sweep shard counts
and data shapes without spawning multi-device subprocesses.

Properties (paper Alg. 1 + the cogroup shuffle):
* ``shuffle_by_key`` never lands a key on the wrong shard, and with
  non-lossy capacity moves every valid row exactly once;
* OR-reduced per-shard partition filters equal the single-device Bloom
  build bit-for-bit (scatter-OR is a set union);
* ``bucketize`` reports capacity overflow exactly — rows are dropped only
  when a bucket is full, and every drop is counted.
"""

import jax
import jax.numpy as jnp
import numpy as np

from conftest import hypothesis_or_stubs
from repro.core import bloom
from repro.core.distributed import bucketize, or_reduce, shuffle_by_key
from repro.core.hashing import hash2
from repro.core.relation import Relation

given, settings, st = hypothesis_or_stubs()

N_PER_SHARD = 64


def _sharded_relation(data_seed: int, k: int, key_range: int, live: float):
    rng = np.random.default_rng(data_seed)
    keys = rng.integers(0, key_range, (k, N_PER_SHARD)).astype(np.uint32)
    vals = rng.normal(0, 1, (k, N_PER_SHARD)).astype(np.float32)
    valid = rng.random((k, N_PER_SHARD)) < live
    return Relation(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))


@given(data_seed=st.integers(0, 2**31 - 1), k=st.sampled_from([1, 2, 4, 8]),
       seed=st.integers(0, 1000), key_range=st.sampled_from([3, 50, 5000]))
@settings(max_examples=25, deadline=None)
def test_shuffle_routes_every_key_to_its_hash_shard(data_seed, k, seed,
                                                    key_range):
    rel = _sharded_relation(data_seed, k, key_range, live=0.8)
    cap = N_PER_SHARD  # a source shard holds N rows total: lossless
    out, _sent, ovf = jax.vmap(
        lambda r: shuffle_by_key(r, k, cap, ("data",), seed),
        axis_name="data")(rel)
    assert int(jnp.sum(ovf)) == 0
    keys = np.asarray(out.keys)          # [k, k*cap]
    valid = np.asarray(out.valid)
    for shard in range(k):
        got = keys[shard][valid[shard]]
        dests = np.asarray(hash2(jnp.asarray(got), seed)) % k
        assert (dests == shard).all(), (shard, got[dests != shard][:5])
    # every valid row arrives exactly once: counts and value-sums match
    assert valid.sum() == int(np.asarray(rel.valid).sum())
    want = sorted(np.asarray(rel.keys)[np.asarray(rel.valid)].tolist())
    assert sorted(keys[valid].tolist()) == want


@given(data_seed=st.integers(0, 2**31 - 1), k=st.sampled_from([1, 2, 4, 8]),
       seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_or_reduce_equals_single_device_bloom_build(data_seed, k, seed):
    rel = _sharded_relation(data_seed, k, key_range=5000, live=0.7)
    nb = bloom.num_blocks_for(k * N_PER_SHARD, 0.01)
    local = jax.vmap(lambda r: bloom.build(r.keys, r.valid, nb, seed).words
                     )(rel)
    merged = jax.vmap(lambda w: or_reduce(w, ("data",)),
                      axis_name="data")(local)
    single = bloom.build(rel.keys.reshape(-1), rel.valid.reshape(-1), nb,
                         seed).words
    for shard in range(k):   # replicated AND bit-identical to one build
        np.testing.assert_array_equal(np.asarray(merged[shard]),
                                      np.asarray(single))


@given(data_seed=st.integers(0, 2**31 - 1), k=st.sampled_from([1, 2, 4, 8]),
       cap=st.sampled_from([1, 3, 8, 64]), seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_bucketize_counts_capacity_overflow_exactly(data_seed, k, cap, seed):
    rng = np.random.default_rng(data_seed)
    rel = Relation(
        jnp.asarray(rng.integers(0, 40, N_PER_SHARD).astype(np.uint32)),
        jnp.asarray(rng.normal(0, 1, N_PER_SHARD).astype(np.float32)),
        jnp.asarray(rng.random(N_PER_SHARD) < 0.8))
    dest = (hash2(rel.keys, seed) % jnp.uint32(k)).astype(jnp.int32)
    keys, _vals, valid, overflow = bucketize(rel, dest, k, cap)
    dest_np = np.asarray(dest)[np.asarray(rel.valid)]
    per_bucket = np.bincount(dest_np, minlength=k)
    # overflow == exactly the rows beyond cap, per destination bucket
    assert int(overflow) == int(np.maximum(per_bucket - cap, 0).sum())
    kept = np.asarray(valid)             # [k, cap]
    assert kept.sum(axis=1).tolist() == np.minimum(per_bucket, cap).tolist()
    # kept rows really belong to their bucket (no mis-routing on drop)
    bkeys = np.asarray(keys)
    for b in range(k):
        got = bkeys[b][kept[b]]
        assert (np.asarray(hash2(jnp.asarray(got), seed)) % k == b).all()
    # nothing is silently dropped: kept + overflow == valid input rows
    assert kept.sum() + int(overflow) == int(np.asarray(rel.valid).sum())


def test_shuffle_overflow_is_counted_not_silent():
    """Deterministic companion (runs even without hypothesis): a skewed
    relation that must overflow a tiny bucket reports every dropped row."""
    k, cap = 4, 2
    keys = np.full((k, N_PER_SHARD), 7, np.uint32)   # all rows -> one shard
    rel = Relation(jnp.asarray(keys),
                   jnp.zeros((k, N_PER_SHARD), jnp.float32),
                   jnp.ones((k, N_PER_SHARD), bool))
    out, _sent, ovf = jax.vmap(
        lambda r: shuffle_by_key(r, k, cap, ("data",), 3),
        axis_name="data")(rel)
    received = int(np.asarray(out.valid).sum())
    dropped = int(np.asarray(ovf).sum())
    assert received + dropped == k * N_PER_SHARD
    assert dropped == k * (N_PER_SHARD - cap)


def test_or_reduce_deterministic_two_shards():
    """Deterministic companion: two-shard OR-merge == one build."""
    rel = _sharded_relation(3, 2, key_range=500, live=1.0)
    nb = bloom.num_blocks_for(2 * N_PER_SHARD, 0.01)
    local = jax.vmap(lambda r: bloom.build(r.keys, r.valid, nb, 5).words)(rel)
    merged = jax.vmap(lambda w: or_reduce(w, ("data",)),
                      axis_name="data")(local)
    single = bloom.build(rel.keys.reshape(-1), rel.valid.reshape(-1), nb,
                         5).words
    np.testing.assert_array_equal(np.asarray(merged[0]), np.asarray(single))
    np.testing.assert_array_equal(np.asarray(merged[1]), np.asarray(single))
