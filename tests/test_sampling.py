"""Stratified-sampling machinery: group-by strata, segment location, edge
draws, exact sufficient-statistics oracles (hypothesis property tests)."""

import jax.numpy as jnp
import numpy as np

from conftest import hypothesis_or_stubs
from repro.core.hashing import hash2
from repro.core.relation import relation, sort_by_key
from repro.core.sampling import (build_strata, exact_count,
                                 exact_sum_of_products, exact_sum_of_sums,
                                 reservoir_empty, reservoir_extend,
                                 reservoir_fill, reservoir_merge,
                                 reservoir_moments, sample_edges)

given, settings, st = hypothesis_or_stubs()

KEYS = st.lists(st.integers(0, 30), min_size=1, max_size=120)


def _sorted_rel(keys, rng):
    vals = rng.normal(2.0, 1.0, len(keys)).astype(np.float32)
    return sort_by_key(relation(np.array(keys, np.uint32), vals))


@settings(max_examples=30, deadline=None)
@given(KEYS, KEYS)
def test_strata_counts_match_numpy(k1, k2):
    rng = np.random.default_rng(0)
    r1, r2 = _sorted_rel(k1, rng), _sorted_rel(k2, rng)
    strata = build_strata([r1, r2], max_strata=64)
    got = {}
    keys = np.asarray(strata.keys)
    for i in range(64):
        if bool(strata.valid[i]):
            got[int(keys[i])] = (int(strata.counts[0, i]),
                                 int(strata.counts[1, i]))
    import collections
    c1 = collections.Counter(k1)
    c2 = collections.Counter(k2)
    want = {}
    # strata come from the lead relation after fmix-free sort: raw keys
    for k in c1:
        want[k] = (c1[k], c2.get(k, 0))
    assert got == want


@settings(max_examples=30, deadline=None)
@given(KEYS, KEYS)
def test_exact_sufficient_stats_vs_bruteforce(k1, k2):
    rng = np.random.default_rng(1)
    r1, r2 = _sorted_rel(k1, rng), _sorted_rel(k2, rng)
    strata = build_strata([r1, r2], max_strata=64)
    v1 = {"k": np.asarray(r1.keys), "v": np.asarray(r1.values)}
    v2 = {"k": np.asarray(r2.keys), "v": np.asarray(r2.values)}
    want_sum = want_prod = 0.0
    want_cnt = 0
    for i in range(len(v1["k"])):
        for j in range(len(v2["k"])):
            if v1["k"][i] == v2["k"][j]:
                want_cnt += 1
                want_sum += float(v1["v"][i]) + float(v2["v"][j])
                want_prod += float(v1["v"][i]) * float(v2["v"][j])
    np.testing.assert_allclose(float(exact_count(strata)), want_cnt,
                               rtol=1e-6)
    np.testing.assert_allclose(float(exact_sum_of_sums([r1, r2], strata)),
                               want_sum, rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(
        float(exact_sum_of_products([r1, r2], strata)), want_prod,
        rtol=2e-4, atol=1e-3)


def test_draws_respect_segments_and_budget():
    rng = np.random.default_rng(2)
    r1 = _sorted_rel(list(rng.integers(0, 20, 500)), rng)
    r2 = _sorted_rel(list(rng.integers(10, 30, 500)), rng)
    strata = build_strata([r1, r2], max_strata=64)
    b_i = jnp.minimum(strata.population, 7.0)
    res = sample_edges([r1, r2], strata, b_i, b_max=16, seed=3)
    n = np.asarray(res.stats.n_sampled)
    joinable = np.asarray(strata.joinable)
    want = np.where(joinable, np.minimum(np.asarray(b_i), 16), 0)
    np.testing.assert_array_equal(n, want)
    # all sampled f-values come from real value combinations: bounded
    vmax = float(np.abs(np.asarray(r1.values)).max()
                 + np.abs(np.asarray(r2.values)).max())
    assert float(np.abs(np.asarray(res.f_values)).max()) <= vmax + 1e-5


def test_sampler_is_partition_invariant():
    """Draws are keyed by (seed, join key, counter), not row position.

    With values that are a function of the key (so within-segment order
    cannot matter), permuting the input rows leaves EVERY per-stratum
    statistic bit-identical — the property that makes the distributed
    sampler coordination-free (DESIGN.md §2)."""
    rng = np.random.default_rng(4)
    k1 = np.array(list(rng.integers(0, 12, 300)), np.uint32)
    k2 = list(rng.integers(6, 18, 300))
    v1 = (k1 * 0.5 + 1.0).astype(np.float32)    # value determined by key
    r1a = sort_by_key(relation(k1, v1))
    r2a = _sorted_rel(k2, np.random.default_rng(6))
    perm = rng.permutation(300)
    r1b = sort_by_key(relation(k1[perm], v1[perm]))
    strata_a = build_strata([r1a, r2a], 32)
    res_a = sample_edges([r1a, r2a], strata_a, jnp.minimum(
        strata_a.population, 5.0), 8, seed=9)
    strata_b = build_strata([r1b, r2a], 32)
    res_b = sample_edges([r1b, r2a], strata_b, jnp.minimum(
        strata_b.population, 5.0), 8, seed=9)
    ka = np.asarray(strata_a.keys)
    kb = np.asarray(strata_b.keys)
    for field in ("n_sampled", "sum_f", "sum_f2"):
        sa = {int(k): float(s) for k, s, v in zip(
            ka, np.asarray(getattr(res_a.stats, field)),
            np.asarray(res_a.stats.valid)) if v}
        sb = {int(k): float(s) for k, s, v in zip(
            kb, np.asarray(getattr(res_b.stats, field)),
            np.asarray(res_b.stats.valid)) if v}
        assert sa == sb, field


def test_strata_overflow_counted():
    rng = np.random.default_rng(5)
    r1 = _sorted_rel(list(range(100)), rng)     # 100 distinct keys
    r2 = _sorted_rel(list(range(100)), rng)
    strata = build_strata([r1, r2], max_strata=32)
    assert int(strata.overflow) == 100 - 32
    assert int(strata.num_strata) == 32


# ---------------------------------------------------------------------------
# Merge-able per-stratum reservoirs (the streaming sketch).
# ---------------------------------------------------------------------------

def _batch(seed, n=256, hi=1000):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, hi, n).astype(np.uint32),
            rng.normal(3.0, 2.0, n).astype(np.float32),
            rng.random(n) < 0.9)


def test_reservoir_under_capacity_keeps_exact_multiset():
    keys, vals, valid = _batch(0, n=100)
    res = reservoir_extend(reservoir_empty(8, 100), jnp.asarray(keys),
                           jnp.asarray(vals), jnp.asarray(valid), 5, 0)
    got = np.sort(np.asarray(res.values)[
        np.asarray(res.priority) != np.uint32(0xFFFFFFFF)])
    np.testing.assert_array_equal(got, np.sort(vals[valid]))
    # n_seen counts offered valid rows per hash stratum
    sid = np.asarray(hash2(jnp.asarray(keys), 5)) % 8
    want = np.bincount(sid[valid], minlength=8)
    np.testing.assert_array_equal(np.asarray(res.n_seen), want)
    np.testing.assert_array_equal(np.asarray(reservoir_fill(res)), want)


def test_reservoir_bounded_overflow():
    keys, vals, valid = _batch(1, n=2048)
    res = reservoir_empty(4, 16)
    for tick in range(3):
        res = reservoir_extend(res, jnp.asarray(keys), jnp.asarray(vals),
                               jnp.asarray(valid), 5, tick)
    fill = np.asarray(reservoir_fill(res))
    np.testing.assert_array_equal(fill, np.full(4, 16))       # saturated
    assert float(np.asarray(res.n_seen).sum()) == 3 * valid.sum()
    # kept values are a subset of the offered ones
    assert set(np.asarray(res.values).ravel().tolist()) <= \
        set(vals[valid].tolist())


def test_reservoir_merge_equals_sequential_extend():
    """Bottom-k by item-identity priorities: folding batches sequentially
    and merging independently-folded reservoirs agree BIT-FOR-BIT."""
    a, b = _batch(2), _batch(3)
    empty = reservoir_empty(8, 32)

    def fold(res, batch, tick):
        keys, vals, valid = batch
        return reservoir_extend(res, jnp.asarray(keys), jnp.asarray(vals),
                                jnp.asarray(valid), 5, tick)

    seq = fold(fold(empty, a, 0), b, 1)
    merged = reservoir_merge(fold(empty, a, 0), fold(empty, b, 1))
    for f in ("priority", "values", "n_seen"):
        np.testing.assert_array_equal(np.asarray(getattr(seq, f)),
                                      np.asarray(getattr(merged, f)), f)


def test_reservoir_moments_match_numpy():
    keys, vals, valid = _batch(4, n=200)
    res = reservoir_extend(reservoir_empty(4, 200), jnp.asarray(keys),
                           jnp.asarray(vals), jnp.asarray(valid), 7, 0)
    n, mean, var = reservoir_moments(res)
    sid = np.asarray(hash2(jnp.asarray(keys), 7)) % 4
    for s in range(4):
        v = vals[valid & (sid == s)].astype(np.float64)
        assert float(n[s]) == len(v)
        np.testing.assert_allclose(float(mean[s]), v.mean(), rtol=1e-5)
        np.testing.assert_allclose(float(var[s]), v.var(ddof=1), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 300), st.integers(1, 300))
def test_reservoir_merge_property(seed, n1, n2):
    rng = np.random.default_rng(seed)
    empty = reservoir_empty(4, 24)

    def fold(res, n, tick):
        keys = rng.integers(0, 50, n).astype(np.uint32)
        vals = rng.normal(0, 1, n).astype(np.float32)
        return keys, vals, reservoir_extend(
            res, jnp.asarray(keys), jnp.asarray(vals),
            jnp.ones(n, bool), 11, tick)

    k1, v1, ra = fold(empty, n1, 0)
    rng2 = np.random.default_rng(seed)        # replay the same draws
    _ = rng2.integers(0, 50, n1), rng2.normal(0, 1, n1)
    k2, v2, seq = fold(ra, n2, 1)
    rb = reservoir_extend(empty, jnp.asarray(k2), jnp.asarray(v2),
                          jnp.ones(n2, bool), 11, 1)
    merged = reservoir_merge(ra, rb)
    np.testing.assert_array_equal(np.asarray(seq.priority),
                                  np.asarray(merged.priority))
    np.testing.assert_array_equal(np.asarray(seq.values),
                                  np.asarray(merged.values))


def test_three_way_strata_and_exact():
    rng = np.random.default_rng(6)
    rels = [_sorted_rel(list(rng.integers(0, 10, 200)), rng)
            for _ in range(3)]
    strata = build_strata(rels, 16)
    got = float(exact_sum_of_sums(rels, strata))
    ks = [np.asarray(r.keys) for r in rels]
    vs = [np.asarray(r.values) for r in rels]
    want = 0.0
    for key in set(ks[0].tolist()):
        segs = [vs[i][ks[i] == key] for i in range(3)]
        if all(len(s) for s in segs):
            n = [len(s) for s in segs]
            want += (segs[0].sum() * n[1] * n[2]
                     + segs[1].sum() * n[0] * n[2]
                     + segs[2].sum() * n[0] * n[1])
    np.testing.assert_allclose(got, want, rtol=1e-4)
