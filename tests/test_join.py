"""End-to-end approx_join behaviour vs brute-force oracles: exact path,
sampled path with CI, HT dedup path, budget machinery, kernel parity,
sigma feedback."""

import numpy as np
import pytest

from conftest import make_pair, numpy_join_sum
from repro.core.budget import QueryBudget, parse_budget
from repro.core.cost import (CostModel, SigmaRegistry, predicted_latency,
                             sizes_for_error, sizes_for_latency)
from repro.core.join import approx_join


def test_exact_path_matches_numpy(rng):
    r1, r2 = make_pair(rng, n=1 << 12)
    want, want_cnt = numpy_join_sum(r1, r2)
    res = approx_join([r1, r2], QueryBudget(), max_strata=1024)
    assert res.diagnostics.sampled is False
    np.testing.assert_allclose(float(res.estimate), want, rtol=1e-4)
    assert float(res.count) == want_cnt


def test_exact_product_expr(rng):
    r1, r2 = make_pair(rng, n=1 << 11)
    want, _ = numpy_join_sum(r1, r2, expr="product")
    res = approx_join([r1, r2], QueryBudget(), expr="product",
                      max_strata=1024)
    np.testing.assert_allclose(float(res.estimate), want, rtol=1e-3)


def test_sampled_path_accuracy_and_ci(rng):
    r1, r2 = make_pair(rng)
    want, _ = numpy_join_sum(r1, r2)
    res = approx_join([r1, r2], QueryBudget(error=0.5, pilot_fraction=0.1),
                      max_strata=1024, b_max=1024, seed=5)
    assert res.diagnostics.sampled is True
    rel_err = abs(float(res.estimate) - want) / abs(want)
    assert rel_err < 0.02, rel_err
    assert abs(float(res.estimate) - want) <= 3 * float(res.error_bound)


def test_count_and_avg_aggregates(rng):
    r1, r2 = make_pair(rng, n=1 << 11)
    want, want_cnt = numpy_join_sum(r1, r2)
    cnt = approx_join([r1, r2], QueryBudget(error=0.5), agg="count",
                      max_strata=1024, b_max=256)
    assert float(cnt.estimate) == want_cnt  # count is exact given strata
    avg = approx_join([r1, r2], QueryBudget(error=0.5), agg="avg",
                      max_strata=1024, b_max=256)
    np.testing.assert_allclose(float(avg.estimate), want / want_cnt,
                               rtol=0.05)


def test_horvitz_thompson_dedup_path(rng):
    r1, r2 = make_pair(rng, n=1 << 12)
    want, _ = numpy_join_sum(r1, r2)
    res = approx_join([r1, r2], QueryBudget(error=0.5, pilot_fraction=0.2),
                      max_strata=1024, b_max=512, dedup=True, seed=2)
    rel_err = abs(float(res.estimate) - want) / abs(want)
    assert rel_err < 0.05, rel_err


def test_kernel_path_bit_identical(rng):
    r1, r2 = make_pair(rng, n=1 << 12)
    a = approx_join([r1, r2], QueryBudget(error=0.5), max_strata=512,
                    b_max=256, seed=3)
    b = approx_join([r1, r2], QueryBudget(error=0.5), max_strata=512,
                    b_max=256, seed=3, use_kernels=True)
    assert float(a.estimate) == float(b.estimate)
    assert float(a.error_bound) == float(b.error_bound)


def test_filter_reduces_shuffle_volume(rng):
    # ~4% key overlap — the low-overlap regime where the paper's filter
    # shines (Fig. 9); at bench scale the |BF| broadcast cost is included.
    r1, r2 = make_pair(rng, keys2=(480, 980))
    res = approx_join([r1, r2], QueryBudget(), max_strata=1024)
    d = res.diagnostics
    assert float(d.shuffled_bytes_filtered) < 0.5 * float(
        d.shuffled_bytes_repartition)
    # higher overlap -> less saving (monotone in the right direction)
    r1h, r2h = make_pair(rng, keys2=(400, 900))   # ~20% overlap
    dh = approx_join([r1h, r2h], QueryBudget(), max_strata=1024).diagnostics
    assert float(dh.shuffled_bytes_filtered) > float(
        d.shuffled_bytes_filtered)


def test_multiway_join_exact(rng):
    from repro.data.synthetic import overlapping_relations
    rels = overlapping_relations([2048, 2048, 2048], 0.1, seed=3)
    res = approx_join(rels, QueryBudget(), max_strata=2048)
    # brute force on the smallest data
    import collections
    maps = []
    for r in rels:
        m = collections.defaultdict(list)
        k, v = np.asarray(r.keys), np.asarray(r.values)
        for kk, vv in zip(k, v):
            m[int(kk)].append(float(vv))
        maps.append(m)
    want = 0.0
    for key in set(maps[0]) & set(maps[1]) & set(maps[2]):
        segs = [np.array(m[key]) for m in maps]
        n = [len(s) for s in segs]
        want += (segs[0].sum() * n[1] * n[2] + segs[1].sum() * n[0] * n[2]
                 + segs[2].sum() * n[0] * n[1])
    np.testing.assert_allclose(float(res.estimate), want, rtol=1e-3)


def test_budget_parsing():
    b = parse_budget("WITHIN 120 SECONDS")
    assert b.latency_s == 120.0 and b.error is None
    b = parse_budget("ERROR 0.01 CONFIDENCE 95%")
    assert b.error == 0.01 and b.confidence == 0.95
    b = parse_budget("WITHIN 5 SECONDS OR ERROR 0.1 CONFIDENCE 99%")
    assert b.latency_s == 5.0 and b.error == 0.1 and b.confidence == 0.99
    with pytest.raises(ValueError):
        parse_budget("GIMME RESULTS")


def test_cost_function_latency_inverse():
    """Eq. 5/6/7 are mutually consistent: predicted latency of the chosen
    b_i hits the budget."""
    cost = CostModel(beta_compute=1e-6, epsilon=0.01)
    pop = np.array([1e4, 1e5, 1e6], np.float32)
    d_desired, d_dt = 0.5, 0.05
    b = np.asarray(sizes_for_latency(cost, d_desired, d_dt, pop))
    pred = float(predicted_latency(cost, b, d_dt))
    assert pred <= d_desired * 1.05
    assert (b >= 1).all() and (b <= pop + 1).all()


def test_cost_function_error_formula():
    b = np.asarray(sizes_for_error(0.1, np.array([2.0]), np.array([1e9])))
    # b = (1.96 * 2 / 0.1)^2 ~ 1537
    assert abs(b[0] - (1.96 * 2 / 0.1) ** 2) / b[0] < 0.05


def test_sigma_feedback_improves_second_run(rng, tmp_path):
    """§3.2-II: with stored sigma the error budget is met with a targeted
    sample size rather than the pilot fraction."""
    r1, r2 = make_pair(rng)
    reg = SigmaRegistry()
    approx_join([r1, r2], QueryBudget(error=2.0, pilot_fraction=0.02),
                max_strata=1024, b_max=512, sigma_registry=reg,
                query_id="q1", seed=7)
    assert reg.has("q1")
    b2 = approx_join([r1, r2], QueryBudget(error=2.0),
                     max_strata=1024, b_max=512, sigma_registry=reg,
                     query_id="q1", seed=8)
    # second run tunes per-stratum sizes from sigma; bound should be tight
    assert float(b2.error_bound) > 0.0
    # registry round-trips through JSON (restart durability)
    reg.save(tmp_path / "sigma.json")
    reg2 = SigmaRegistry.load(tmp_path / "sigma.json")
    assert reg2.has("q1")


def test_latency_budget_exact_fastpath(rng):
    """§3.1.1: when the exact join fits the latency budget, no sampling."""
    r1, r2 = make_pair(rng, n=1 << 10)
    cost = CostModel(beta_compute=1e-12, epsilon=0.0)  # absurdly fast box
    res = approx_join([r1, r2], QueryBudget(latency_s=100.0),
                      cost_model=cost, max_strata=1024)
    assert res.diagnostics.sampled is False
    want, _ = numpy_join_sum(r1, r2)
    np.testing.assert_allclose(float(res.estimate), want, rtol=1e-4)


def test_stdev_aggregate(rng):
    """STDEV of v1+v2 over the join ~ sqrt(var1 + var2) for independent
    normals (values are independent of keys here)."""
    r1, r2 = make_pair(rng, n=1 << 13)  # v1~N(10,2), v2~N(5,1)
    res = approx_join([r1, r2], QueryBudget(error=0.1, pilot_fraction=0.2),
                      agg="stdev", max_strata=1024, b_max=1024, seed=4)
    want = np.sqrt(2.0**2 + 1.0**2)
    assert abs(float(res.estimate) - want) / want < 0.05, float(res.estimate)
    assert float(res.error_bound) > 0
