"""JoinServer serving path: bit-identity with direct approx_join, executable
cache behaviour, tenant isolation of the sigma feedback, shape classes."""

import numpy as np
import pytest

from conftest import make_pair
from repro.core.budget import QueryBudget
from repro.core.cost import SigmaRegistry
from repro.core.join import approx_join
from repro.core.relation import bucket_capacity, bucket_to_pow2, relation
from repro.runtime.join_serve import (JoinRequest, JoinServer,
                                      ServerDiagnostics, shape_class_of)

MS, BM = 1024, 512   # max_strata / b_max used throughout


def _identical(a, b):
    """Bitwise equality of the user-facing result surface."""
    return (float(a.estimate) == float(b.estimate)
            and float(a.error_bound) == float(b.error_bound)
            and float(a.count) == float(b.count)
            and float(a.dof) == float(b.dof))


def _req(rels, budget, qid, seed):
    return JoinRequest(rels=rels, budget=budget, query_id=qid, seed=seed,
                       max_strata=MS, b_max=BM)


def test_single_query_bit_identical_to_direct(rng):
    r1, r2 = make_pair(rng, n=1 << 12)      # pow2: bucketing is a no-op
    srv = JoinServer(batch_slots=4)
    q = srv.submit(_req([r1, r2], QueryBudget(error=0.5), "t0", seed=5))
    srv.run()
    direct = approx_join([r1, r2], QueryBudget(error=0.5), max_strata=MS,
                         b_max=BM, seed=5)
    assert q.done and _identical(q.result, direct)
    assert bool(q.result.diagnostics.sampled)
    # live/total counts and population survive the batched path bit-exactly
    np.testing.assert_array_equal(
        np.asarray(q.result.diagnostics.live_counts),
        np.asarray(direct.diagnostics.live_counts))
    np.testing.assert_array_equal(np.asarray(q.result.strata.keys),
                                  np.asarray(direct.strata.keys))


def test_batched_mixed_budgets_bit_identical(rng):
    """One engine step serves a mixed exact/sampled batch; every slot is
    bit-identical to its own direct approx_join call."""
    pairs = [make_pair(rng, n=1 << 12),
             make_pair(rng, n=1 << 12, keys2=(450, 950)),
             make_pair(rng, n=1 << 12, mu1=3.0)]
    budgets = [QueryBudget(error=0.5), QueryBudget(error=0.5), QueryBudget()]
    srv = JoinServer(batch_slots=4)
    qs = [srv.submit(_req(list(p), b, f"t{i}", seed=10 + i))
          for i, (p, b) in enumerate(zip(pairs, budgets))]
    assert srv.step() == 3                   # one batch, same shape class
    for i, (p, b) in enumerate(zip(pairs, budgets)):
        direct = approx_join(list(p), b, max_strata=MS, b_max=BM,
                             seed=10 + i)
        assert _identical(qs[i].result, direct), i
    assert not bool(qs[2].result.diagnostics.sampled)  # exact budget


def test_cache_hits_increase_on_repeat_shape_class(rng):
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = JoinServer(batch_slots=2)
    srv.submit(_req([r1, r2], QueryBudget(error=0.5), "a", seed=1))
    srv.run()
    first = srv.diagnostics.snapshot()
    # >=3 executables compiled (filter build, prepare, sample); the only
    # admissible hit so far is the second relation reusing the build exe
    assert first["compiles"] >= 3 and first["cache_hits"] <= 1
    srv.submit(_req([r1, r2], QueryBudget(error=0.5), "a", seed=2))
    srv.run()
    second = srv.diagnostics.snapshot()
    assert second["compiles"] == first["compiles"]     # zero recompiles
    assert second["cache_hits"] > first["cache_hits"]
    # a new shape class compiles fresh executables
    r3, r4 = make_pair(rng, n=1 << 12)
    srv.submit(_req([r3, r4], QueryBudget(error=0.5), "a", seed=3))
    srv.run()
    assert srv.diagnostics.compiles > second["compiles"]


def test_interleaved_tenants_do_not_cross_contaminate_sigma(rng):
    """Tenant A and B interleave in the queue; each query_id's sigma table
    matches the one a dedicated per-tenant driver would have produced."""
    ra = make_pair(rng, n=1 << 12)
    rb = make_pair(rng, n=1 << 12, keys2=(300, 800), mu1=20.0)
    srv = JoinServer(batch_slots=2)
    for q in range(2):
        srv.submit(_req(list(ra), QueryBudget(error=0.5), "tenantA", q))
        srv.submit(_req(list(rb), QueryBudget(error=0.5), "tenantB", q))
    srv.run()
    assert set(srv.sigma.table) == {"tenantA", "tenantB"}

    for qid, rels in (("tenantA", ra), ("tenantB", rb)):
        reg = SigmaRegistry()
        for q in range(2):
            approx_join(list(rels), QueryBudget(error=0.5), max_strata=MS,
                        b_max=BM, seed=q, sigma_registry=reg, query_id=qid)
        assert srv.sigma.table[qid] == reg.table[qid], qid


def test_two_shape_classes_concurrently(rng):
    """Queries from two capacity shape classes interleave; the engine groups
    them into per-class batches and each result stays bit-identical.

    Each query gets a unique query_id: same-id queries co-batched into one
    step legitimately diverge from a *sequential* direct driver, because
    sigma feedback lands between steps, not between slots of one step.
    """
    small = make_pair(rng, n=1 << 11)
    large = make_pair(rng, n=1 << 12)
    srv = JoinServer(batch_slots=4)
    qs = []
    for q in range(2):
        qs.append((small, srv.submit(
            _req(list(small), QueryBudget(error=0.5), f"s{q}", seed=q))))
        qs.append((large, srv.submit(
            _req(list(large), QueryBudget(error=0.5), f"l{q}", seed=q))))
    srv.run()
    classes = {shape_class_of(r) for _, r in qs}
    assert len(classes) == 2
    for rels, req in qs:
        direct = approx_join(list(rels), QueryBudget(error=0.5),
                             max_strata=MS, b_max=BM, seed=req.seed)
        assert _identical(req.result, direct)
    assert srv.diagnostics.steps <= 4        # batched, not one step/query


def test_nonpow2_input_bucketed_like_direct_padded_call(rng):
    """Non-pow2 capacities are padded to their bucket; the result equals a
    direct approx_join on the explicitly bucketed relations."""
    n = 3000                                  # buckets to 4096
    r1 = relation(rng.integers(0, 500, n).astype(np.uint32),
                  rng.normal(10, 2, n).astype(np.float32))
    r2 = relation(rng.integers(400, 900, n).astype(np.uint32),
                  rng.normal(5, 1, n).astype(np.float32))
    assert bucket_capacity(n) == 4096
    srv = JoinServer(batch_slots=2)
    q = srv.submit(_req([r1, r2], QueryBudget(error=0.5), "t", seed=3))
    srv.run()
    direct = approx_join([bucket_to_pow2(r1), bucket_to_pow2(r2)],
                         QueryBudget(error=0.5), max_strata=MS, b_max=BM,
                         seed=3)
    assert _identical(q.result, direct)


def test_dataset_handles_and_validation(rng):
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = JoinServer(batch_slots=2)
    srv.register_dataset("shared", [r1, r2])
    q = srv.submit(JoinRequest(dataset="shared", budget=QueryBudget(),
                               query_id="t", max_strata=MS, b_max=BM))
    srv.run()
    direct = approx_join([r1, r2], QueryBudget(), max_strata=MS, b_max=BM)
    assert _identical(q.result, direct)
    assert q.queue_latency_s > 0
    with pytest.raises(ValueError):
        srv.submit(JoinRequest(budget=QueryBudget()))        # no rels
    with pytest.raises(ValueError):
        srv.submit(JoinRequest(rels=[r1, r2], agg="median"))  # unknown agg


def test_dataset_filter_words_built_once(rng):
    """Registered-dataset Bloom filter reuse: N steps over a dataset build
    the filter words exactly once per (num_blocks, seed); re-registering
    identical relations under a new name reuses the cache; a new seed (the
    filter hash is seeded) builds fresh words."""
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = JoinServer(batch_slots=1)        # force one step per query
    srv.register_dataset("ds", [r1, r2])

    def submit(name, qid, seed):
        return srv.submit(JoinRequest(dataset=name,
                                      budget=QueryBudget(error=0.5),
                                      query_id=qid, seed=seed, max_strata=MS,
                                      b_max=BM))

    q = submit("ds", "t0", 7)
    for i in range(1, 3):
        submit("ds", f"t{i}", 7)
    srv.run()
    d = srv.diagnostics
    assert d.steps == 3
    assert d.filter_builds == 2            # one per relation, built once
    assert d.filter_cache_hits == 4        # 2 later steps x 2 relations
    # the cached-words path is still bit-identical to a direct driver call
    direct = approx_join([r1, r2], QueryBudget(error=0.5), max_strata=MS,
                         b_max=BM, seed=7)
    assert _identical(q.result, direct)

    # re-register the same relations under a new name: same fingerprints
    srv.register_dataset("ds-again", [r1, r2])
    submit("ds-again", "t3", 7)
    srv.run()
    assert srv.diagnostics.filter_builds == 2
    assert srv.diagnostics.filter_cache_hits == 6

    # a different seed hashes differently -> fresh words, once
    submit("ds", "t4", 8)
    srv.run()
    assert srv.diagnostics.filter_builds == 4


def test_sigma_pipeline_matches_sequential_driver(rng):
    """Cross-step sigma pipelining: same-query_id error-budget repeats
    submitted together are deferred one step each, so every repeat sees the
    previous execution's measured sigma — bit-identical to a sequential
    driver threading feedback through one registry."""
    r1, r2 = make_pair(rng, n=1 << 12)
    srv = JoinServer(batch_slots=4)
    qs = [srv.submit(_req([r1, r2], QueryBudget(error=0.5), "tenant", seed=s))
          for s in range(3)]
    srv.run()
    assert srv.diagnostics.steps == 3           # one repeat per step
    assert srv.diagnostics.sigma_deferrals == 3
    reg = SigmaRegistry()
    for s in range(3):
        direct = approx_join([r1, r2], QueryBudget(error=0.5), max_strata=MS,
                             b_max=BM, seed=s, sigma_registry=reg,
                             query_id="tenant")
        assert _identical(qs[s].result, direct), s


def test_sigma_pipeline_fills_slots_with_other_tenants(rng):
    """Deferred repeats must not cost throughput when the queue has id
    diversity: alternating tenants keep every batch full, so N rounds of two
    tenants take exactly N steps — same as without pipelining."""
    r1, r2 = make_pair(rng, n=1 << 12)
    srv = JoinServer(batch_slots=2)
    for q in range(3):
        srv.submit(_req([r1, r2], QueryBudget(error=0.5), "A", seed=q))
        srv.submit(_req([r1, r2], QueryBudget(error=0.5), "B", seed=q))
    srv.run()
    assert srv.diagnostics.steps == 3
    assert srv.diagnostics.max_batch == 2

    # opting out restores co-batching: all three same-id repeats in one step
    srv2 = JoinServer(batch_slots=4, sigma_pipeline=False)
    for q in range(3):
        srv2.submit(_req([r1, r2], QueryBudget(error=0.5), "A", seed=q))
    srv2.run()
    assert srv2.diagnostics.steps == 1
    assert srv2.diagnostics.sigma_deferrals == 0


def test_queue_latency_percentiles(rng):
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = JoinServer(batch_slots=2)
    qs = [srv.submit(_req([r1, r2], QueryBudget(error=0.5), f"t{q}", seed=q))
          for q in range(4)]
    srv.run()
    snap = srv.diagnostics.snapshot()
    assert "queue_latencies" not in snap        # raw ring stays internal
    assert 0 < snap["queue_latency_p50_s"] <= snap["queue_latency_p95_s"] \
        <= snap["queue_latency_max_s"]
    assert snap["queue_latency_max_s"] == \
        pytest.approx(max(q.queue_latency_s for q in qs))


def test_latency_percentiles_empty_and_single_sample():
    d = ServerDiagnostics()
    snap = d.snapshot()                        # empty rings -> hard zeros
    for k in ("queue_latency_p50_s", "queue_latency_p95_s",
              "queue_latency_max_s", "e2e_latency_p50_s",
              "e2e_latency_p95_s", "e2e_latency_max_s"):
        assert snap[k] == 0.0
    assert snap["per_tenant"] == {}
    d.note_latency("a", 0.25, 0.5, 8)          # one sample: p50 == p95 == max
    snap = d.snapshot()
    assert snap["queue_latency_p50_s"] == snap["queue_latency_p95_s"] \
        == snap["queue_latency_max_s"] == 0.25
    assert snap["e2e_latency_p95_s"] == 0.5
    assert snap["per_tenant"]["a"]["samples"] == 1
    assert snap["per_tenant"]["a"]["queue_latency_p95_s"] == 0.25


def test_latency_percentiles_ring_wrap_and_reset():
    """The sample rings are bounded: with cap=4, eight samples 0..7 leave
    exactly the last four, and the percentiles describe those — while the
    cumulative sums keep covering every query ever served."""
    d = ServerDiagnostics()
    for i in range(8):
        d.note_latency("t", float(i), float(i), 4)
    assert d.queue_latencies == [4.0, 5.0, 6.0, 7.0]
    assert d.tenant_latencies["t"][0] == [4.0, 5.0, 6.0, 7.0]
    snap = d.snapshot()
    assert snap["queue_latency_max_s"] == 7.0
    assert snap["queue_latency_p50_s"] == pytest.approx(5.5)
    assert snap["queue_latency_p95_s"] == pytest.approx(6.85)
    assert d.queue_latency_s == sum(range(8))  # cumulative: unwindowed
    d.reset_latencies()
    assert d.queue_latencies == [] and d.e2e_latencies == []
    assert d.tenant_latencies == {}
    assert d.queue_latency_s == sum(range(8))  # sums survive a ring reset
    assert d.snapshot()["queue_latency_p95_s"] == 0.0


def test_latency_ring_bounded_by_server_cap(rng):
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = JoinServer(batch_slots=2, latency_samples=2)
    for q in range(5):
        srv.submit(_req([r1, r2], QueryBudget(error=0.5), "t/a", seed=q))
        srv.run()
    d = srv.diagnostics
    assert d.queries == 5
    assert len(d.queue_latencies) == 2 and len(d.e2e_latencies) == 2
    assert len(d.tenant_latencies["t"][0]) == 2
    assert d.snapshot()["per_tenant"]["t"]["samples"] == 2


def test_kernel_batch_mixed_seeds_bit_identical_to_per_query(rng):
    """The acceptance contract: ONE engine step serves a mixed-seed kernel
    batch through the stacked Pallas grids, and every slot is bit-identical
    to its own per-query approx_join(use_kernels=True) call."""
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = JoinServer(batch_slots=4)
    seeds = [3, 11, 3, 250]
    qs = [srv.submit(JoinRequest(rels=[r1, r2], budget=QueryBudget(error=0.5),
                                 query_id=f"t{i}", seed=s, max_strata=512,
                                 b_max=256, use_kernels=True))
          for i, s in enumerate(seeds)]
    assert srv.step() == 4                    # one fused dispatch, no loop
    for i, s in enumerate(seeds):
        direct = approx_join([r1, r2], QueryBudget(error=0.5), max_strata=512,
                             b_max=256, seed=s, use_kernels=True)
        assert _identical(qs[i].result, direct), (i, s)
        assert bool(qs[i].result.diagnostics.sampled)
    assert srv.diagnostics.kernel_queries == 4
    assert srv.diagnostics.max_batch == 4
    # meshless: the batched kernel path never round-trips rows to the host
    assert srv.diagnostics.kernel_gather_bytes == 0.0


def test_kernel_seed_sweep_no_recompiles_no_rebuilds(rng):
    """The static-seed recompile bug, fixed at the engine: a 16-seed warm
    sweep over one kernel shape class (mixed batch fills too) must keep the
    compile AND filter-build counters flat — seeds are runtime operands and
    the dataset words cache ignores the sampling seed entirely."""
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = JoinServer(batch_slots=4)
    srv.register_dataset("ds", [r1, r2])

    def submit(q, seed):
        return srv.submit(JoinRequest(
            dataset="ds", budget=QueryBudget(error=0.5), query_id=f"t{q}",
            seed=seed, filter_seed=7, max_strata=512, b_max=256,
            use_kernels=True))

    # warmup: cover the batch fills the sweep uses (4-wide and 2-wide)
    for q in range(4):
        submit(q, seed=1000 + q)
    srv.run()
    for q in range(2):
        submit(q, seed=2000 + q)
    srv.run()
    warm = srv.diagnostics.snapshot()
    assert warm["filter_builds"] == 2          # one per relation, ever

    qs = []
    for seed in range(16):                     # 4 full batches + 2-fills
        qs.append(submit(seed % 4, seed))
        if seed % 4 == 3:
            srv.run()
    for seed in range(16, 20, 2):
        submit(0, seed), submit(1, seed + 1)
        srv.run()
    after = srv.diagnostics.snapshot()
    assert after["compiles"] == warm["compiles"], "seed sweep recompiled"
    assert after["filter_builds"] == warm["filter_builds"], \
        "seed sweep rebuilt filter words"
    assert all(q.done for q in qs)


def test_kernel_batch_width_capped_by_vmem_budget(rng, monkeypatch):
    """A kernel class whose per-slot VMEM working set only fits a few
    stacked slots must serve in narrower batches (width 1 == exactly the
    retired per-query path's capacity) instead of tripping the wrappers'
    B * filter_bytes asserts — and each narrowed batch stays bit-identical
    to per-query approx_join."""
    from repro.core import bloom
    from repro.kernels import bloom_probe
    r1, r2 = make_pair(rng, n=1 << 11)
    # shrink the budget so this class's stacked filters fit only 2 slots
    fb = bloom.num_blocks_for(1 << 11, 0.01) * bloom.WORDS_PER_BLOCK * 4
    monkeypatch.setattr(bloom_probe, "VMEM_FILTER_LIMIT", 2 * fb)
    srv = JoinServer(batch_slots=4)
    qs = [srv.submit(JoinRequest(rels=[r1, r2], budget=QueryBudget(error=0.5),
                                 query_id=f"t{i}", seed=10 + i,
                                 max_strata=512, b_max=256,
                                 use_kernels=True))
          for i in range(4)]
    srv.run()
    assert srv.diagnostics.max_batch == 2        # capped below batch_slots
    assert srv.diagnostics.steps == 2
    for i, q in enumerate(qs):
        direct = approx_join([r1, r2], QueryBudget(error=0.5), max_strata=512,
                             b_max=256, seed=10 + i, use_kernels=True)
        assert _identical(q.result, direct), i


def test_kernel_route_accepts_filter_seed_and_prebuilt_words(rng):
    """filter_seed decoupling (and prebuilt words) now work on the kernel
    path — the refactor lifted the old ValueError — and stay bit-identical
    to the jnp path under the same (filter_seed, seed) split."""
    from repro.core import bloom
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = JoinServer(batch_slots=2)

    def submit(use_kernels, **kw):
        return srv.submit(JoinRequest(
            rels=[r1, r2], budget=QueryBudget(error=0.5), seed=3,
            max_strata=512, b_max=256, use_kernels=use_kernels, **kw))

    a = submit(True, query_id="k", filter_seed=9)
    b = submit(False, query_id="j", filter_seed=9)
    srv.run()
    assert _identical(a.result, b.result)

    nb = bloom.num_blocks_for(1 << 11, 0.01)
    words = [bloom.build(r.keys, r.valid, nb, 9).words for r in (r1, r2)]
    c = submit(True, query_id="kw")
    c.filter_seed = 9
    c._words = words
    d = submit(True, query_id="kw2", filter_seed=9)
    srv.run()
    assert _identical(c.result, d.result)      # prebuilt == cache-built


def test_kernel_route_on_mesh1_no_host_gather(rng):
    """A 1-device mesh server serves kernel queries without any host
    round-trip (the rows already sit on the one device) — the satellite
    meter must read zero, and results match the meshless kernel server."""
    import jax
    import numpy as np_
    from jax.sharding import Mesh
    r1, r2 = make_pair(rng, n=1 << 11)
    mesh = Mesh(np_.array(jax.devices()[:1]), ("data",))
    srv = JoinServer(batch_slots=2, mesh=mesh)
    srv.register_dataset("ds", [r1, r2])
    q = srv.submit(JoinRequest(dataset="ds", budget=QueryBudget(error=0.5),
                               query_id="t", seed=3, max_strata=512,
                               b_max=256, use_kernels=True))
    srv.run()
    direct = approx_join([r1, r2], QueryBudget(error=0.5), max_strata=512,
                         b_max=256, seed=3, use_kernels=True)
    assert _identical(q.result, direct)
    assert srv.diagnostics.kernel_queries == 1
    assert srv.diagnostics.kernel_gather_bytes == 0.0
