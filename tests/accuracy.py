"""Statistical accuracy harness for ApproxJoin backends.

The bit-parity suite (tests/test_join_serve_distributed.py) proves the
expensive gather-merge serve path reproduces the single-device pipeline
float-for-float.  An *approximate* system's real contract is statistical —
"tight error bounds on the accuracy of the final results" — and that is the
only gate the cheap psum merge with capacity-planned buckets can pass.  This
harness states that contract once, for ANY backend:

Given R seeded replications over synthetic relations with known ground truth
(the exact ``repartition_join`` baseline from ``core/baselines.py``):

(a) **relative error within the CLT bound**: the mean relative error of the
    SUM estimate is dominated by the mean relative CLT half-width the
    backend reported (plus the per-replication check feeding (b));
(b) **CI coverage**: the reported ``[estimate ± error_bound]`` interval
    covers the truth in at least ``confidence - coverage_slack`` of the
    replications;
(c) **allocation-faithful draws**: realized per-stratum draw counts equal
    the stratified allocation ``min(max(ceil(s * B_i), 1), b_max)`` over
    joinable strata (skipped for backends that do not expose stats);
plus COUNT (exact given the strata) within ``count_rtol`` — the tolerance a
capacity-planned backend's counted drops must stay inside.

A backend is any ``fn(rels, seed) -> (estimate, error_bound, count, stats)``
with floats and an optional :class:`~repro.core.estimators.StratumStats`-like
pytree (any slot layout — canonical [S] or concatenated per-device [k*S];
the checks are per-stratum sums, layout-free).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.baselines import repartition_join
from repro.data.synthetic import overlapping_relations


@dataclass(frozen=True)
class GateConfig:
    """Workload + thresholds of one accuracy-gate run.

    The defaults build joins with ~64 shared strata of ~8 rows per side
    (population B_i ~ 64), so the pilot allocation draws enough per stratum
    for the variance estimate to be real — a gate over strata with b_i = 1
    would be vacuous (zero estimated variance, exact-by-accident sampling).
    """

    replications: int = 30
    n_rows: int = 2048
    keys_per_dataset: int = 256
    overlap: float = 0.25
    pilot_fraction: float = 0.1
    b_max: int = 256
    max_strata: int = 512
    confidence: float = 0.95
    coverage_slack: float = 0.05
    count_rtol: float = 1e-6
    seed: int = 0


@dataclass
class GateReport:
    """Everything the gate measured; ``failures`` empty == gate passed."""

    replications: int = 0
    coverage: float = 0.0
    nominal: float = 0.0
    mean_rel_err: float = 0.0
    mean_rel_bound: float = 0.0
    max_count_rel_err: float = 0.0
    alloc_mismatches: int = 0
    checked_allocation: bool = False
    failures: list = field(default_factory=list)

    @property
    def passed(self) -> bool:
        return not self.failures

    def summary(self) -> str:
        return (f"coverage {self.coverage:.3f} (nominal {self.nominal:.2f}), "
                f"rel err {self.mean_rel_err:.4f} vs CLT bound "
                f"{self.mean_rel_bound:.4f}, count rel err "
                f"{self.max_count_rel_err:.2e}, alloc mismatches "
                f"{self.alloc_mismatches} over {self.replications} reps"
                + ("" if self.passed else f" — FAILURES: {self.failures}"))


def expected_allocation(population: np.ndarray, pilot_fraction: float,
                        b_max: int) -> np.ndarray:
    """The §3.2-II pilot allocation the sampler must realize per stratum."""
    want = np.where(population > 0,
                    np.maximum(np.ceil(pilot_fraction * population), 1.0),
                    0.0)
    return np.minimum(want, float(b_max))


_TRUTH_CACHE: dict = {}


def _workload(cfg: GateConfig, r: int):
    """Replication r's relations + exact ground truth (truth memoized —
    several backends gate over the same seeded workloads)."""
    rels = overlapping_relations(
        [cfg.n_rows] * 2, cfg.overlap,
        keys_per_dataset=cfg.keys_per_dataset, seed=cfg.seed + r)
    key = (cfg.n_rows, cfg.keys_per_dataset, cfg.overlap, cfg.seed + r)
    if key not in _TRUTH_CACHE:
        truth = repartition_join(rels, expr="sum")
        _TRUTH_CACHE[key] = (float(truth.estimate), float(truth.count))
    return rels, _TRUTH_CACHE[key]


def run_accuracy_gate(backend, cfg: GateConfig = GateConfig()) -> GateReport:
    """Run R replications of ``backend`` against exact ground truth."""
    hits, rel_errs, rel_bounds, count_errs = 0, [], [], []
    alloc_bad, checked_alloc = 0, False
    for r in range(cfg.replications):
        rels, (t_sum, t_cnt) = _workload(cfg, r)
        est, bound, cnt, stats = backend(rels, cfg.seed + 7919 + r)
        hits += abs(est - t_sum) <= bound
        rel_errs.append(abs(est - t_sum) / max(abs(t_sum), 1e-9))
        rel_bounds.append(bound / max(abs(t_sum), 1e-9))
        count_errs.append(abs(cnt - t_cnt) / max(t_cnt, 1.0))
        if stats is not None:
            checked_alloc = True
            pop = np.asarray(stats.population, np.float64)
            drawn = np.where(np.asarray(stats.valid),
                             np.asarray(stats.n_sampled, np.float64), 0.0)
            want = expected_allocation(pop, cfg.pilot_fraction, cfg.b_max)
            alloc_bad += int(np.sum(want != drawn))

    rep = GateReport(
        replications=cfg.replications,
        coverage=hits / cfg.replications,
        nominal=cfg.confidence,
        mean_rel_err=float(np.mean(rel_errs)),
        mean_rel_bound=float(np.mean(rel_bounds)),
        max_count_rel_err=float(np.max(count_errs)),
        alloc_mismatches=alloc_bad,
        checked_allocation=checked_alloc)
    if rep.coverage < cfg.confidence - cfg.coverage_slack:
        rep.failures.append(
            f"coverage {rep.coverage:.3f} < "
            f"{cfg.confidence - cfg.coverage_slack:.3f}")
    if rep.mean_rel_err > rep.mean_rel_bound:
        rep.failures.append(
            f"mean relative error {rep.mean_rel_err:.4f} exceeds the mean "
            f"CLT relative bound {rep.mean_rel_bound:.4f}")
    if rep.max_count_rel_err > cfg.count_rtol:
        rep.failures.append(
            f"count rel err {rep.max_count_rel_err:.2e} > {cfg.count_rtol}")
    if alloc_bad:
        rep.failures.append(
            f"{alloc_bad} strata drew != the stratified allocation")
    return rep
