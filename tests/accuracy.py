"""Statistical accuracy harness for ApproxJoin backends.

The bit-parity suite (tests/test_join_serve_distributed.py) proves the
expensive gather-merge serve path reproduces the single-device pipeline
float-for-float.  An *approximate* system's real contract is statistical —
"tight error bounds on the accuracy of the final results" — and that is the
only gate the cheap psum merge with capacity-planned buckets can pass.  This
harness states that contract once, for ANY backend:

Given R seeded replications over synthetic relations with known ground truth
(the exact ``repartition_join`` baseline from ``core/baselines.py``):

(a) **relative error within the CLT bound**: the mean relative error of the
    SUM estimate is dominated by the mean relative CLT half-width the
    backend reported (plus the per-replication check feeding (b));
(b) **CI coverage**: the reported ``[estimate ± error_bound]`` interval
    covers the truth in at least ``confidence - coverage_slack`` of the
    replications;
(c) **allocation-faithful draws**: realized per-stratum draw counts equal
    the stratified allocation ``min(max(ceil(s * B_i), 1), b_max)`` over
    joinable strata (skipped for backends that do not expose stats);
plus COUNT (exact given the strata) within ``count_rtol`` — the tolerance a
capacity-planned backend's counted drops must stay inside.

A backend is any ``fn(rels, seed) -> (estimate, error_bound, count, stats)``
with floats and an optional :class:`~repro.core.estimators.StratumStats`-like
pytree (any slot layout — canonical [S] or concatenated per-device [k*S];
the checks are per-stratum sums, layout-free).

:func:`run_stream_accuracy_gate` restates the same contract **per window**
for a streaming backend: every replication is one tumbling window delivered
as micro-batches, checked against the exact join of exactly that window's
tuples — so a window whose estimate leaked expired data, missed a
micro-batch, or reported a stale bound fails the gate the same way a biased
static backend does.  A stream backend is
``fn(micro_batches, w) -> (estimate, error_bound, count, stats)`` where
``micro_batches`` is a list of per-side Relation lists (``stats`` may be
None on windows whose allocation is sigma-fed rather than pilot-fed).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.baselines import repartition_join
from repro.core.relation import Relation
from repro.data.synthetic import overlapping_relations


@dataclass(frozen=True)
class GateConfig:
    """Workload + thresholds of one accuracy-gate run.

    The defaults build joins with ~64 shared strata of ~8 rows per side
    (population B_i ~ 64), so the pilot allocation draws enough per stratum
    for the variance estimate to be real — a gate over strata with b_i = 1
    would be vacuous (zero estimated variance, exact-by-accident sampling).
    """

    replications: int = 30
    n_rows: int = 2048
    n_rels: int = 2            # inputs per join (3+ gates multi-way plans)
    keys_per_dataset: int = 256
    overlap: float = 0.25
    pilot_fraction: float = 0.1
    b_max: int = 256
    max_strata: int = 512
    confidence: float = 0.95
    coverage_slack: float = 0.05
    count_rtol: float = 1e-6
    seed: int = 0


@dataclass
class GateReport:
    """Everything the gate measured; ``failures`` empty == gate passed."""

    replications: int = 0
    coverage: float = 0.0
    nominal: float = 0.0
    mean_rel_err: float = 0.0
    mean_rel_bound: float = 0.0
    max_count_rel_err: float = 0.0
    alloc_mismatches: int = 0
    checked_allocation: bool = False
    failures: list = field(default_factory=list)

    @property
    def passed(self) -> bool:
        return not self.failures

    def summary(self) -> str:
        return (f"coverage {self.coverage:.3f} (nominal {self.nominal:.2f}), "
                f"rel err {self.mean_rel_err:.4f} vs CLT bound "
                f"{self.mean_rel_bound:.4f}, count rel err "
                f"{self.max_count_rel_err:.2e}, alloc mismatches "
                f"{self.alloc_mismatches} over {self.replications} reps"
                + ("" if self.passed else f" — FAILURES: {self.failures}"))


def expected_allocation(population: np.ndarray, pilot_fraction: float,
                        b_max: int) -> np.ndarray:
    """The §3.2-II pilot allocation the sampler must realize per stratum."""
    want = np.where(population > 0,
                    np.maximum(np.ceil(pilot_fraction * population), 1.0),
                    0.0)
    return np.minimum(want, float(b_max))


_TRUTH_CACHE: dict = {}


def _workload(cfg: GateConfig, r: int):
    """Replication r's relations + exact ground truth (truth memoized —
    several backends gate over the same seeded workloads)."""
    rels = overlapping_relations(
        [cfg.n_rows] * cfg.n_rels, cfg.overlap,
        keys_per_dataset=cfg.keys_per_dataset, seed=cfg.seed + r)
    key = (cfg.n_rows, cfg.n_rels, cfg.keys_per_dataset, cfg.overlap,
           cfg.seed + r)
    if key not in _TRUTH_CACHE:
        truth = repartition_join(rels, expr="sum")
        _TRUTH_CACHE[key] = (float(truth.estimate), float(truth.count))
    return rels, _TRUTH_CACHE[key]


class _Collector:
    """Accumulates per-replication measurements and applies the checks —
    shared by the static and per-window gates (one contract, two drivers)."""

    def __init__(self, pilot_fraction: float, b_max: int):
        self.pilot_fraction, self.b_max = pilot_fraction, b_max
        self.hits, self.n = 0, 0
        self.rel_errs, self.rel_bounds, self.count_errs = [], [], []
        self.alloc_bad, self.checked_alloc = 0, False

    def add(self, est, bound, cnt, stats, t_sum, t_cnt) -> None:
        self.n += 1
        self.hits += abs(est - t_sum) <= bound
        self.rel_errs.append(abs(est - t_sum) / max(abs(t_sum), 1e-9))
        self.rel_bounds.append(bound / max(abs(t_sum), 1e-9))
        self.count_errs.append(abs(cnt - t_cnt) / max(t_cnt, 1.0))
        if stats is not None:
            self.checked_alloc = True
            pop = np.asarray(stats.population, np.float64)
            drawn = np.where(np.asarray(stats.valid),
                             np.asarray(stats.n_sampled, np.float64), 0.0)
            want = expected_allocation(pop, self.pilot_fraction, self.b_max)
            self.alloc_bad += int(np.sum(want != drawn))

    def report(self, confidence: float, coverage_slack: float,
               count_rtol: float) -> GateReport:
        rep = GateReport(
            replications=self.n,
            coverage=self.hits / max(self.n, 1),
            nominal=confidence,
            mean_rel_err=float(np.mean(self.rel_errs)),
            mean_rel_bound=float(np.mean(self.rel_bounds)),
            max_count_rel_err=float(np.max(self.count_errs)),
            alloc_mismatches=self.alloc_bad,
            checked_allocation=self.checked_alloc)
        if rep.coverage < confidence - coverage_slack:
            rep.failures.append(
                f"coverage {rep.coverage:.3f} < "
                f"{confidence - coverage_slack:.3f}")
        if rep.mean_rel_err > rep.mean_rel_bound:
            rep.failures.append(
                f"mean relative error {rep.mean_rel_err:.4f} exceeds the "
                f"mean CLT relative bound {rep.mean_rel_bound:.4f}")
        if rep.max_count_rel_err > count_rtol:
            rep.failures.append(
                f"count rel err {rep.max_count_rel_err:.2e} > {count_rtol}")
        if self.alloc_bad:
            rep.failures.append(
                f"{self.alloc_bad} strata drew != the stratified allocation")
        return rep


def run_accuracy_gate(backend, cfg: GateConfig = GateConfig()) -> GateReport:
    """Run R replications of ``backend`` against exact ground truth."""
    col = _Collector(cfg.pilot_fraction, cfg.b_max)
    for r in range(cfg.replications):
        rels, (t_sum, t_cnt) = _workload(cfg, r)
        est, bound, cnt, stats = backend(rels, cfg.seed + 7919 + r)
        col.add(est, bound, cnt, stats, t_sum, t_cnt)
    return col.report(cfg.confidence, cfg.coverage_slack, cfg.count_rtol)


# ---------------------------------------------------------------------------
# Per-window gate for streaming backends: each replication is one tumbling
# window delivered as micro-batches; truth is the exact join of exactly that
# window's tuples (so leaked expired data or a missed micro-batch fails).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StreamGateConfig:
    """Workload + thresholds of one per-window accuracy-gate run."""

    windows: int = 12          # replications (one per tumbling window)
    window_size: int = 4       # micro-batches (sub-windows) per window
    rows_per_window: int = 2048
    keys_per_dataset: int = 256
    overlap: float = 0.25
    pilot_fraction: float = 0.1
    b_max: int = 256
    max_strata: int = 512
    confidence: float = 0.95
    coverage_slack: float = 0.05
    count_rtol: float = 1e-6
    seed: int = 0

    @property
    def rows_per_sub(self) -> int:
        assert self.rows_per_window % self.window_size == 0
        return self.rows_per_window // self.window_size


def stream_window_workload(cfg: StreamGateConfig, w: int):
    """Window w's micro-batch stream + its exact ground truth.

    The window's relations are drawn like the static gate's (fresh keys and
    values per window — independent replications), then sliced into
    ``window_size`` per-side micro-batches; the streaming engine must
    reassemble exactly this window.
    """
    rels = overlapping_relations(
        [cfg.rows_per_window] * 2, cfg.overlap,
        keys_per_dataset=cfg.keys_per_dataset, seed=cfg.seed + w)
    rs = cfg.rows_per_sub
    mbs = [[Relation(r.keys[m * rs:(m + 1) * rs],
                     r.values[m * rs:(m + 1) * rs],
                     r.valid[m * rs:(m + 1) * rs]) for r in rels]
           for m in range(cfg.window_size)]
    key = ("stream", cfg.rows_per_window, cfg.keys_per_dataset, cfg.overlap,
           cfg.seed + w)
    if key not in _TRUTH_CACHE:
        truth = repartition_join(rels, expr="sum")
        _TRUTH_CACHE[key] = (float(truth.estimate), float(truth.count))
    return mbs, _TRUTH_CACHE[key]


def run_stream_accuracy_gate(stream_backend,
                             cfg: StreamGateConfig = StreamGateConfig()
                             ) -> GateReport:
    """Per-window statistical contract of a streaming join backend."""
    col = _Collector(cfg.pilot_fraction, cfg.b_max)
    for w in range(cfg.windows):
        mbs, (t_sum, t_cnt) = stream_window_workload(cfg, w)
        est, bound, cnt, stats = stream_backend(mbs, w)
        col.add(est, bound, cnt, stats, t_sum, t_cnt)
    return col.report(cfg.confidence, cfg.coverage_slack, cfg.count_rtol)
