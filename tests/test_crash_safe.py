"""Crash-safe serving (ISSUE 7): engine snapshot/restore round-trips over
every live-state leaf kind, hardened checkpoint validation (torn dirs,
stale tmp sweeps, corrupt leaves), fault-layer fixes (even-fleet straggler
median, guarded_step backoff + shielded callback, InjectedFault), and the
end-to-end drill — a replica killed mid-stream whose successor adopts its
tenants from the newest checkpoint and serves every subsequent window
bit-identical to an uninterrupted run, shedding nothing.

This file is owned by the CI "async serving" leg (8 host devices) and
excluded everywhere else — keep it runnable on 1 device: multi-device
cases must skip, not fail.
"""

import os
import time

import jax
import numpy as np
import pytest

from repro.core.budget import QueryBudget
from repro.core.relation import relation
from repro.core.window import WindowSpec
from repro.runtime.async_serve import AsyncJoinFrontDoor
from repro.runtime.checkpoint import (CheckpointCorruptError, latest_step,
                                      load_checkpoint, save_checkpoint)
from repro.runtime.fault import (InjectedFault, StragglerMonitor,
                                 elastic_restore_engine, guarded_step)
from repro.runtime.join_serve import JoinRequest
from repro.runtime.stream_join import StreamJoinServer

MS, BM = 1024, 512


def _mb(seed, n=256):
    r = np.random.default_rng(seed)
    return [relation(r.integers(0, 200, n).astype(np.uint32),
                     r.normal(10, 2, n).astype(np.float32)),
            relation(r.integers(150, 350, n).astype(np.uint32),
                     r.normal(5, 1, n).astype(np.float32))]


def _result_key(r):
    return (float(r.result.estimate), float(r.result.error_bound),
            float(r.result.count), float(r.result.dof))


def _stream_server(**kw):
    srv = StreamJoinServer(batch_slots=4, **kw)
    return srv


def _loaded_engine():
    """A StreamJoinServer carrying every leaf kind the snapshot covers:
    registered dataset (jnp Relations), warm filter-word cache, sigma
    table, a queued static request, and a sliding-window session with live
    sub-windows, reservoir sketches, and a non-trivial running SumParts."""
    srv = _stream_server()
    srv.register_dataset("ds0", _mb(1, n=512))
    srv.sigma.table["tq/agg"] = {7: 0.25, 11: 1.5}
    sess = srv.open_stream("t", WindowSpec(size=2, slide=1, sub_rows=256),
                           budget=QueryBudget(error=0.5), max_strata=MS,
                           b_max=BM, seed=3)
    # serve window 0 so the accumulator and overlap state are non-trivial,
    # then leave window 1 queued and sub-windows 1..2 live in the buffer
    sess.push(_mb(100))
    sess.push(_mb(101))
    srv.run()
    sess.drain()
    sess.push(_mb(102))
    srv.submit(JoinRequest(dataset="ds0", budget=QueryBudget(error=0.5),
                           query_id="tq/agg", seed=5, max_strata=MS,
                           b_max=BM))
    return srv, sess


def test_snapshot_roundtrip_every_leaf_kind(tmp_path):
    """snapshot -> save -> load -> restore reproduces every leaf kind
    bit-exactly, and the restored engine serves its adopted queue
    bit-identical to the original serving its own."""
    srv, sess = _loaded_engine()
    flat, meta = srv.snapshot_state()
    save_checkpoint(str(tmp_path), 0, flat, extra=meta)
    flat2, meta2 = load_checkpoint(str(tmp_path), 0)

    dst = _stream_server()
    restored = dst.restore_state(flat2, meta2)
    assert len(restored) == len(srv.queue) == 2  # window 1 + static query

    # datasets (jnp Relations + fingerprints/overlap bookkeeping)
    assert list(dst.datasets) == ["ds0"]
    for a, b in zip(srv.datasets["ds0"], dst.datasets["ds0"]):
        for f in ("keys", "values", "valid"):
            np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                          np.asarray(getattr(b, f)))
    assert dst._dataset_fps["ds0"] == srv._dataset_fps["ds0"]

    # filter-word cache entries, in LRU order
    assert list(dst._filter_words) == list(srv._filter_words)
    for k in srv._filter_words:
        np.testing.assert_array_equal(np.asarray(srv._filter_words[k]),
                                      np.asarray(dst._filter_words[k]))

    # sigma registry
    assert dst.sigma.table["tq/agg"] == {7: 0.25, 11: 1.5}

    # session: buffer bookkeeping, live sub-windows, sketch reservoirs,
    # running SumParts accumulation
    d = dst.sessions["t"]
    assert (d.buffer.arrived, d.buffer.emitted) == (3, 2)
    assert [s.index for s in d.buffer.live] == \
        [s.index for s in sess.buffer.live]
    for a, b in zip(sess.buffer.live, d.buffer.live):
        assert a.fps == b.fps
        for ra, rb in zip(a.rels, b.rels):
            np.testing.assert_array_equal(np.asarray(ra.keys),
                                          np.asarray(rb.keys))
    for side in range(2):
        for f in ("priority", "values", "n_seen"):
            np.testing.assert_array_equal(
                np.asarray(getattr(sess.sketch[side], f)),
                np.asarray(getattr(d.sketch[side], f)))
    assert d._running == sess._running and d._running[0] != 0.0
    assert (d._acc_end, d.accumulated_windows) == (2, 1)

    # both engines serve their (identical) queues bit-identically, and the
    # restored session keeps emitting from where the original would
    srv.run(), dst.run()
    sess.push(_mb(103)), d.push(_mb(103))
    srv.run(), dst.run()
    a, b = sess.drain(), d.drain()
    assert [r.window_id for r in a] == [r.window_id for r in b] == [1, 2]
    for ra, rb in zip(a, b):
        assert _result_key(ra) == _result_key(rb)


def test_restore_merges_into_live_engine(tmp_path):
    """Failover semantics: restore MERGES — the successor keeps its own
    datasets and sessions alongside the adopted ones."""
    srv, _ = _loaded_engine()
    flat, meta = srv.snapshot_state()
    save_checkpoint(str(tmp_path), 4, flat, extra=meta)

    dst = _stream_server()
    dst.register_dataset("own", _mb(2, n=512))
    dst.open_stream("mine", WindowSpec(size=1, slide=1, sub_rows=256),
                    budget=QueryBudget(error=0.5), max_strata=MS, b_max=BM)
    assert elastic_restore_engine(str(tmp_path), dst) == 4
    assert set(dst.datasets) == {"own", "ds0"}
    assert set(dst.sessions) == {"mine", "t"}
    assert elastic_restore_engine(str(tmp_path / "empty"), dst) is None


def test_async_writer_path_and_surfaced_failure(tmp_path):
    """The async writer round-trips, and a writer failure is recorded on
    the thread object instead of dying silently (the stale-checkpoint
    failure mode the drill would otherwise inherit)."""
    srv, _ = _loaded_engine()
    flat, meta = srv.snapshot_state()
    th = save_checkpoint(str(tmp_path), 9, flat, sync=False, extra=meta)
    th.join(60)
    assert th.exception is None and latest_step(str(tmp_path)) == 9
    flat2, _ = load_checkpoint(str(tmp_path), 9)
    assert set(flat2) == set(flat)

    blocked = tmp_path / "blocked"
    blocked.write_text("not a directory")
    th = save_checkpoint(str(blocked), 0, {"a": np.zeros(3)}, sync=False)
    th.join(60)
    assert th.exception is not None


def test_latest_step_skips_torn_dirs_and_sweeps_stale_tmp(tmp_path):
    """A mid-write kill leaves either an unrenamed .tmp-* dir or (a hand
    copy / partial sync) a step dir without a readable manifest — neither
    may be offered as the newest checkpoint, and stale tmp dirs are swept."""
    save_checkpoint(str(tmp_path), 3, {"a": np.arange(4)})
    torn = tmp_path / "step_00000008"
    torn.mkdir()
    np.save(torn / "a.npy", np.arange(4))          # leaves, no manifest
    garbled = tmp_path / "step_00000009"
    garbled.mkdir()
    (garbled / "manifest.json").write_text("{truncated")
    fresh_tmp = tmp_path / "step_00000010.tmp-abc"
    fresh_tmp.mkdir()
    stale_tmp = tmp_path / "step_00000011.tmp-def"
    stale_tmp.mkdir()
    old = time.time() - 3600
    os.utime(stale_tmp, (old, old))

    assert latest_step(str(tmp_path)) == 3
    assert fresh_tmp.exists() and not stale_tmp.exists()


def test_corrupt_checkpoints_raise_typed_errors(tmp_path):
    srv, _ = _loaded_engine()
    flat, meta = srv.snapshot_state()
    save_checkpoint(str(tmp_path), 1, flat, extra=meta)
    d = tmp_path / "step_00000001"
    leaf = next(f for f in os.listdir(d) if f.endswith(".npy"))
    (d / leaf).write_bytes(b"\x00" * 8)
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(str(tmp_path), 1)
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        load_checkpoint(str(tmp_path), 77)


def test_straggler_median_even_fleet():
    """4-host regression: with EWMAs [1.0, 1.0, 2.2, 4.2] the true median
    is 1.6 (threshold 3.2 flags the 4.2 host); the old upper-middle
    'median' of 2.2 set the bar at 4.4 and hid the straggler entirely."""
    mon = StragglerMonitor(threshold=2.0)
    for host, t in [("a", 1.0), ("b", 1.0), ("c", 2.2), ("d", 4.2)]:
        for _ in range(5):
            mon.record(host, t)
    assert mon.stragglers() == ["d"]


def test_guarded_step_backoff_and_shielded_callback(monkeypatch):
    sleeps = []
    monkeypatch.setattr("repro.runtime.fault.time.sleep", sleeps.append)
    calls = {"n": 0, "cb": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("injected")
        return "ok"

    def bad_callback(attempt, exc):
        calls["cb"] += 1
        raise ValueError("callback bug must not mask the step error")

    out = guarded_step(flaky, None, None, retries=3, backoff_s=0.1,
                       on_failure=bad_callback)
    assert out == "ok" and sleeps == [0.1, 0.2]   # exponential, no 3rd sleep
    with pytest.raises(RuntimeError, match="failed after"):
        guarded_step(lambda s, b: 1 / 0, None, None, retries=1,
                     backoff_s=0.1, on_failure=bad_callback)
    assert sleeps == [0.1, 0.2, 0.1]              # no sleep after last try
    assert calls["cb"] == 4


def test_injected_fault_passes_retry_loop():
    calls = {"n": 0}

    def dies(state, batch):
        calls["n"] += 1
        raise InjectedFault("killed")

    with pytest.raises(InjectedFault):
        guarded_step(dies, None, None, retries=5)
    assert calls["n"] == 1                        # not retried, not wrapped


# -- the drill: kill a replica mid-stream, successor adopts its tenants ------

def _drill(tmp, mesh_devices=0, ticks=8, kill_after_windows=2):
    """Uninterrupted baseline vs a 2-replica front door whose replica0 is
    killed after ``kill_after_windows`` served windows.  Returns
    (baseline {window_id: result key}, faulted ditto, shed, failovers,
    baseline sigma table, front-door sigma table)."""
    spec = WindowSpec(size=2, slide=2, sub_rows=256)
    budget = QueryBudget(error=0.5)

    def mesh():
        if not mesh_devices:
            return None
        from jax.sharding import Mesh
        return Mesh(np.array(jax.devices()[:mesh_devices]), ("data",))

    base = _stream_server(mesh=mesh())
    bsess = base.open_stream("tenA", spec, budget=budget, max_strata=MS,
                             b_max=BM, seed=7)
    for t in range(ticks):
        bsess.push(_mb(100 + t))
        base.run()
    baseline = {r.window_id: _result_key(r) for r in bsess.drain()}

    def factory(i):
        return _stream_server(mesh=mesh())

    out = {}
    pre_kill_ticks = kill_after_windows * spec.slide
    with AsyncJoinFrontDoor(replicas=2, engine_factory=factory,
                            checkpoint_dir=tmp) as fd:
        rep, _ = fd.open_stream("tenA", spec, budget=budget, max_strata=MS,
                                b_max=BM, seed=7)
        futs = []
        for t in range(pre_kill_ticks):
            futs += fd.push("tenA", _mb(100 + t))
        for f in futs:
            r = f.result(timeout=120)
            out[r.window_id] = _result_key(r)
        rep.kill_after(0)
        rep._thread.join(60)
        assert not rep._thread.is_alive()
        assert isinstance(rep.error, InjectedFault)
        # fd.push re-routes to wherever the session lives NOW: the failover
        # successor restores replica0's newest checkpoint on first touch
        for t in range(pre_kill_ticks, ticks):
            for f in fd.push("tenA", _mb(100 + t)):
                r = f.result(timeout=120)
                out[r.window_id] = _result_key(r)
        snap = fd.snapshot()
        succ = next(r for r in fd.replicas if r.error is None)
        shed = succ.call(
            lambda: succ.engine.stream_diagnostics.windows_shed).result()
    return (baseline, out, shed, snap,
            dict(base.sigma.table), dict(fd.sigma.table))


def test_kill_and_resume_bit_parity(tmp_path):
    """A replica killed mid-stream, restored by a successor from its
    newest checkpoint, serves every subsequent window of the adopted
    tenant bit-identical to an uninterrupted run — zero windows shed, and
    the sigma sequence continues exactly (identical final tables)."""
    baseline, out, shed, snap, bsig, fsig = _drill(str(tmp_path))
    assert snap["failovers"] == 1 and snap["failed"] == ["replica0"]
    assert shed == 0
    assert sorted(out) == sorted(baseline) == [0, 1, 2, 3]
    assert out == baseline
    assert fsig == bsig


def test_kill_and_resume_mesh_parity(tmp_path):
    """The drill on a device mesh: the successor re-shards restored
    relations onto its mesh and window parity still holds."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    ndev = min(jax.device_count(), 4)
    baseline, out, shed, snap, _, _ = _drill(
        str(tmp_path), mesh_devices=ndev, ticks=6, kill_after_windows=1)
    assert snap["failovers"] == 1 and shed == 0
    assert sorted(out) == sorted(baseline) == [0, 1, 2]
    assert out == baseline
