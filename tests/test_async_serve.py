"""Async serving tier (``runtime/async_serve.py``): bit-identity of the
event-loop path with the synchronous server and with direct ``approx_join``
(including per-``query_id`` sigma sequences), backfill order preservation,
deadline-aware admission through the ingress ring, front-door tenant
sharding + work stealing, async streaming windows (served and shed), and
the perf-trajectory gate (``benchmarks/check_trajectory.py``).

This file is owned by the CI "async serving" leg (8 host devices) and
excluded everywhere else — keep it runnable on 1 device: multi-device
cases must skip, not fail.
"""

import json
import sys
import threading
import time
from concurrent.futures import Future
from pathlib import Path

import numpy as np
import pytest

from conftest import make_pair
from repro.core.budget import QueryBudget
from repro.core.cost import CostModel
from repro.core.join import approx_join
from repro.core.window import WindowSpec
from repro.core.relation import relation
from repro.runtime.async_serve import AsyncJoinFrontDoor, AsyncJoinServer
from repro.runtime.join_serve import JoinRequest, JoinServer
from repro.runtime.stream_join import StreamJoinServer

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks import check_trajectory  # noqa: E402

MS, BM = 1024, 512   # max_strata / b_max used throughout


def _identical(a, b):
    """Bitwise equality of the user-facing result surface."""
    return (float(a.estimate) == float(b.estimate)
            and float(a.error_bound) == float(b.error_bound)
            and float(a.count) == float(b.count)
            and float(a.dof) == float(b.dof))


def _req(rels, budget, qid, seed):
    return JoinRequest(rels=rels, budget=budget, query_id=qid, seed=seed,
                       max_strata=MS, b_max=BM)


def _workload(rng, tenants=2, per_tenant=4):
    """(rels, budget, qid, seed) tuples: tenants interleaved, repeated
    query_ids so the sigma feedback chain is exercised, an exact budget
    mixed in."""
    pairs = [make_pair(rng, n=1 << 11, mu1=5.0 + 3 * t)
             for t in range(tenants)]
    out = []
    for q in range(per_tenant):
        for t in range(tenants):
            budget = QueryBudget() if q == per_tenant - 1 \
                else QueryBudget(error=0.5)
            out.append((list(pairs[t]), budget,
                        f"tenant{t}/sum{q % 2}", 40 + q))
    return out


def _sync_baseline(workload, **kw):
    srv = JoinServer(batch_slots=4, **kw)
    reqs = [srv.submit(_req(*w)) for w in workload]
    srv.run()
    return reqs


# -- single replica ----------------------------------------------------------

def test_async_bit_identical_to_sync_and_direct(rng):
    workload = _workload(rng)
    sync = _sync_baseline(workload)
    with AsyncJoinServer(batch_slots=4) as srv:
        futs = [srv.submit(_req(*w)) for w in workload]
        reqs = [f.result(timeout=120) for f in futs]
        snap = srv.snapshot()

    for i, (r, s) in enumerate(zip(reqs, sync)):
        assert r.done and not r.shed and _identical(r.result, s.result), i
    # the first occurrence of each query_id equals direct approx_join
    seen = set()
    for (rels, budget, qid, seed), r in zip(workload, reqs):
        if qid in seen:
            continue
        seen.add(qid)
        direct = approx_join(rels, budget, max_strata=MS, b_max=BM,
                             seed=seed)
        assert _identical(r.result, direct), qid
    # ingestion/dispatch/completion stamps are ordered, latencies positive
    for r in reqs:
        assert 0 < r._ingest_t <= r._dispatch_t <= r._complete_t
        assert r.queue_latency_s >= 0 and r.e2e_latency_s > 0
    # diagnostics carry the async surface
    assert snap["ingested"] == len(workload) and snap["backlog"] == 0
    assert snap["queries"] == len(workload)
    assert 0 < snap["queue_latency_p50_s"] <= snap["queue_latency_p95_s"]
    assert snap["e2e_latency_p95_s"] >= snap["queue_latency_p95_s"]
    assert set(snap["per_tenant"]) == {"tenant0", "tenant1"}
    assert snap["per_tenant"]["tenant0"]["samples"] == len(workload) // 2


def test_async_backfill_never_reorders_same_id(rng):
    """Seeded property: whatever slices of the stream land via mid-flight
    backfill vs idle drain, same-``query_id`` requests dispatch in
    submission order and results stay bit-identical to the sync server."""
    workload = _workload(rng, tenants=2, per_tenant=6)
    sync = _sync_baseline(workload)
    prop_rng = np.random.default_rng(7)
    for trial in range(3):
        with AsyncJoinServer(batch_slots=4, linger_s=0.004) as srv:
            futs = []
            for w in workload:
                futs.append(srv.submit(_req(*w)))
                # jitter submissions so some requests arrive mid-step and
                # enter through _linger backfill, others through idle drain
                time.sleep(float(prop_rng.uniform(0, 0.004)))
            reqs = [f.result(timeout=120) for f in futs]
        order = {}
        for i, ((_, _, qid, _), r) in enumerate(zip(workload, reqs)):
            assert _identical(r.result, sync[i].result), (trial, i)
            order.setdefault(qid, []).append(r._dispatch_t)
        for qid, ts in order.items():
            assert ts == sorted(ts), (trial, qid, ts)


def test_async_deadline_scheduling_from_ingress(rng):
    """A latency-budget query entering through the ingress ring is promoted
    by the engine's deadline-aware scheduler: with the loop held until every
    submission is ingested, the backlog drains in at most two waves, and the
    latency query (submitted mid-burst) always lands in a backlogged queue —
    so it must dispatch before every error query submitted after it."""
    r1, r2 = make_pair(rng, n=1 << 11)
    gate_open = threading.Event()
    with AsyncJoinServer(batch_slots=2,
                         cost_model=CostModel(beta_compute=1e-7,
                                              epsilon=1e-3)) as srv:
        gate = srv.call(gate_open.wait)     # hold the loop while we submit
        early = [srv.submit(_req([r1, r2], QueryBudget(error=0.5),
                                 f"t/e{i}", seed=50 + i)) for i in range(4)]
        lat = srv.submit(_req([r1, r2], QueryBudget(latency_s=2.0),
                              "t/lat", seed=99))
        late = [srv.submit(_req([r1, r2], QueryBudget(error=0.5),
                                f"t/e{4 + i}", seed=54 + i))
                for i in range(4)]
        gate_open.set()
        gate.result(timeout=60)
        done = [f.result(timeout=120) for f in early + [lat] + late]
    lat_r, late_rs = done[4], done[5:]
    assert lat_r.done and not lat_r.shed
    assert lat_r._dispatch_t <= min(r._dispatch_t for r in late_rs)


def test_async_close_rejects_new_submissions(rng):
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = AsyncJoinServer(batch_slots=2)
    f = srv.submit(_req([r1, r2], QueryBudget(error=0.5), "t/a", seed=1))
    assert f.result(timeout=120).done
    srv.close()
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(_req([r1, r2], QueryBudget(error=0.5), "t/b", seed=2))


# -- front door: sharding + stealing -----------------------------------------

def test_front_door_steals_and_stays_bit_identical(rng):
    workload = _workload(rng, tenants=4, per_tenant=4)
    sync = _sync_baseline(workload)
    with AsyncJoinFrontDoor(replicas=2, batch_slots=2) as fd:
        # pre-assign every tenant to replica0 so replica1 starts idle and
        # MUST steal to participate
        with fd._alock:
            for t in range(4):
                fd._assign[f"tenant{t}"] = fd.replicas[0]
        futs = [fd.submit(_req(*w)) for w in workload]
        reqs = [f.result(timeout=120) for f in futs]
        snap = fd.snapshot()
    for i, (r, s) in enumerate(zip(reqs, sync)):
        assert _identical(r.result, s.result), i
    assert snap["steals"] > 0
    served = {name: d["queries"] for name, d in snap["replicas"].items()}
    assert served["replica1"] > 0 and sum(served.values()) == len(workload)


def test_front_door_sticky_without_stealing(rng):
    workload = _workload(rng, tenants=2, per_tenant=3)
    with AsyncJoinFrontDoor(replicas=2, work_stealing=False,
                            batch_slots=2) as fd:
        with fd._alock:
            for t in range(2):
                fd._assign[f"tenant{t}"] = fd.replicas[0]
        futs = [fd.submit(_req(*w)) for w in workload]
        for f in futs:
            assert f.result(timeout=120).done
        snap = fd.snapshot()
    assert snap["steals"] == 0
    assert snap["replicas"]["replica1"]["queries"] == 0
    assert snap["replicas"]["replica0"]["queries"] == len(workload)


def test_front_door_dataset_broadcast(rng):
    r1, r2 = make_pair(rng, n=1 << 11)
    with AsyncJoinFrontDoor(replicas=2, batch_slots=2) as fd:
        fd.register_dataset("shared", [r1, r2])
        for rep in fd.replicas:
            assert "shared" in rep.engine.datasets
        f = fd.submit(JoinRequest(dataset="shared",
                                  budget=QueryBudget(error=0.5),
                                  query_id="x/q", seed=3,
                                  max_strata=MS, b_max=BM))
        assert f.result(timeout=120).done


@pytest.mark.slow
def test_async_mesh_parity(rng):
    """Async tier over a device mesh matches the synchronous mesh server
    bit for bit (the CI async leg runs with 8 forced host devices)."""
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices")
    from jax.sharding import Mesh
    ndev = min(jax.device_count(), 4)
    workload = _workload(rng, tenants=2, per_tenant=2)

    def mesh():
        return Mesh(np.array(jax.devices()[:ndev]), ("data",))

    sync = _sync_baseline(workload, mesh=mesh())

    def factory(i):
        return JoinServer(batch_slots=4, mesh=mesh())

    with AsyncJoinFrontDoor(replicas=2, engine_factory=factory) as fd:
        futs = [fd.submit(_req(*w)) for w in workload]
        reqs = [f.result(timeout=300) for f in futs]
    for i, (r, s) in enumerate(zip(reqs, sync)):
        assert _identical(r.result, s.result), i


# -- async streaming ---------------------------------------------------------

def _mb(seed, n=512):
    r = np.random.default_rng(seed)
    return [relation(r.integers(0, 200, n).astype(np.uint32),
                     r.normal(10, 2, n).astype(np.float32)),
            relation(r.integers(150, 350, n).astype(np.uint32),
                     r.normal(5, 1, n).astype(np.float32))]


def test_async_stream_windows_bit_identical():
    spec = WindowSpec(size=4, slide=1, sub_rows=512)
    batches = [_mb(100 + i) for i in range(6)]

    base = StreamJoinServer(batch_slots=2)
    sess = base.open_stream("t", spec, budget=QueryBudget(error=0.5),
                            max_strata=MS, b_max=BM, seed=3)
    done = []
    for mb in batches:
        sess.push(mb)
        base.run()
        done += sess.drain()
    assert [r.window_id for r in done] == [0, 1, 2]

    with AsyncJoinServer(StreamJoinServer(batch_slots=2)) as srv:
        asess = srv.open_stream("t", spec, budget=QueryBudget(error=0.5),
                                max_strata=MS, b_max=BM, seed=3)
        futs = []
        for mb in batches:
            futs.append(srv.push(asess, mb))
        wins = [f.result(timeout=120) for fs in futs for f in fs]
    assert [r.window_id for r in wins] == [0, 1, 2]
    for a, b in zip(wins, done):
        assert not a.shed and _identical(a.result, b.result), a.window_id


def test_async_stream_shed_windows_resolve_futures():
    """Per-tenant admission sheds the oldest queued window; the shed hook
    must resolve the async caller's future (with ``.shed`` set) instead of
    leaving it hanging.  The loop is held during the pushes so the shed
    sequence is deterministic."""
    spec = WindowSpec(size=1, slide=1, sub_rows=512)
    with AsyncJoinServer(StreamJoinServer(batch_slots=4,
                                          window_slots=1)) as srv:
        sess = srv.open_stream("t", spec, budget=QueryBudget(error=0.5),
                               max_strata=MS, b_max=BM, seed=3)

        def _push_all():
            # mirrors AsyncJoinServer.push, but all four pushes run in one
            # loop turn: no window can be served between them, so with
            # window_slots=1 exactly the first three are shed
            pairs = []
            for i in range(4):
                for req in sess.push(_mb(200 + i)):
                    f = Future()
                    req._future = f
                    pairs.append((req, f))
            return pairs

        pairs = srv.call(_push_all).result(timeout=120)
        reqs = [f.result(timeout=120) for _, f in pairs]
        shed_count = srv.engine.stream_diagnostics.windows_shed
    assert len(reqs) == 4 and shed_count == 3
    assert [r.shed for r in reqs] == [True, True, True, False]
    assert reqs[-1].done and reqs[-1].result is not None


# -- perf-trajectory gate ----------------------------------------------------

def _rows(**over):
    base = {"bench": "serve", "mode": "batched", "queries": 64,
            "qps": 100.0, "queue_latency_p95_s": 0.10}
    base.update(over)
    return {("serve", "batched"): base}


def test_trajectory_compare_throughput_and_latency():
    old = _rows()
    ok, notes = check_trajectory.compare(_rows(qps=95.0), old,
                                         tol=0.20, factor=1.0)
    assert ok == [] and notes == []
    bad, _ = check_trajectory.compare(_rows(qps=75.0), old,
                                      tol=0.20, factor=1.0)
    assert bad and "qps regressed" in bad[0]
    # latency has an absolute floor: 0.16 < 0.10*1.2 + 0.05 passes
    ok, _ = check_trajectory.compare(_rows(queue_latency_p95_s=0.16), old,
                                     tol=0.20, factor=1.0)
    assert ok == []
    bad, _ = check_trajectory.compare(_rows(queue_latency_p95_s=0.50), old,
                                      tol=0.20, factor=1.0)
    assert bad and "queue_latency_p95_s regressed" in bad[0]


def test_trajectory_compare_scaling_rows_and_ratios():
    old = _rows()
    # a 2x slower machine is allowed 2x lower qps before tolerance
    ok, _ = check_trajectory.compare(_rows(qps=45.0), old,
                                     tol=0.20, factor=2.0)
    assert ok == []
    # a vanished row always fails
    bad, _ = check_trajectory.compare({}, old, tol=0.20, factor=1.0)
    assert bad and "disappeared" in bad[0]
    # smoke-vs-full scale mismatch is skipped with a note, not gated
    ok, notes = check_trajectory.compare(
        _rows(queries=640, qps=10.0), old, tol=0.20, factor=1.0)
    assert ok == [] and notes and "skipped" in notes[0]
    # speedup ratios are machine-independent: the factor must NOT excuse
    # a ratio regression
    old_r = {("serve", "speedup"): {"bench": "serve", "mode": "speedup",
                                    "x": 2.0}}
    new_r = {("serve", "speedup"): {"bench": "serve", "mode": "speedup",
                                    "x": 1.4}}
    bad, _ = check_trajectory.compare(new_r, old_r, tol=0.20, factor=2.0)
    assert bad and "x regressed" in bad[0]


def test_trajectory_refresh_and_check_roundtrip(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    rows = [{"bench": "serve", "mode": "batched", "queries": 64,
             "qps": 100.0, "queue_latency_p95_s": 0.10}]
    (tmp_path / "BENCH_serve.json").write_text(json.dumps(rows))
    base = str(tmp_path / "bl")
    assert check_trajectory.refresh(base) == 0
    assert (tmp_path / "bl" / "serve.json").exists()
    assert (tmp_path / "bl" / "calibration.json").exists()
    # same artifact gates clean; a big qps drop fails; a missing artifact
    # with a baseline present fails
    assert check_trajectory.main(["--baseline-dir", base]) == 0
    rows[0]["qps"] = 10.0
    (tmp_path / "BENCH_serve.json").write_text(json.dumps(rows))
    assert check_trajectory.main(["--baseline-dir", base]) == 1
    (tmp_path / "BENCH_serve.json").unlink()
    assert check_trajectory.main(["--baseline-dir", base]) == 1


def test_trajectory_baseline_dir_precedence(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    monkeypatch.delenv("REPRO_BASELINE_DIR", raising=False)
    assert check_trajectory.baseline_dir("explicit") == "explicit"
    monkeypatch.setenv("REPRO_BASELINE_DIR", "from-env")
    assert check_trajectory.baseline_dir(None) == "from-env"
    monkeypatch.delenv("REPRO_BASELINE_DIR")
    # empty cache dir falls through to the committed snapshot ...
    assert check_trajectory.baseline_dir(None) \
        == check_trajectory.COMMITTED_DIR
    # ... a populated one takes precedence
    cache = tmp_path / check_trajectory.CACHE_DIR
    cache.mkdir()
    (cache / "serve.json").write_text("[]")
    assert check_trajectory.baseline_dir(None) == check_trajectory.CACHE_DIR
