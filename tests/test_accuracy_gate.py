"""The statistical accuracy gate (tests/accuracy.py) applied to every join
backend: the approx_join driver, the gather-merge (exact-parity) server and
the psum server with capacity-planned shuffle buckets, at mesh 1/2/4/8.

This is what licenses the cheap psum serve path: it can never be
bit-identical to the single-device pipeline (float reassociation in the
psum, counted drops beyond the bucket plan), so its contract is the paper's
— CLT-bounded relative error, nominal CI coverage, allocation-faithful
stratified draws — verified over >= 30 seeded replications against the
exact ``repartition_join`` ground truth.

Mesh sizes > 1 run in a SUBPROCESS with
``--xla_force_host_platform_device_count=8`` so the rest of the suite keeps
the real single-device backend; mesh 1 and the driver run in-process.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from accuracy import GateConfig, run_accuracy_gate
from repro.core.budget import QueryBudget
from repro.core.join import approx_join
from repro.runtime.join_serve import JoinRequest, JoinServer

CFG = GateConfig()
# capacity-planned buckets may drop (counted) tuples; the count estimate is
# allowed to move by at most 2% — anything silent or larger fails the gate
PSUM_CFG = GateConfig(count_rtol=2e-2)


def approx_join_backend(rels, seed):
    res = approx_join(
        rels, QueryBudget(error=0.5, pilot_fraction=CFG.pilot_fraction),
        max_strata=CFG.max_strata, b_max=CFG.b_max, seed=seed)
    return (float(res.estimate), float(res.error_bound), float(res.count),
            res.stats)


def make_server_backend(server: JoinServer, use_kernels: bool = False):
    """One registered dataset + one pilot-round query per replication."""
    def backend(rels, seed):
        name = f"rep{seed}"
        server.register_dataset(name, rels)
        q = server.submit(JoinRequest(
            dataset=name,
            budget=QueryBudget(error=0.5, pilot_fraction=CFG.pilot_fraction),
            query_id=name, seed=seed, max_strata=CFG.max_strata,
            b_max=CFG.b_max, use_kernels=use_kernels))
        server.run()
        return (float(q.result.estimate), float(q.result.error_bound),
                float(q.result.count), q.result.stats)
    return backend


def mesh_server(devices: int, serve_mode: str) -> JoinServer:
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:devices]), ("data",))
    return JoinServer(batch_slots=1, mesh=mesh, serve_mode=serve_mode)


def test_accuracy_gate_approx_join():
    rep = run_accuracy_gate(approx_join_backend, CFG)
    assert rep.passed, rep.summary()
    assert rep.checked_allocation


@pytest.mark.parametrize("serve_mode", ["exact-parity", "psum"])
def test_accuracy_gate_server_mesh1(serve_mode):
    srv = mesh_server(1, serve_mode)
    rep = run_accuracy_gate(make_server_backend(srv), PSUM_CFG
                            if serve_mode == "psum" else CFG)
    assert rep.passed, rep.summary()
    assert rep.checked_allocation
    assert srv.diagnostics.dist_dropped_tuples == 0.0


def test_accuracy_gate_approx_join_kernels():
    """Kernel-path row: the fused Pallas operator (interpret mode) passes
    the same statistical contract as the jnp driver."""
    def backend(rels, seed):
        res = approx_join(
            rels, QueryBudget(error=0.5, pilot_fraction=CFG.pilot_fraction),
            max_strata=CFG.max_strata, b_max=CFG.b_max, seed=seed,
            use_kernels=True)
        return (float(res.estimate), float(res.error_bound),
                float(res.count), res.stats)
    rep = run_accuracy_gate(backend, CFG)
    assert rep.passed, rep.summary()
    assert rep.checked_allocation


def test_accuracy_gate_server_kernels_mesh1():
    """Kernel-path row, served: the batched Pallas engine path at mesh 1
    passes the gate with zero host-gather bytes (the post-refactor batched
    path never round-trips rows on a 1-device mesh)."""
    srv = mesh_server(1, "exact-parity")
    rep = run_accuracy_gate(make_server_backend(srv, use_kernels=True), CFG)
    assert rep.passed, rep.summary()
    assert rep.checked_allocation
    assert srv.diagnostics.kernel_queries == CFG.replications
    assert srv.diagnostics.kernel_gather_bytes == 0.0


def test_gate_rejects_biased_backend():
    """Harness self-test: a backend whose estimate is 20% off must fail."""
    def biased(rels, seed):
        est, bound, cnt, _ = approx_join_backend(rels, seed)
        return est * 1.2, bound, cnt, None
    rep = run_accuracy_gate(biased, GateConfig(replications=10))
    assert not rep.passed, rep.summary()


def test_gate_rejects_overconfident_backend():
    """A backend reporting absurdly tight error bounds must fail coverage."""
    def overconfident(rels, seed):
        est, bound, cnt, _ = approx_join_backend(rels, seed)
        return est, bound * 1e-4, cnt, None
    rep = run_accuracy_gate(overconfident, GateConfig(replications=10))
    assert not rep.passed, rep.summary()


def test_gate_rejects_silent_drops():
    """Uncounted lost tuples surface as a count mismatch."""
    def lossy(rels, seed):
        est, bound, cnt, _ = approx_join_backend(rels, seed)
        return est, bound, cnt * 0.9, None
    rep = run_accuracy_gate(lossy, GateConfig(replications=5))
    assert not rep.passed, rep.summary()


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from test_accuracy_gate import (CFG, PSUM_CFG, make_server_backend,
                                mesh_server, run_accuracy_gate)

for d in (2, 4, 8):
    for mode, cfg in (("exact-parity", CFG), ("psum", PSUM_CFG)):
        srv = mesh_server(d, mode)
        rep = run_accuracy_gate(make_server_backend(srv), cfg)
        dropped = srv.diagnostics.dist_dropped_tuples
        print(f"mesh{d} {mode}: {rep.summary()} dropped={dropped}",
              flush=True)
        assert rep.passed, (d, mode, rep.summary())
        assert rep.checked_allocation
        if mode == "exact-parity":
            # lossless buckets: the parity path may never drop a row
            assert dropped == 0.0, dropped
        else:
            # whatever the plan dropped was counted, per device too
            assert dropped == float(
                srv.diagnostics.per_device_dropped_tuples.sum())
print("ACCURACY-GATE-OK")
"""


@pytest.mark.slow
def test_accuracy_gate_mesh_2_4_8():
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(["src", "tests"]))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ACCURACY-GATE-OK" in out.stdout, out.stdout[-2000:]
