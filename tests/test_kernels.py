"""Pallas kernel sweeps: shapes x seeds x fp-rates, bit-exact vs the ref.py
oracles (interpret mode on CPU; same code Mosaic-compiles on TPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bloom
from repro.core.relation import relation, sort_by_key
from repro.core.sampling import build_strata, sample_edges
from repro.kernels import ops, ref
from repro.kernels.bloom_build import bloom_hashes
from repro.kernels.bloom_probe import bloom_probe
from repro.kernels.edge_sample import edge_sample


@pytest.mark.parametrize("n", [2048, 4096, 8192])
@pytest.mark.parametrize("seed", [0, 7])
def test_bloom_hashes_sweep(n, seed):
    keys = jnp.asarray(np.random.default_rng(seed).integers(
        0, 2**32 - 1, n, dtype=np.uint32))
    nb = bloom.num_blocks_for(n, 0.01)
    blk, masks = bloom_hashes(keys, nb, seed, interpret=True)
    rblk, rmasks = ref.bloom_hashes_ref(keys, nb, seed)
    np.testing.assert_array_equal(np.asarray(blk), np.asarray(rblk))
    np.testing.assert_array_equal(np.asarray(masks), np.asarray(rmasks))


@pytest.mark.parametrize("n,fp", [(2048, 0.1), (4096, 0.01), (2048, 0.001)])
def test_bloom_probe_sweep(n, fp):
    rng = np.random.default_rng(n)
    keys = jnp.asarray(rng.integers(0, 1 << 20, n, dtype=np.uint32))
    nb = bloom.num_blocks_for(n, fp)
    f = bloom.build(keys, jnp.ones(n, bool), nb, seed=3)
    probe = jnp.asarray(rng.integers(0, 1 << 21, 4096, dtype=np.uint32))
    got = bloom_probe(f.words, probe, seed=3, interpret=True)
    want = ref.bloom_probe_ref(f.words, probe, seed=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_build_filter_wrapper_pads_and_matches():
    rng = np.random.default_rng(1)
    for n in (100, 2048, 5000):  # non-multiples exercise padding
        keys = jnp.asarray(rng.integers(0, 1 << 16, n, dtype=np.uint32))
        valid = jnp.asarray(rng.random(n) > 0.2)
        nb = bloom.num_blocks_for(n, 0.01)
        a = bloom.build(keys, valid, nb, seed=5)
        b = ops.build_filter(keys, valid, nb, seed=5, interpret=True)
        np.testing.assert_array_equal(np.asarray(a.words),
                                      np.asarray(b.words))
        m1 = bloom.contains(a, keys)
        m2 = ops.probe_filter(a.words, keys, seed=5, interpret=True)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@pytest.mark.parametrize("S,b_max", [(128, 64), (256, 128), (384, 256)])
@pytest.mark.parametrize("expr", ["sum", "product"])
def test_edge_sample_sweep(S, b_max, expr):
    rng = np.random.default_rng(S + b_max)
    n = 4096
    r1 = sort_by_key(relation(
        rng.integers(0, S // 2, n).astype(np.uint32),
        rng.normal(3, 1, n).astype(np.float32)))
    r2 = sort_by_key(relation(
        rng.integers(S // 4, S, n).astype(np.uint32),
        rng.normal(1, 2, n).astype(np.float32)))
    strata = build_strata([r1, r2], S)
    b_i = jnp.ceil(0.3 * strata.population)
    got = edge_sample(r1.values, r2.values, strata.keys,
                      strata.starts[0], strata.counts[0],
                      strata.starts[1], strata.counts[1],
                      strata.joinable, b_i.astype(jnp.float32),
                      b_max, seed=11, expr=expr, interpret=True)
    want = ref.edge_sample_ref(r1.values, r2.values, strata.keys,
                               strata.starts[0], strata.counts[0],
                               strata.starts[1], strata.counts[1],
                               strata.joinable, b_i.astype(jnp.float32),
                               b_max, seed=11, expr=expr)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, atol=1e-4)


def test_edge_sample_matches_core_sampler():
    """Kernel == the full core sampler (which also does dedup bookkeeping)."""
    rng = np.random.default_rng(9)
    n = 2048
    r1 = sort_by_key(relation(rng.integers(0, 40, n).astype(np.uint32),
                              rng.normal(0, 1, n).astype(np.float32)))
    r2 = sort_by_key(relation(rng.integers(20, 60, n).astype(np.uint32),
                              rng.normal(0, 1, n).astype(np.float32)))
    strata = build_strata([r1, r2], 128)
    b_i = jnp.minimum(strata.population, 100.0)
    core = sample_edges([r1, r2], strata, b_i, 128, seed=4)
    kern = ops.sample_stats([r1, r2], strata, b_i, 128, seed=4,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(core.stats.n_sampled),
                                  np.asarray(kern.n_sampled))
    np.testing.assert_allclose(np.asarray(core.stats.sum_f),
                               np.asarray(kern.sum_f), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(core.stats.sum_f2),
                               np.asarray(kern.sum_f2), rtol=1e-6)


def test_vmem_guards():
    """Wrappers refuse working sets beyond the VMEM budget."""
    big = jnp.zeros((1 << 22,), jnp.float32)  # 16 MiB > 8 MiB budget
    with pytest.raises(AssertionError):
        edge_sample(big, big, jnp.zeros((128,), jnp.uint32),
                    jnp.zeros((128,), jnp.int32), jnp.ones((128,), jnp.int32),
                    jnp.zeros((128,), jnp.int32), jnp.ones((128,), jnp.int32),
                    jnp.ones((128,), bool), jnp.ones((128,), jnp.float32),
                    64)
    with pytest.raises(AssertionError):
        bloom_probe(jnp.zeros((1 << 19, 8), jnp.uint32),
                    jnp.zeros((2048,), jnp.uint32))
