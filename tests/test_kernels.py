"""Pallas kernel sweeps: shapes x seeds x fp-rates, bit-exact vs the ref.py
oracles (interpret mode on CPU; same code Mosaic-compiles on TPU), plus the
batched-slot contracts: the 2-D (batch_slot, key/strata block) grids must be
bit-exact per slot against the single-query wrappers, seeds must be runtime
operands (one compile per shape class across any number of seeds), and
wrapper padding must never flip a result."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stubs
from repro.core import bloom
from repro.core.relation import relation, sort_by_key
from repro.core.sampling import build_strata, sample_edges
from repro.kernels import ops, ref
from repro.kernels.bloom_build import bloom_hashes
from repro.kernels.bloom_probe import bloom_probe
from repro.kernels.edge_sample import edge_sample

given, settings, st = hypothesis_or_stubs()


@pytest.mark.parametrize("n", [2048, 4096, 8192])
@pytest.mark.parametrize("seed", [0, 7])
def test_bloom_hashes_sweep(n, seed):
    keys = jnp.asarray(np.random.default_rng(seed).integers(
        0, 2**32 - 1, n, dtype=np.uint32))
    nb = bloom.num_blocks_for(n, 0.01)
    blk, masks = bloom_hashes(keys, nb, seed, interpret=True)
    rblk, rmasks = ref.bloom_hashes_ref(keys, nb, seed)
    np.testing.assert_array_equal(np.asarray(blk), np.asarray(rblk))
    np.testing.assert_array_equal(np.asarray(masks), np.asarray(rmasks))


@pytest.mark.parametrize("n,fp", [(2048, 0.1), (4096, 0.01), (2048, 0.001)])
def test_bloom_probe_sweep(n, fp):
    rng = np.random.default_rng(n)
    keys = jnp.asarray(rng.integers(0, 1 << 20, n, dtype=np.uint32))
    nb = bloom.num_blocks_for(n, fp)
    f = bloom.build(keys, jnp.ones(n, bool), nb, seed=3)
    probe = jnp.asarray(rng.integers(0, 1 << 21, 4096, dtype=np.uint32))
    got = bloom_probe(f.words, probe, seed=3, interpret=True)
    want = ref.bloom_probe_ref(f.words, probe, seed=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_build_filter_wrapper_pads_and_matches():
    rng = np.random.default_rng(1)
    for n in (100, 2048, 5000):  # non-multiples exercise padding
        keys = jnp.asarray(rng.integers(0, 1 << 16, n, dtype=np.uint32))
        valid = jnp.asarray(rng.random(n) > 0.2)
        nb = bloom.num_blocks_for(n, 0.01)
        a = bloom.build(keys, valid, nb, seed=5)
        b = ops.build_filter(keys, valid, nb, seed=5, interpret=True)
        np.testing.assert_array_equal(np.asarray(a.words),
                                      np.asarray(b.words))
        m1 = bloom.contains(a, keys)
        m2 = ops.probe_filter(a.words, keys, seed=5, interpret=True)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@pytest.mark.parametrize("S,b_max", [(128, 64), (256, 128), (384, 256)])
@pytest.mark.parametrize("expr", ["sum", "product"])
def test_edge_sample_sweep(S, b_max, expr):
    rng = np.random.default_rng(S + b_max)
    n = 4096
    r1 = sort_by_key(relation(
        rng.integers(0, S // 2, n).astype(np.uint32),
        rng.normal(3, 1, n).astype(np.float32)))
    r2 = sort_by_key(relation(
        rng.integers(S // 4, S, n).astype(np.uint32),
        rng.normal(1, 2, n).astype(np.float32)))
    strata = build_strata([r1, r2], S)
    b_i = jnp.ceil(0.3 * strata.population)
    got = edge_sample(r1.values, r2.values, strata.keys,
                      strata.starts[0], strata.counts[0],
                      strata.starts[1], strata.counts[1],
                      strata.joinable, b_i.astype(jnp.float32),
                      b_max, seed=11, expr=expr, interpret=True)
    want = ref.edge_sample_ref(r1.values, r2.values, strata.keys,
                               strata.starts[0], strata.counts[0],
                               strata.starts[1], strata.counts[1],
                               strata.joinable, b_i.astype(jnp.float32),
                               b_max, seed=11, expr=expr)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, atol=1e-4)


def test_edge_sample_matches_core_sampler():
    """Kernel == the full core sampler (which also does dedup bookkeeping)."""
    rng = np.random.default_rng(9)
    n = 2048
    r1 = sort_by_key(relation(rng.integers(0, 40, n).astype(np.uint32),
                              rng.normal(0, 1, n).astype(np.float32)))
    r2 = sort_by_key(relation(rng.integers(20, 60, n).astype(np.uint32),
                              rng.normal(0, 1, n).astype(np.float32)))
    strata = build_strata([r1, r2], 128)
    b_i = jnp.minimum(strata.population, 100.0)
    core = sample_edges([r1, r2], strata, b_i, 128, seed=4)
    kern = ops.sample_stats([r1, r2], strata, b_i, 128, seed=4,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(core.stats.n_sampled),
                                  np.asarray(kern.n_sampled))
    np.testing.assert_allclose(np.asarray(core.stats.sum_f),
                               np.asarray(kern.sum_f), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(core.stats.sum_f2),
                               np.asarray(kern.sum_f2), rtol=1e-6)


def test_vmem_guards():
    """Wrappers refuse working sets beyond the VMEM budget — including the
    stacked-slot layouts, whose budget is charged for ALL B slots."""
    big = jnp.zeros((1 << 22,), jnp.float32)  # 16 MiB > 8 MiB budget
    with pytest.raises(AssertionError):
        edge_sample(big, big, jnp.zeros((128,), jnp.uint32),
                    jnp.zeros((128,), jnp.int32), jnp.ones((128,), jnp.int32),
                    jnp.zeros((128,), jnp.int32), jnp.ones((128,), jnp.int32),
                    jnp.ones((128,), bool), jnp.ones((128,), jnp.float32),
                    64)
    with pytest.raises(AssertionError):
        bloom_probe(jnp.zeros((1 << 19, 8), jnp.uint32),
                    jnp.zeros((2048,), jnp.uint32))
    # each slot fits alone, but B of them bust the B * filter_bytes budget
    from repro.kernels.bloom_probe import bloom_probe_batched
    with pytest.raises(AssertionError):
        bloom_probe_batched(jnp.zeros((16, 1 << 16, 8), jnp.uint32),
                            jnp.zeros((16, 2048), jnp.uint32),
                            jnp.zeros((16,), jnp.uint32))
    from repro.kernels.edge_sample import edge_sample_batched
    col = jnp.zeros((16, 128), jnp.int32)
    with pytest.raises(AssertionError):
        edge_sample_batched(jnp.zeros((16, 1 << 18), jnp.float32),
                            jnp.zeros((16, 1 << 18), jnp.float32),
                            col.astype(jnp.uint32), col, col, col, col,
                            col.astype(bool), col.astype(jnp.float32),
                            jnp.zeros((16,), jnp.uint32), 64)


# ---------------------------------------------------------------------------
# Batched slot layouts: per-slot bit-parity with the single-query wrappers,
# mixed seeds per slot.
# ---------------------------------------------------------------------------

def test_batched_build_and_probe_mixed_seeds_bit_exact():
    """One stacked dispatch over B slots with B different seeds must equal B
    single-slot calls (and the jnp reference) bit for bit."""
    rng = np.random.default_rng(2)
    B, n = 4, 2048
    keys = jnp.asarray(rng.integers(0, 1 << 20, (B, n), dtype=np.uint32))
    valid = jnp.asarray(rng.random((B, n)) > 0.2)
    probe_keys = jnp.asarray(rng.integers(0, 1 << 21, (B, 3000),
                                          dtype=np.uint32))
    seeds = jnp.asarray([3, 11, 3, 250], jnp.uint32)   # repeats + distinct
    nb = bloom.num_blocks_for(n, 0.01)
    words = ops.build_filter_batched(keys, valid, nb, seeds, interpret=True)
    hits = ops.probe_filter_batched(words, probe_keys, seeds, interpret=True)
    for b in range(B):
        s = int(seeds[b])
        ref_f = bloom.build(keys[b], valid[b], nb, s)
        np.testing.assert_array_equal(np.asarray(words[b]),
                                      np.asarray(ref_f.words))
        one = ops.probe_filter(words[b], probe_keys[b], s, interpret=True)
        np.testing.assert_array_equal(np.asarray(hits[b]), np.asarray(one))
        np.testing.assert_array_equal(
            np.asarray(hits[b]),
            np.asarray(bloom.contains(ref_f, probe_keys[b])))


def test_batched_edge_sample_mixed_seeds_bit_exact():
    """The stacked sampler grid: every slot must match its own single-slot
    kernel call AND the jnp oracle, under per-slot seeds."""
    rng = np.random.default_rng(5)
    B, n, S, b_max = 3, 2048, 256, 128
    seeds = [7, 7, 901]
    slots = []
    for b in range(B):
        r1 = sort_by_key(relation(
            rng.integers(0, S // 2, n).astype(np.uint32),
            rng.normal(3, 1, n).astype(np.float32)))
        r2 = sort_by_key(relation(
            rng.integers(S // 4, S, n).astype(np.uint32),
            rng.normal(1, 2, n).astype(np.float32)))
        strata = build_strata([r1, r2], S)
        slots.append((r1, r2, strata, jnp.ceil(0.3 * strata.population)))
    def stack(xs):
        return jnp.stack(list(xs))
    stats = ops.sample_stats_batched(
        stack(s[0].values for s in slots), stack(s[1].values for s in slots),
        stack(s[2].keys for s in slots), stack(s[2].starts for s in slots),
        stack(s[2].counts for s in slots),
        stack(s[2].joinable for s in slots),
        stack(s[2].population for s in slots), stack(s[3] for s in slots),
        jnp.asarray(seeds, jnp.uint32), b_max, "sum", interpret=True)
    for b, (r1, r2, strata, b_i) in enumerate(slots):
        one = ops.sample_stats([r1, r2], strata, b_i, b_max, seeds[b],
                               interpret=True)
        want = ref.edge_sample_ref(
            r1.values, r2.values, strata.keys,
            strata.starts[0], strata.counts[0],
            strata.starts[1], strata.counts[1],
            strata.joinable, b_i.astype(jnp.float32), b_max, seeds[b])
        for got in (
            (stats.n_sampled[b], stats.sum_f[b], stats.sum_f2[b]),
            (one.n_sampled, one.sum_f, one.sum_f2),
        ):
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_seeds_are_runtime_operands_no_recompiles():
    """The static-seed recompile bug, fixed: a 16-seed sweep through every
    wrapper must compile each executable exactly once."""
    rng = np.random.default_rng(8)
    n, S, b_max = 2048, 128, 64
    keys = jnp.asarray(rng.integers(0, 1 << 16, n, dtype=np.uint32))
    valid = jnp.ones(n, bool)
    nb = bloom.num_blocks_for(n, 0.01)
    r1 = sort_by_key(relation(rng.integers(0, 40, n).astype(np.uint32),
                              rng.normal(0, 1, n).astype(np.float32)))
    r2 = sort_by_key(relation(rng.integers(20, 60, n).astype(np.uint32),
                              rng.normal(0, 1, n).astype(np.float32)))
    strata = build_strata([r1, r2], S)
    b_i = jnp.minimum(strata.population, 50.0)
    jitted = (ops.build_filter_batched, ops.probe_filter_batched,
              ops.sample_stats_batched)
    before = tuple(f._cache_size() for f in jitted)
    for seed in range(16):
        f = ops.build_filter(keys, valid, nb, seed, interpret=True)
        ops.probe_filter(f.words, keys, seed, interpret=True)
        ops.sample_stats([r1, r2], strata, b_i, b_max, seed, interpret=True)
    grew = tuple(f._cache_size() - b for f, b in zip(jitted, before))
    assert all(g <= 1 for g in grew), \
        f"seed sweep recompiled: cache growth {grew}"


def test_prepare_stage_kernels_prebuilt_words_match():
    """The kernel prepare stage accepts prebuilt filter words (the serving
    engine's cache contract) and produces exactly the build-from-scratch
    result — and both match the jnp prepare_stage."""
    from repro.core.join import prepare_stage, prepare_stage_kernels
    rng = np.random.default_rng(3)
    n = 2048
    r1 = relation(rng.integers(0, 300, n).astype(np.uint32),
                  rng.normal(10, 2, n).astype(np.float32))
    r2 = relation(rng.integers(200, 500, n).astype(np.uint32),
                  rng.normal(5, 1, n).astype(np.float32))
    nb = bloom.num_blocks_for(n, 0.01)
    built = prepare_stage_kernels([r1, r2], nb, 512, 5)
    words = jnp.stack([bloom.build(r.keys, r.valid, nb, 5).words
                       for r in (r1, r2)])
    pre = prepare_stage_kernels([r1, r2], nb, 512, 5, filter_words=words)
    ref_prep = prepare_stage([r1, r2], nb, 512, 5)
    for other in (pre, ref_prep):
        np.testing.assert_array_equal(np.asarray(built.strata.keys),
                                      np.asarray(other.strata.keys))
        np.testing.assert_array_equal(np.asarray(built.strata.counts),
                                      np.asarray(other.strata.counts))
        np.testing.assert_array_equal(np.asarray(built.live_counts),
                                      np.asarray(other.live_counts))
        for a, b in zip(built.sorted_rels, other.sorted_rels):
            np.testing.assert_array_equal(np.asarray(a.values),
                                          np.asarray(b.values))


# ---------------------------------------------------------------------------
# Padding unification: wrappers pad, kernels assert, tails never leak.
# ---------------------------------------------------------------------------

def test_raw_kernels_assert_block_multiples():
    """The raw kernels refuse non-multiples — padding is the wrappers' job,
    in exactly one place."""
    with pytest.raises(AssertionError):
        bloom_hashes(jnp.zeros((100,), jnp.uint32), 16, 0)
    with pytest.raises(AssertionError):
        bloom_probe(jnp.zeros((16, 8), jnp.uint32),
                    jnp.zeros((100,), jnp.uint32))
    with pytest.raises(AssertionError):
        edge_sample(jnp.zeros((64,), jnp.float32), jnp.zeros((64,),
                                                            jnp.float32),
                    jnp.zeros((100,), jnp.uint32),
                    jnp.zeros((100,), jnp.int32), jnp.ones((100,), jnp.int32),
                    jnp.zeros((100,), jnp.int32), jnp.ones((100,), jnp.int32),
                    jnp.ones((100,), bool), jnp.ones((100,), jnp.float32),
                    16)


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=1, max_value=5000),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_padded_tail_never_flips_membership(n, seed32):
    """Hypothesis property: for any key-array length (pow2 or not — the
    wrapper pads the tail) and any seed, kernel probe == jnp membership and
    kernel build == jnp build.  A tail key leaking into the filter or the
    probe output would flip a bit somewhere in this comparison."""
    rng = np.random.default_rng(n * 31 + (seed32 & 0xFFFF))
    seed = int(seed32)
    keys = jnp.asarray(rng.integers(0, 1 << 12, n, dtype=np.uint32))
    valid = jnp.asarray(rng.random(n) > 0.3)
    nb = bloom.num_blocks_for(n, 0.05)
    want = bloom.build(keys, valid, nb, seed)
    got = ops.build_filter(keys, valid, nb, seed, interpret=True)
    np.testing.assert_array_equal(np.asarray(got.words),
                                  np.asarray(want.words))
    m = n + 13 if n % 2 else max(n - 7, 1)   # probe length != build length
    probe_keys = jnp.asarray(rng.integers(0, 1 << 13, m, dtype=np.uint32))
    hits = ops.probe_filter(want.words, probe_keys, seed, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(hits), np.asarray(bloom.contains(want, probe_keys)))
