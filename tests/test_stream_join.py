"""StreamJoin subsystem: incremental window filters (the slide contract),
bit-parity with the re-register baseline, window expiry, running estimates,
per-tenant admission / shedding, and the per-window accuracy gate."""

import os
import subprocess
import sys

import numpy as np
import pytest

from accuracy import StreamGateConfig, run_stream_accuracy_gate, \
    stream_window_workload
from repro.core.baselines import repartition_join
from repro.core.budget import QueryBudget
from repro.core.relation import bucket_to_pow2, concatenate, relation
from repro.core.window import (WindowBuffer, WindowSpec, SubWindow,
                               window_relations)
from repro.runtime.join_serve import JoinRequest, JoinServer
from repro.runtime.stream_join import StreamJoinServer

MS, BM = 1024, 256   # max_strata / b_max used throughout


def _mb(seed, n=512, k1=(0, 200), k2=(150, 350)):
    r = np.random.default_rng(seed)
    return [relation(r.integers(*k1, n).astype(np.uint32),
                     r.normal(10, 2, n).astype(np.float32)),
            relation(r.integers(*k2, n).astype(np.uint32),
                     r.normal(5, 1, n).astype(np.float32))]


def _identical(a, b):
    return (float(a.estimate) == float(b.estimate)
            and float(a.error_bound) == float(b.error_bound)
            and float(a.count) == float(b.count)
            and float(a.dof) == float(b.dof))


def _session(srv, spec, name="t", **kw):
    kw.setdefault("budget", QueryBudget(error=0.5))
    kw.setdefault("max_strata", MS)
    kw.setdefault("b_max", BM)
    kw.setdefault("seed", 3)
    return srv.open_stream(name, spec, **kw)


def test_window_buffer_emission_and_expiry():
    spec = WindowSpec(size=3, slide=2, sub_rows=4)
    buf = WindowBuffer(spec)
    seen, gone = [], []
    for i in range(7):
        due, expired = buf.push(SubWindow(i, (), ()))
        seen += [(w, [s.index for s in subs]) for w, subs in due]
        gone += [s.index for s in expired]
    # windows at starts 0, 2, 4; each emission expires everything below the
    # NEXT window's start (0..1, 2..3, then 4..5 once window 2 is out)
    assert seen == [(0, [0, 1, 2]), (1, [2, 3, 4]), (2, [4, 5, 6])]
    assert gone == [0, 1, 2, 3, 4, 5]
    assert [s.index for s in buf.live] == [6]
    with pytest.raises(ValueError):
        WindowSpec(size=2, slide=3, sub_rows=4).validate()


def test_sliding_window_bit_identical_to_reregister_baseline():
    """Every sliding window served incrementally equals a fresh
    register-the-window-as-a-dataset query bit for bit — including the
    sigma feedback sequence across windows (same query_id, same order)."""
    spec = WindowSpec(size=4, slide=1, sub_rows=512)
    srv = StreamJoinServer(batch_slots=2)
    sess = _session(srv, spec)
    batches = [_mb(100 + i) for i in range(6)]
    done = []
    for mb in batches:
        sess.push(mb)
        srv.run()
        done += sess.drain()
    assert [r.window_id for r in done] == [0, 1, 2]

    base = JoinServer(batch_slots=1)
    for r in done:
        w = r.window_id
        rels = [bucket_to_pow2(concatenate(
            [batches[w + m][side] for m in range(spec.size)]))
            for side in range(2)]
        base.register_dataset(f"w{w}", rels)
        q = base.submit(JoinRequest(
            dataset=f"w{w}", budget=QueryBudget(error=0.5),
            query_id=sess.query_id, seed=sess.seed + 1 + w,
            filter_seed=sess.filter_seed, max_strata=MS, b_max=BM))
        base.run()
        assert _identical(r.result, q.result), w


def test_slide_reuses_surviving_filter_builds():
    """The acceptance contract: sliding by one sub-window builds exactly
    one new filter per input, hits the cache for every survivor, and incurs
    zero recompiles at steady state."""
    spec = WindowSpec(size=4, slide=1, sub_rows=512)
    srv = StreamJoinServer(batch_slots=1)
    sess = _session(srv, spec)
    for i in range(4):
        sess.push(_mb(100 + i))
        srv.run()
    first = srv.diagnostics.snapshot()
    # first window: one build per (sub-window, side), nothing to reuse yet
    assert first["filter_builds"] == spec.size * 2
    assert first["filter_cache_hits"] == 0
    for i in range(4, 7):
        before = srv.diagnostics.snapshot()
        sess.push(_mb(100 + i))
        srv.run()
        after = srv.diagnostics.snapshot()
        # exactly the new sub-window builds; all survivors are cache hits
        assert after["filter_builds"] - before["filter_builds"] == 2
        assert after["filter_cache_hits"] - before["filter_cache_hits"] \
            == (spec.size - 1) * 2
        assert after["compiles"] == first["compiles"], "recompiled"
    # four windows emitted -> sub-windows 0..3 expired, words retired
    assert srv.stream_diagnostics.retired_filter_words == 4 * 2
    assert len(sess.drain()) == 4


def test_tumbling_windows_and_running_estimate():
    """Tumbling windows are disjoint: the running SumParts accumulation
    must cover the exact whole-stream join total within its CLT bound."""
    spec = WindowSpec(size=2, slide=2, sub_rows=512)
    srv = StreamJoinServer(batch_slots=1)
    sess = _session(srv, spec)
    batches = [_mb(200 + i) for i in range(8)]
    for mb in batches:
        sess.push(mb)
        srv.run()
    done = sess.drain()
    assert [r.window_id for r in done] == [0, 1, 2, 3]
    assert sess.accumulated_windows == 4

    total, cnt = 0.0, 0.0
    for w in range(4):
        rels = [bucket_to_pow2(concatenate(
            [batches[2 * w + m][side] for m in range(2)]))
            for side in range(2)]
        truth = repartition_join(rels, expr="sum")
        total += float(truth.estimate)
        cnt += float(truth.count)
    run = sess.running_estimate()
    # deterministic identity: the parts merge IS the sum of the per-window
    # estimates (windows are disjoint), and the count piece is exact
    per_window = sum(float(r.result.estimate) for r in done)
    assert float(run.estimate) == pytest.approx(per_window, rel=1e-6)
    assert sess._running[-1] == pytest.approx(cnt, rel=1e-6)
    # statistical sanity at this fixed seed (a single 95% CI realization
    # may graze the truth; 2x the half-width must contain it)
    assert abs(float(run.estimate) - total) <= 2 * float(run.error_bound)
    assert float(run.error_bound) < sum(
        float(r.result.error_bound) for r in done)


def test_window_expiry_drops_expired_tuples():
    """Tuples of an expired sub-window must not contribute: window [B, C]
    must equal the exact join of B+C alone, unmoved by A's heavy overlap."""
    spec = WindowSpec(size=2, slide=1, sub_rows=512)
    srv = StreamJoinServer(batch_slots=1)
    sess = _session(srv, spec, budget=QueryBudget())   # exact per window
    a = _mb(300, k1=(0, 50), k2=(0, 50))       # dense overlap, huge join
    b, c = _mb(301), _mb(302)
    for mb in (a, b, c):
        sess.push(mb)
        srv.run()
    w0, w1 = sess.drain()
    truth_ab = repartition_join(
        [bucket_to_pow2(concatenate([a[s], b[s]])) for s in range(2)],
        expr="sum")
    truth_bc = repartition_join(
        [bucket_to_pow2(concatenate([b[s], c[s]])) for s in range(2)],
        expr="sum")
    assert float(w0.result.estimate) == pytest.approx(
        float(truth_ab.estimate), rel=1e-5)
    assert float(w1.result.estimate) == pytest.approx(
        float(truth_bc.estimate), rel=1e-5)
    assert float(w1.result.count) == float(truth_bc.count)
    # the test is vacuous unless A actually would have moved the answer
    assert abs(float(truth_ab.estimate) - float(truth_bc.estimate)) \
        > 100 * abs(float(truth_bc.estimate)) * 1e-5


def test_admission_sheds_oldest_window_and_bounds_queue():
    spec = WindowSpec(size=1, slide=1, sub_rows=512)
    srv = StreamJoinServer(batch_slots=1, window_slots=2)
    sess = _session(srv, spec)
    reqs = []
    for i in range(5):                 # emit 5 windows, never serve
        reqs += sess.push(_mb(400 + i))
    assert srv.stream_diagnostics.windows_shed == 3
    assert [r.window_id for r in reqs if r.shed] == [0, 1, 2]
    assert [r.window_id for r in srv.queue] == [3, 4]
    srv.run()
    done = sess.drain()
    assert [r.window_id for r in done] == [3, 4]   # shed ones never serve
    assert all(not r.done for r in reqs[:3])
    # rows beyond the sub-window slot are dropped and counted at admission
    big = _mb(500, n=700)
    sess.push(big)
    assert srv.stream_diagnostics.admission_dropped_rows == 2 * (700 - 512)


def test_shedding_mid_queue_victim_across_tenants():
    """The shed victim is rarely the queue head in a multi-tenant queue;
    removal must be by identity (JoinRequest carries jnp arrays, so a
    value-equality removal would raise)."""
    spec = WindowSpec(size=1, slide=1, sub_rows=512)
    srv = StreamJoinServer(batch_slots=1, window_slots=1)
    sa = _session(srv, spec, name="A")
    sb = _session(srv, spec, name="B", seed=4)
    (a0,) = sa.push(_mb(600))
    (b0,) = sb.push(_mb(601))
    (b1,) = sb.push(_mb(602))      # sheds b0, which sits BEHIND a0
    assert b0.shed and not a0.shed and not b1.shed
    assert [(r.stream, r.window_id) for r in srv.queue] == [("A", 0),
                                                           ("B", 1)]
    srv.run()
    assert a0.done and b1.done and not b0.done


def test_retire_keeps_words_live_in_other_sessions():
    """Two same-geometry sessions over the SAME micro-batch stream share
    filter-cache entries ((fingerprint, num_blocks, seed) coincide); one
    session expiring a sub-window must not evict words the other still
    holds live — the other's slides must stay all-cache-hit."""
    batches = [_mb(700 + i) for i in range(4)]
    srv = StreamJoinServer(batch_slots=1)
    # same size -> same window capacity -> same num_blocks (shared entries);
    # A tumbles (expires everything at once), B slides one sub at a time
    sa = _session(srv, WindowSpec(3, 3, 512), name="A")
    sb = _session(srv, WindowSpec(3, 1, 512), name="B")
    for mb in batches[:3]:
        sb.push(mb)
        sa.push(mb)
        srv.run()
    d = srv.diagnostics.snapshot()
    # B's window 0 built each sub once; A's identical window was all hits
    assert d["filter_builds"] == 3 * 2 and d["filter_cache_hits"] == 3 * 2
    # A's tumble expired subs 0..2, but B still holds 1..2 live: only the
    # everywhere-dead sub 0 may be retired
    assert srv.stream_diagnostics.retired_filter_words == 2
    sb.push(batches[3])            # B slides: survivors 1..2 must still hit
    srv.run()
    after = srv.diagnostics.snapshot()
    assert after["filter_builds"] - d["filter_builds"] == 2
    assert after["filter_cache_hits"] - d["filter_cache_hits"] == 2 * 2


def test_fused_window_assembly_matches_reference():
    """The session's cached `wasm` executable must equal the reference
    assembly in core/window.py (guards drift between the two)."""
    spec = WindowSpec(size=3, slide=1, sub_rows=512)
    srv = StreamJoinServer(batch_slots=1)
    sess = _session(srv, spec)
    subs = [SubWindow(i, tuple(sess._admit_micro_batch(r)
                               for r in _mb(800 + i)), ("", ""))
            for i in range(spec.size)]
    got = sess._window_rels(subs)
    want = window_relations(subs, minimum=srv.mesh_k)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g.keys), np.asarray(w.keys))
        np.testing.assert_array_equal(np.asarray(g.values),
                                      np.asarray(w.values))
        np.testing.assert_array_equal(np.asarray(g.valid),
                                      np.asarray(w.valid))


def test_deadline_scheduling_under_backlog(rng):
    """When the queue backs up, latency-budget queries are served before
    error-budget ones (base-server policy the streaming admission uses)."""
    from conftest import make_pair
    from repro.core.cost import CostModel
    r1, r2 = make_pair(rng, n=1 << 11)
    srv = JoinServer(batch_slots=1, backlog_slots=0,
                     cost_model=CostModel(beta_compute=1e-7, epsilon=1e-3))
    errs = [srv.submit(JoinRequest(rels=[r1, r2],
                                   budget=QueryBudget(error=0.5),
                                   query_id=f"e{i}", seed=i, max_strata=MS,
                                   b_max=BM)) for i in range(3)]
    lat = srv.submit(JoinRequest(rels=[r1, r2],
                                 budget=QueryBudget(latency_s=0.25),
                                 query_id="lat", seed=7, max_strata=MS,
                                 b_max=BM))
    srv.step()
    assert lat.done and not any(e.done for e in errs)
    srv.run()
    assert all(e.done for e in errs)
    snap = srv.diagnostics.snapshot()
    assert snap["queue_latency_max_s"] >= snap["queue_latency_p95_s"] \
        >= snap["queue_latency_p50_s"] > 0


def _gate_backend(server, spec, cfg, **kw):
    """Adapter: one streaming session, one tumbling window per replication.
    Window 0 is pilot-allocated (fresh sigma) so it feeds the allocation
    check; later windows are sigma-fed and check coverage/bounds only."""
    state = {}

    def backend(mbs, w):
        if "sess" not in state:
            state["sess"] = server.open_stream(
                "gate", spec,
                budget=QueryBudget(error=0.5,
                                   pilot_fraction=cfg.pilot_fraction),
                max_strata=cfg.max_strata, b_max=cfg.b_max, seed=cfg.seed,
                **kw)
        sess = state["sess"]
        out = []
        for mb in mbs:
            out += sess.push(mb)
        server.run()
        (req,) = out
        assert req.done and req.window_id == w
        res = req.result
        return (float(res.estimate), float(res.error_bound),
                float(res.count), res.stats if w == 0 else None)

    return backend


def _stream_gate_cfg(**kw):
    return StreamGateConfig(**kw)


def test_stream_accuracy_gate_single_device():
    cfg = _stream_gate_cfg()
    spec = WindowSpec(size=cfg.window_size, slide=cfg.window_size,
                      sub_rows=cfg.rows_per_sub)
    srv = StreamJoinServer(batch_slots=1)
    rep = run_stream_accuracy_gate(_gate_backend(srv, spec, cfg), cfg)
    assert rep.passed, rep.summary()
    assert rep.checked_allocation
    assert srv.stream_diagnostics.windows_emitted == cfg.windows
    # steady-state streaming: everything after the first (compiling) window
    # reuses cached executables — the whole run compiles each stage once
    assert srv.diagnostics.cache_hits > srv.diagnostics.compiles


def test_stream_kernel_windows_match_jnp_within_gate_tolerance():
    """Kernel-mode streaming parity: two same-seed sessions over the SAME
    micro-batch stream — one through the batched Pallas path, one jnp —
    must agree per window well within the accuracy gate's tolerance (the
    shared hash math makes them bit-identical in practice), share the
    filter-word cache (bit-identical words), and stay zero-recompile after
    the first window in BOTH modes."""
    spec = WindowSpec(size=4, slide=1, sub_rows=512)
    srv = StreamJoinServer(batch_slots=2)
    sk = _session(srv, spec, name="kern", use_kernels=True)
    sj = _session(srv, spec, name="jnp")
    batches = [_mb(900 + i) for i in range(6)]
    done_k, done_j = [], []
    for i, mb in enumerate(batches):
        sk.push(mb)
        sj.push(mb)
        srv.run()
        if i == spec.size - 1:        # both modes fully compiled by now
            warm = srv.diagnostics.snapshot()
        done_k += sk.drain()
        done_j += sj.drain()
    assert len(done_k) == len(done_j) == 3
    for a, b in zip(done_k, done_j):
        assert float(a.result.estimate) == pytest.approx(
            float(b.result.estimate), rel=1e-6), a.window_id
        assert float(a.result.error_bound) == pytest.approx(
            float(b.result.error_bound), rel=1e-6), a.window_id
        assert float(a.result.count) == float(b.result.count), a.window_id
    after = srv.diagnostics.snapshot()
    assert after["compiles"] == warm["compiles"], "steady state recompiled"
    # same fingerprints + same filter_seed: the kernel session's words were
    # built once and the jnp session reused every one of them (or vice
    # versa) — one build per (sub-window, side) across BOTH sessions
    assert after["filter_builds"] == len(batches) * 2
    assert srv.diagnostics.kernel_gather_bytes == 0.0
    assert srv.diagnostics.kernel_queries == 3


def test_stream_accuracy_gate_kernels_single_device():
    """Acceptance: StreamJoinServer(use_kernels=True) windows pass the
    per-window statistical gate at mesh 1, interpret mode."""
    cfg = _stream_gate_cfg()
    spec = WindowSpec(size=cfg.window_size, slide=cfg.window_size,
                      sub_rows=cfg.rows_per_sub)
    srv = StreamJoinServer(batch_slots=1)
    rep = run_stream_accuracy_gate(
        _gate_backend(srv, spec, cfg, use_kernels=True), cfg)
    assert rep.passed, rep.summary()
    assert rep.checked_allocation
    assert srv.diagnostics.kernel_queries == cfg.windows
    assert srv.diagnostics.kernel_gather_bytes == 0.0
    assert srv.diagnostics.cache_hits > srv.diagnostics.compiles


def test_stream_gate_rejects_window_leak():
    """Harness self-test: a backend that leaks the previous window's tuples
    into the estimate must fail the per-window gate."""
    cfg = _stream_gate_cfg(windows=6)
    carry = {}

    def leaky(mbs, w):
        prev = carry.get("prev")
        carry["prev"] = mbs
        rels = [bucket_to_pow2(concatenate(
            [mb[side] for mb in mbs]
            + ([mb[side] for mb in prev] if prev else [])))
            for side in range(2)]
        truth = repartition_join(rels, expr="sum")
        return (float(truth.estimate), float(truth.estimate) * 0.01,
                float(truth.count), None)

    rep = run_stream_accuracy_gate(leaky, cfg)
    assert not rep.passed, rep.summary()


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from jax.sharding import Mesh
from accuracy import StreamGateConfig, run_stream_accuracy_gate
from repro.core.window import WindowSpec
from repro.runtime.stream_join import StreamJoinServer
from test_stream_join import _gate_backend

CFG = StreamGateConfig()
PSUM_CFG = StreamGateConfig(count_rtol=2e-2)

for d in (2, 4, 8):
    for mode, cfg in (("exact-parity", CFG), ("psum", PSUM_CFG)):
        mesh = Mesh(np.array(jax.devices()[:d]), ("data",))
        srv = StreamJoinServer(batch_slots=1, mesh=mesh, serve_mode=mode)
        spec = WindowSpec(cfg.window_size, cfg.window_size, cfg.rows_per_sub)
        rep = run_stream_accuracy_gate(_gate_backend(srv, spec, cfg), cfg)
        sess = srv.sessions["gate"]
        print(f"mesh{d} {mode}: {rep.summary()} "
              f"dropped={srv.diagnostics.dist_dropped_tuples} "
              f"overlap_ewma={sess.overlap_ewma:.3f}", flush=True)
        assert rep.passed, (d, mode, rep.summary())
        assert rep.checked_allocation
        if mode == "exact-parity":
            assert srv.diagnostics.dist_dropped_tuples == 0.0
        else:
            # the rolling overlap estimate actually drove the bucket plan
            assert sess.overlap_ewma is not None and sess.overlap_ewma < 1.0
print("STREAM-GATE-OK")
"""


@pytest.mark.slow
def test_stream_accuracy_gate_mesh_2_4_8():
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(["src", "tests"]))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "STREAM-GATE-OK" in out.stdout, out.stdout[-2000:]


def test_stream_gate_workload_truth_matches_reassembly():
    """The gate's micro-batch split must reassemble to exactly the window
    it computes truth for (guards the harness itself)."""
    cfg = _stream_gate_cfg(windows=1)
    mbs, (t_sum, t_cnt) = stream_window_workload(cfg, 0)
    rels = [bucket_to_pow2(concatenate([mb[side] for mb in mbs]))
            for side in range(2)]
    truth = repartition_join(rels, expr="sum")
    assert float(truth.estimate) == pytest.approx(t_sum, rel=1e-6)
    assert float(truth.count) == t_cnt
