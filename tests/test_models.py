"""Per-arch smoke tests (assignment requirement: reduced config, one
forward/train step on CPU, shape + finiteness asserts) plus decode parity
and layer-level properties."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCHS, Model
from repro.models.model import CLIP_DIM
from repro.runtime.train import make_train_step, train_state_init

ALL_ARCHS = list(ARCHS)


def _batch(cfg, B=2, T=32, key=0):
    rng = np.random.default_rng(key)
    toks = rng.integers(0, cfg.vocab, (B, T + 1))
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "targets": jnp.asarray(toks[:, 1:], jnp.int32)}
    if cfg.num_img_tokens:
        batch["img_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_img_tokens, CLIP_DIM)), jnp.float32)
    if cfg.is_encdec:
        e = cfg.encoder
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, e.n_frames, e.d_input)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced same-family config: forward shapes + one train step, no NaN."""
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    B, T = 2, 32
    batch = _batch(cfg, B, T)
    state = train_state_init(model, jax.random.key(0))
    logits, _ = model.forward(state.params, batch)
    assert logits.shape == (B, T, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    step = make_train_step(model, total_steps=10, warmup=2)
    state2, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 48
    if cfg.is_encdec:
        e = cfg.encoder
        frames = jnp.zeros((B, e.n_frames, e.d_input), jnp.float32)
        cache = model.init_cache(params, B, S, frames)
    else:
        cache = model.init_cache(None, B, S)
    toks = jnp.asarray([1, 2], jnp.int32)
    logits, cache = model.decode_step(params, toks, cache)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "falcon-mamba-7b",
                                  "recurrentgemma-2b"])
def test_decode_matches_forward(arch):
    """Step-by-step decode reproduces the teacher-forced last-position
    logits (strict for attention; small scan-order tolerance for SSM)."""
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, T = 2, 24
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab)
    logits_train, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(None, B, T)
    step = jax.jit(model.decode_step)
    for t in range(T):
        logits_dec, cache = step(params, toks[:, t], cache)
    scale = float(jnp.abs(logits_train[:, -1]).max())
    diff = float(jnp.abs(logits_train[:, -1] - logits_dec).max())
    assert diff / scale < 0.08, diff / scale


def test_moe_decode_lossless_capacity():
    """With train-mode capacity drops disabled, MoE decode is bit-exact."""
    cfg = ARCHS["qwen2-moe-a2.7b"].reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, T = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab)
    logits_train, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(None, B, T)
    for t in range(T):
        logits_dec, cache = model.decode_step(params, toks[:, t], cache)
    np.testing.assert_allclose(np.asarray(logits_train[:, -1]),
                               np.asarray(logits_dec), atol=1e-3)


def test_local_attention_equals_global_when_window_covers():
    """A local layer with window >= seq is exactly causal attention."""
    from repro.models import layers as L
    cfg = ARCHS["qwen3-1.7b"].reduced(window=1024)
    p = L.init_attention(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          L.COMPUTE_DTYPE)
    a = L.attention_train(p, x, cfg, kind="causal")
    b = L.attention_train(p, x, cfg, kind="local")
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-4)


def test_local_ring_buffer_consistency():
    """Decode with ring cache == decode with full cache inside the window."""
    cfg = ARCHS["gemma2-9b"].reduced(window=8)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, T = 1, 20
    toks = jax.random.randint(jax.random.key(2), (B, T), 0, cfg.vocab)
    # ground truth: teacher-forced forward (local masking in train mode)
    logits_train, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(None, B, T)
    for t in range(T):
        logits_dec, cache = model.decode_step(params, toks[:, t], cache)
    scale = float(jnp.abs(logits_train[:, -1]).max())
    diff = float(jnp.abs(logits_train[:, -1] - logits_dec).max())
    assert diff / scale < 0.08, diff / scale


def test_mamba_chunked_scan_matches_unchunked():
    from repro.models import ssm as S
    cfg = ARCHS["falcon-mamba-7b"].reduced()
    p = S.init_mamba(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 512, cfg.d_model),
                          jnp.float32)  # 512 = 2 chunks of 256
    y_chunked = S.mamba_train(p, x, cfg)
    # force single chunk by monkeypatching chunk size
    old = S.SCAN_CHUNK
    try:
        S.SCAN_CHUNK = 512
        y_whole = S.mamba_train(p, x, cfg)
    finally:
        S.SCAN_CHUNK = old
    np.testing.assert_allclose(np.asarray(y_chunked, np.float32),
                               np.asarray(y_whole, np.float32),
                               atol=2e-2, rtol=1e-2)


def test_vlm_image_prefix_changes_logits():
    cfg = ARCHS["phi-3-vision-4.2b"].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b1 = _batch(cfg, key=1)
    b2 = {**b1, "img_embeds": b1["img_embeds"] + 1.0}
    l1, _ = model.forward(params, b1)
    l2, _ = model.forward(params, b2)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3


def test_whisper_encoder_states_feed_decoder():
    cfg = ARCHS["whisper-small"].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b1 = _batch(cfg, key=1)
    b2 = {**b1, "frames": b1["frames"] + 1.0}
    l1, _ = model.forward(params, b1)
    l2, _ = model.forward(params, b2)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3


def test_gradients_flow_everywhere():
    """Every parameter leaf of a hybrid arch receives nonzero gradient."""
    cfg = ARCHS["recurrentgemma-2b"].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, key=3)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    zero_leaves = [jax.tree_util.keystr(path)
                   for path, g in
                   jax.tree_util.tree_flatten_with_path(grads)[0]
                   if float(jnp.abs(g).max()) == 0.0]
    assert zero_leaves == [], zero_leaves


def test_chunked_attention_matches_dense():
    """Flash-style KV-chunked softmax == dense attention (bf16 tolerance),
    for every mask kind and with gemma2's softcap."""
    from repro.models import layers as L
    for arch, kind in [("qwen3-1.7b", "causal"), ("qwen3-1.7b", "local"),
                       ("qwen3-1.7b", "full"), ("gemma2-9b", "local")]:
        cfg = ARCHS[arch].reduced()
        cfgc = dataclasses.replace(cfg, attn_chunk=16)
        p = L.init_attention(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model),
                              L.COMPUTE_DTYPE)
        a = jnp.asarray(L.attention_train(p, x, cfg, kind=kind), jnp.float32)
        b = jnp.asarray(L.attention_train(p, x, cfgc, kind=kind),
                        jnp.float32)
        rel = float(jnp.abs(a - b).max()) / float(jnp.abs(a).max())
        assert rel < 1e-2, (arch, kind, rel)


def test_chunked_attention_gradients():
    cfg = dataclasses.replace(ARCHS["qwen3-1.7b"].reduced(), attn_chunk=16)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, B=2, T=64)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    assert sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads)) > 0
