"""Cross-mesh parity suite for the distributed JoinServer.

The contract under test: a JoinServer constructed with a mesh of ANY size
produces results bit-identical to (a) the single-device JoinServer and
(b) direct ``distributed_approx_join`` calls, under the same seed — the
shuffle routes every key to one device, received rows arrive in original
row order, per-stratum statistics are computed by the owning device and
merged back into the canonical [S] slot layout, so every float is the same.

Runs in a SUBPROCESS with --xla_force_host_platform_device_count=8 so the
rest of the suite keeps the real single-device backend.  Mesh sizes 1/2/4
use device subsets of the 8 placeholder devices.
"""

import os
import subprocess
import sys

import pytest

from repro.core.budget import QueryBudget
from repro.runtime.join_serve import JoinRequest, ShapeClass, shape_class_of

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from jax.sharding import Mesh
from repro.core.budget import QueryBudget
from repro.core.distributed import distributed_approx_join
from repro.core.relation import relation
from repro.runtime.join_serve import JoinRequest, JoinServer

MS, BM = 1024, 512
rng = np.random.default_rng(0)
n = 1 << 12
r1 = relation(rng.integers(0, 500, n).astype(np.uint32),
              rng.normal(10, 2, n).astype(np.float32))
r2 = relation(rng.integers(400, 900, n).astype(np.uint32),
              rng.normal(5, 1, n).astype(np.float32))


def req(qid, seed, budget=None):
    return JoinRequest(dataset="ds", budget=budget or QueryBudget(error=0.5),
                       query_id=qid, seed=seed, max_strata=MS, b_max=BM)


def surface(q):
    r = q.result
    return (float(r.estimate), float(r.error_bound), float(r.count),
            float(r.dof))


def serve(server):
    qs = [server.submit(req("tA", 5)),                   # pilot round
          server.submit(req("tB", 6)),
          server.submit(req("tC", 7, QueryBudget())),    # exact path
          server.submit(req("tA", 8))]                   # sigma round 2
    server.run()
    return [surface(q) for q in qs], qs


ref_srv = JoinServer(batch_slots=2)
ref_srv.register_dataset("ds", [r1, r2])
ref, ref_qs = serve(ref_srv)

# --- direct distributed_approx_join references (same seeds) ---------------
for d in (1, 2, 4, 8):
    mesh = Mesh(np.array(jax.devices()[:d]), ("data",))
    dist = distributed_approx_join(mesh, [r1, r2], mode="exact",
                                   max_strata=MS, seed=7)
    assert float(dist.estimate) == ref[2][0], (d, "exact estimate")
    assert float(dist.count) == ref[2][2], (d, "exact count")
    samp = distributed_approx_join(mesh, [r1, r2], mode="sample",
                                   sample_fraction=0.1, b_max=BM,
                                   max_strata=MS, seed=5)
    assert (float(samp.estimate), float(samp.error_bound),
            float(samp.count), float(samp.dof)) == ref[0], (d, "sampled")
print("DIRECT-PARITY-OK")

# --- mesh servers: bit-identical results + sigma feedback ------------------
for d in (1, 2, 4, 8):
    mesh = Mesh(np.array(jax.devices()[:d]), ("data",))
    srv = JoinServer(batch_slots=2, mesh=mesh)
    srv.register_dataset("ds", [r1, r2])
    got, qs = serve(srv)
    assert got == ref, (d, got, ref)
    assert srv.sigma.table == ref_srv.sigma.table, d
    # diagnostics surfaces survive the distributed path
    q = qs[0]
    np.testing.assert_array_equal(
        np.asarray(q.result.diagnostics.live_counts),
        np.asarray(ref_qs[0].result.diagnostics.live_counts))
    np.testing.assert_array_equal(np.asarray(q.result.strata.keys),
                                  np.asarray(ref_qs[0].result.strata.keys))
    d8 = srv.diagnostics
    assert d8.per_device_shuffled_bytes.shape == (d,)
    if d > 1:
        assert d8.dist_shuffled_tuple_bytes > 0
        assert all(b > 0 for b in d8.per_device_shuffled_bytes)
print("SERVER-PARITY-OK")

# --- mesh-keyed shape classes: warm then zero recompiles -------------------
mesh = Mesh(np.array(jax.devices()), ("data",))
srv = JoinServer(batch_slots=2, mesh=mesh)
srv.register_dataset("ds", [r1, r2])
for q in range(2):   # warmup covers (fbuild, prepare, sample, exact) x B
    srv.submit(req(f"w{q}", 11))
    srv.submit(req(f"we{q}", 11, QueryBudget()))
srv.run()
warm = srv.diagnostics.snapshot()
assert warm["compiles"] >= 4, warm
for q in range(4):
    srv.submit(req(f"m{q}", 11))
    srv.submit(req(f"me{q}", 11, QueryBudget()))
srv.run()
after = srv.diagnostics.snapshot()
assert after["compiles"] == warm["compiles"], (warm, after)
assert after["cache_hits"] > warm["cache_hits"]
# dataset filter words were built once per relation for seed 11 and reused
assert after["filter_builds"] == warm["filter_builds"]
assert after["filter_cache_hits"] > warm["filter_cache_hits"]
print("CACHE-OK")

# --- kernel route on mesh servers: the single-device Pallas path gathers
# --- sharded rows to the host (metered, zero at mesh 1), results identical
from repro.core.join import approx_join

kref = approx_join([r1, r2], QueryBudget(error=0.5), max_strata=MS,
                   b_max=BM, seed=21, use_kernels=True)
for d in (1, 2, 8):
    mesh = Mesh(np.array(jax.devices()[:d]), ("data",))
    srv = JoinServer(batch_slots=2, mesh=mesh)
    srv.register_dataset("ds", [r1, r2])
    q = srv.submit(JoinRequest(dataset="ds", budget=QueryBudget(error=0.5),
                               query_id="k0", seed=21, max_strata=MS,
                               b_max=BM, use_kernels=True))
    srv.run()
    assert surface(q) == (float(kref.estimate), float(kref.error_bound),
                          float(kref.count), float(kref.dof)), d
    assert srv.diagnostics.kernel_queries == 1, d
    if d == 1:
        assert srv.diagnostics.kernel_gather_bytes == 0.0, d
    else:
        assert srv.diagnostics.kernel_gather_bytes > 0, d
    if d == 2:
        bytes_one = srv.diagnostics.kernel_gather_bytes

# gathers are memoized per distinct array within a step: a 2-slot batch of
# the SAME dataset (shared rows + shared filter words) moves exactly the
# bytes one query does
srv = JoinServer(batch_slots=2,
                 mesh=Mesh(np.array(jax.devices()[:2]), ("data",)))
srv.register_dataset("ds", [r1, r2])
for i in (0, 1):
    srv.submit(JoinRequest(dataset="ds", budget=QueryBudget(error=0.5),
                           query_id=f"k{i}", seed=21 + i, filter_seed=21,
                           max_strata=MS, b_max=BM, use_kernels=True))
assert srv.step() == 2
assert srv.diagnostics.kernel_gather_bytes == bytes_one, \
    (srv.diagnostics.kernel_gather_bytes, bytes_one)
print("KERNEL-MESH-OK")
"""


@pytest.mark.slow
def test_distributed_server_parity_1_2_4_8():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    for marker in ("DIRECT-PARITY-OK", "SERVER-PARITY-OK", "CACHE-OK",
                   "KERNEL-MESH-OK"):
        assert marker in out.stdout, (marker, out.stdout[-2000:])


def _mesh1_server(**kw):
    import jax
    import numpy as np
    from jax.sharding import Mesh
    from repro.runtime.join_serve import JoinServer
    return JoinServer(mesh=Mesh(np.array(jax.devices()[:1]), ("data",)),
                      **kw)


def test_serve_mode_cache_isolation(rng):
    """psum and exact-parity entries never collide in the executable cache:
    switching modes compiles fresh programs once, then each mode hits its
    own entries — no recompiles of the other mode's executables.  At mesh
    size 1 both modes run the same arithmetic, so results must agree."""
    from conftest import make_pair
    from repro.core.budget import QueryBudget
    from repro.runtime.join_serve import JoinRequest

    r1, r2 = make_pair(rng, n=1 << 11)
    srv = _mesh1_server(batch_slots=2)
    srv.register_dataset("ds", [r1, r2])

    def submit(mode, seed):
        return srv.submit(JoinRequest(
            dataset="ds", budget=QueryBudget(error=0.5), query_id=f"{mode}",
            seed=seed, max_strata=512, b_max=128, serve_mode=mode))

    q_par = submit("exact-parity", 7)
    srv.run()
    c_parity = srv.diagnostics.compiles
    q_psum = submit("psum", 7)
    srv.run()
    c_both = srv.diagnostics.compiles
    assert c_both > c_parity                  # psum compiled its own stages
    assert q_par._class != q_psum._class
    assert q_par._class._replace(
        serve_mode="psum", bucket_cap=q_psum._class.bucket_cap) \
        == q_psum._class                      # the ONLY key difference
    # alternate modes (same batch bucket): zero further compiles either way
    for seed in (8, 9):
        submit("exact-parity", seed)
        srv.run()
        submit("psum", seed)
        srv.run()
    assert srv.diagnostics.compiles == c_both
    assert srv.diagnostics.cache_hits > 0
    # one device: the psum merge degenerates to the canonical arithmetic
    assert float(q_psum.result.estimate) == float(q_par.result.estimate)
    assert float(q_psum.result.error_bound) == float(q_par.result.error_bound)


def test_meshless_server_normalizes_serve_mode(rng):
    """Off-mesh there is one pipeline (the exact one): psum requests fold
    into the exact-parity shape class instead of forking the cache."""
    from conftest import make_pair
    from repro.core.budget import QueryBudget
    from repro.runtime.join_serve import JoinRequest, JoinServer

    r1, r2 = make_pair(rng, n=1 << 10)
    srv = JoinServer(batch_slots=2)
    q = srv.submit(JoinRequest(rels=[r1, r2], budget=QueryBudget(error=0.5),
                               query_id="t", seed=1, max_strata=256,
                               b_max=128, serve_mode="psum"))
    assert q._class.serve_mode == "exact-parity"
    assert q._class.bucket_cap == 0
    with pytest.raises(ValueError):
        srv.submit(JoinRequest(rels=[r1, r2], budget=QueryBudget(),
                               query_id="t", max_strata=256, b_max=128,
                               serve_mode="gossip"))


def test_forced_bucket_overflow_is_counted(rng):
    """An under-provisioned bucket plan must COUNT what it drops — in the
    server totals, per device, and on the per-query result diagnostics —
    and the count estimate shrinks accordingly (never silently)."""
    from conftest import make_pair
    from repro.core.budget import QueryBudget
    from repro.runtime.join_serve import JoinRequest

    r1, r2 = make_pair(rng, n=1 << 11)
    srv = _mesh1_server(batch_slots=1, serve_mode="psum", bucket_cap=64)
    srv.register_dataset("ds", [r1, r2])
    lossless = _mesh1_server(batch_slots=1, serve_mode="psum")
    lossless.register_dataset("ds", [r1, r2])

    def ask(server):
        q = server.submit(JoinRequest(dataset="ds", budget=QueryBudget(),
                                      query_id="t", seed=3, max_strata=2048,
                                      b_max=128))
        server.run()
        return q

    q_tight, q_free = ask(srv), ask(lossless)
    d = srv.diagnostics
    assert d.dist_dropped_tuples > 0
    assert d.per_device_dropped_tuples.sum() == d.dist_dropped_tuples
    assert float(q_tight.result.diagnostics.dist_dropped_tuples) \
        == d.dist_dropped_tuples
    assert lossless.diagnostics.dist_dropped_tuples == 0
    assert float(q_free.result.diagnostics.dist_dropped_tuples) == 0.0
    assert float(q_tight.result.count) < float(q_free.result.count)


def test_shape_class_keys_on_mesh_shape(rng):
    """Same query admitted on different mesh shapes lands in different
    executable-cache classes (no cross-mesh executable collisions)."""
    from conftest import make_pair
    r1, r2 = make_pair(rng, n=1 << 10)
    req = JoinRequest(rels=[r1, r2], budget=QueryBudget(error=0.5),
                      max_strata=512, b_max=128)
    single = shape_class_of(req)
    mesh8 = shape_class_of(req, (("data", 8),))
    mesh2x4 = shape_class_of(req, (("pod", 2), ("data", 4)))
    assert single.mesh == ()
    assert len({single, mesh8, mesh2x4}) == 3
    assert isinstance(single, ShapeClass)
    # everything but the mesh key is identical
    assert single._replace(mesh=(("data", 8),)) == mesh8
