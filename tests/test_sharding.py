"""Sharding rules: divisibility-aware spec construction and the logical-axes
trees for parameters and caches (single-device safe — no mesh needed beyond
a trivial one)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import ARCHS, Model
from repro.sharding.axes import cache_axes, param_axes
from repro.sharding.specs import DEFAULT_RULES, spec_for


class FakeMesh:
    """Duck-typed mesh with just .shape (spec_for only reads sizes)."""

    def __init__(self, **shape):
        self.shape = shape


def test_spec_divisibility_fallback():
    mesh = FakeMesh(data=16, model=16)
    # divisible dims shard
    assert spec_for(("ff",), (4864,), mesh, DEFAULT_RULES) == P("model")
    # indivisible dims replicate instead of failing
    assert spec_for(("ff",), (4863,), mesh, DEFAULT_RULES) == P(None)
    # vocab 51865 (whisper) is odd -> replicated
    assert spec_for(("vocab", "embed"), (51865, 768), mesh,
                    DEFAULT_RULES) == P(None, None)
    # vocab 151936 divides 16 -> sharded
    assert spec_for(("vocab", "embed"), (151936, 896), mesh,
                    DEFAULT_RULES) == P("model", None)


def test_spec_tuple_axes_and_missing_axes():
    mesh = FakeMesh(pod=2, data=16, model=16)
    assert spec_for(("batch", None), (256, 128), mesh, DEFAULT_RULES) \
        == P(("pod", "data"), None)
    # batch=1 (long_500k) cannot shard over 32 -> replicated
    assert spec_for(("batch", None), (1, 128), mesh, DEFAULT_RULES) \
        == P(None, None)
    # single-pod mesh: 'pod' axis dropped from the tuple
    mesh2 = FakeMesh(data=16, model=16)
    assert spec_for(("batch",), (256,), mesh2, DEFAULT_RULES) == P(("data",))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "moonshot-v1-16b-a3b",
                                  "falcon-mamba-7b", "recurrentgemma-2b",
                                  "whisper-small"])
def test_param_axes_cover_every_leaf(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    axes = param_axes(shapes)
    flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_a = jax.tree.leaves(axes, is_leaf=_is_axes_leaf)
    assert len(flat_s) == len(flat_a)
    for (path, leaf), ax in zip(flat_s, flat_a):
        assert len(ax) == leaf.ndim, (jax.tree_util.keystr(path), ax,
                                      leaf.shape)


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(
        isinstance(n, (str, type(None))) for n in x)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "falcon-mamba-7b",
                                  "recurrentgemma-2b"])
def test_cache_axes_cover_every_leaf(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    shapes = model.cache_shape(batch=2, max_seq=32)
    axes = cache_axes(shapes)
    flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_a = jax.tree.leaves(axes, is_leaf=_is_axes_leaf)
    assert len(flat_s) == len(flat_a)
    for (path, leaf), ax in zip(flat_s, flat_a):
        assert len(ax) == leaf.ndim, (jax.tree_util.keystr(path), ax,
                                      leaf.shape)


def test_expert_weights_marked_for_ep():
    cfg = ARCHS["qwen2-moe-a2.7b"].reduced()
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    axes = param_axes(shapes)
    found = []

    def visit(path, ax):
        names = [str(getattr(p, "key", "")) for p in path]
        if any(n.startswith("ff_") for n in names) and "wg" in names \
                and "shared" not in names:
            found.append(ax)

    jax.tree_util.tree_map_with_path(visit, axes, is_leaf=_is_axes_leaf)
    assert found and all(ax[-3] == "expert" for ax in found), found
