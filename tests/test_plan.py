"""The query-plan compiler and the engine's multi-way serve paths.

Owned by the async-serving CI leg (8 forced host devices, so the mesh
parity/gate cases run).  Covers:

* plan IR validation, flattening, and the compiled-plan cache;
* the ``intersect_all`` typed validation + prebuilt-words shape asserts;
* the §3.1 ``(n + 1)`` filter-exchange formula at n = 2/3/4;
* the strata-grid sizing regression (defaults must size from the LARGEST
  input — previously from ``rels[0]``, driver and server both);
* 3-way and 4-way joins through the batched server, a mixed 2-way/3-way
  submission (shape classes must not collide), the async tier, and a
  2-device mesh (bit-parity with the meshless engine);
* plan bit-parity with composed direct ``approx_join`` calls, plan
  survival across ``snapshot_state``/``restore_state``, and the
  statistical accuracy gate for a 3-way plan at mesh 1 (in-process) and
  mesh 2/4/8 in both serve modes (slow subprocess).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from accuracy import GateConfig, run_accuracy_gate
from repro.core import bloom
from repro.core.budget import QueryBudget
from repro.core.join import (TUPLE_BYTES, approx_join, filter_exchange_bytes,
                             prepare_stage_pre)
from repro.core.plan import Plan, PlanNode, compile_plan, node_bytes_model
from repro.core.relation import relation
from repro.data.synthetic import overlapping_relations
from repro.runtime.join_serve import JoinRequest, JoinServer

ERR = QueryBudget(error=0.05)


def _rels(n, rows=1 << 10, seed=3, overlap=0.25):
    return overlapping_relations([rows] * n, overlap, seed=seed)


def _identical(a, b) -> bool:
    """Bitwise equality of two JoinResults (scalars + strata grid)."""
    if a.strata.keys.shape != b.strata.keys.shape:
        return False
    return all(bool(jnp.all(getattr(a, f) == getattr(b, f)))
               for f in ("estimate", "error_bound", "count", "dof")) \
        and bool(jnp.all(a.strata.keys == b.strata.keys))


# -- plan IR ----------------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError, match="at least one node"):
        Plan(())
    with pytest.raises(ValueError, match="duplicate"):
        Plan((PlanNode("x", ("a", "b")), PlanNode("x", ("a", "b"))))
    with pytest.raises(ValueError, match="references itself"):
        Plan((PlanNode("x", ("x", "a")),))
    with pytest.raises(ValueError, match="no inputs"):
        PlanNode("x", ())
    with pytest.raises(ValueError, match="reserved"):
        PlanNode("a/b", ("a", "b"))


def test_compile_rejects_unknown_and_degenerate():
    a, b = _rels(2)
    datasets = {"a": [a], "b": [b]}
    with pytest.raises(ValueError, match="neither an earlier plan node"):
        compile_plan(Plan((PlanNode("x", ("a", "nope")),)), datasets)
    # forward references read as (unknown) dataset names: order = topo order
    with pytest.raises(ValueError, match="neither an earlier plan node"):
        compile_plan(Plan((PlanNode("x", ("a", "y")),
                           PlanNode("y", ("a", "b")))), datasets)
    with pytest.raises(ValueError, match="at least two"):
        compile_plan(Plan((PlanNode("x", ("a",)),)), datasets)


def test_plan_flattening_fuses_leaf_sets():
    plan = Plan((PlanNode("ab", ("a", "b")),
                 PlanNode("abc", ("ab", "c")),
                 PlanNode("deep", ("abc", "ab", "d"))))
    assert plan.leaf_inputs("ab") == ("a", "b")
    assert plan.leaf_inputs("abc") == ("a", "b", "c")
    # recursive expansion, order-preserving dedupe
    assert plan.leaf_inputs("deep") == ("a", "b", "c", "d")


def test_compile_expands_multi_relation_datasets():
    a, b, c = _rels(3)
    compiled = compile_plan(Plan((PlanNode("j", ("pair", "c")),)),
                            {"pair": [a, b], "c": [c]})
    assert compiled.nodes[0].n_rels == 3
    assert compiled.bytes_model["j"]["n"] == 3


# -- bloom intersect validation ---------------------------------------------

def test_intersect_all_typed_validation():
    r1, r2 = _rels(2, rows=256)
    f1 = bloom.build(r1.keys, r1.valid, 8, seed=0)
    f2 = bloom.build(r2.keys, r2.valid, 8, seed=0)
    with pytest.raises(ValueError, match="at least one filter"):
        bloom.intersect_all([])
    with pytest.raises(ValueError, match="num_blocks mismatch"):
        bloom.intersect_all([f1, bloom.build(r2.keys, r2.valid, 16, seed=0)])
    with pytest.raises(ValueError, match="seed"):
        bloom.intersect_all([f1, bloom.build(r2.keys, r2.valid, 8, seed=9)])
    merged = bloom.intersect_all([f1, f2])
    assert bool(jnp.all(merged.words == (f1.words & f2.words)))
    assert bloom.intersect_all([f1]) is not None


def test_prepare_pre_asserts_shape_agreement():
    rels = _rels(3, rows=256)
    nb = bloom.num_blocks_for(256, 0.01)
    words = jnp.stack([bloom.build(r.keys, r.valid, nb, 0).words
                       for r in rels[:2]])
    with pytest.raises(ValueError, match="2 prebuilt filters for 3 inputs"):
        prepare_stage_pre(rels, words, 256, 0)


# -- §3.1 filter-exchange formula -------------------------------------------

@pytest.mark.parametrize("n", [2, 3, 4])
def test_filter_exchange_bytes_nway(n):
    """Diagnostics must charge live tuples + the (n + 1) filter transfers of
    §3.1 (n per-dataset filters to the merge site + one broadcast back)."""
    res = approx_join(_rels(n, rows=512), ERR, seed=2)
    d = res.diagnostics
    expect = (int(jnp.sum(d.live_counts)) * TUPLE_BYTES
              + d.filter_bytes * (n + 1))
    assert int(d.shuffled_bytes_filtered) == expect
    assert int(filter_exchange_bytes(n, d.filter_bytes)) \
        == d.filter_bytes * (n + 1)


# -- strata-grid sizing regression (rels[0] -> max) -------------------------

def _asymmetric():
    rng = np.random.default_rng(0)
    small = relation(np.arange(512, dtype=np.uint32),
                     rng.poisson(10, 512).astype(np.float32))
    big = relation(rng.integers(0, 3000, 4096).astype(np.uint32),
                   rng.poisson(10, 4096).astype(np.float32))
    return small, big


def test_strata_grid_sized_from_largest_input_driver():
    """Regression: the default strata grid must equal sizing from the
    LARGEST input — the old ``rels[0].capacity`` default built a 512-row
    grid here (the big side's 4096 capacity ignored), so the result was not
    bit-identical to the explicitly max-sized call."""
    small, big = _asymmetric()
    default = approx_join([small, big], ERR, seed=1)
    explicit = approx_join([small, big], ERR, seed=1, max_strata=4096)
    assert default.strata.keys.shape == explicit.strata.keys.shape
    assert _identical(default, explicit)
    assert int(default.diagnostics.strata_overflow) == 0


def test_strata_grid_sized_from_largest_input_server():
    """Server counterpart: a default-sized request must resolve
    ``max_strata`` to the largest input's (bucketed) capacity and serve
    bit-identically to the explicitly max-sized driver call."""
    small, big = _asymmetric()
    srv = JoinServer(batch_slots=2)
    req = srv.submit(JoinRequest(rels=[small, big], budget=ERR, seed=1))
    srv.run()
    assert req.max_strata == 4096       # old code: rels[0].capacity == 512
    explicit = approx_join([small, big], ERR, seed=1, max_strata=4096)
    assert _identical(req.result, explicit)


# -- n-way joins through the server -----------------------------------------

@pytest.mark.parametrize("n", [3, 4])
def test_nway_served_bit_identical(n):
    rels = _rels(n)
    srv = JoinServer(batch_slots=4)
    req = srv.submit(JoinRequest(rels=rels, budget=ERR, seed=5,
                                 query_id=f"q{n}"))
    srv.run()
    direct = approx_join(rels, ERR, seed=5, query_id=f"q{n}",
                         max_strata=req.max_strata)
    assert _identical(req.result, direct)


def test_mixed_two_and_three_way_batch():
    """2-way and 3-way queries submitted together must serve in separate
    shape classes (one step each), each bit-identical to its direct call —
    a shape-class collision would fuse mismatched stage programs."""
    rels3 = _rels(3)
    srv = JoinServer(batch_slots=4)
    reqs2 = [srv.submit(JoinRequest(rels=rels3[:2], budget=ERR, seed=s,
                                    query_id=f"two{s}")) for s in (1, 2)]
    reqs3 = [srv.submit(JoinRequest(rels=rels3, budget=ERR, seed=s,
                                    query_id=f"three{s}")) for s in (1, 2)]
    assert reqs2[0]._class != reqs3[0]._class
    steps0 = srv.diagnostics.steps
    srv.run()
    assert srv.diagnostics.steps - steps0 == 2
    for req, n in [(r, 2) for r in reqs2] + [(r, 3) for r in reqs3]:
        direct = approx_join(rels3[:n], ERR, seed=req.seed,
                             query_id=req.query_id,
                             max_strata=req.max_strata)
        assert _identical(req.result, direct), req.query_id


# -- plans through the engine -----------------------------------------------

def _abc_server(**kw):
    srv = JoinServer(batch_slots=4, **kw)
    for name, r in zip("abcd", _rels(4)):
        srv.register_dataset(name, [r])
    return srv


_PLAN = Plan((PlanNode("ab", ("a", "b"), budget=ERR),
              PlanNode("abc", ("ab", "c"), budget=ERR)))


def _assert_plan_parity(srv, results, seed, query_id="p0"):
    """Every node must be bit-identical to the composed direct call over
    its flattened leaf relations (same seed, same query id)."""
    for name, leaves in (("ab", ("a", "b")), ("abc", ("a", "b", "c"))):
        direct_rels = [r for d in leaves for r in srv.datasets[d]]
        direct = approx_join(direct_rels, ERR, seed=seed,
                             query_id=f"{query_id}/{name}",
                             max_strata=max(r.capacity for r in direct_rels))
        assert _identical(results[name], direct), name


def test_plan_served_bit_identical_to_composed_calls():
    srv = _abc_server()
    handle = srv.submit_plan(_PLAN, query_id="p0", seed=7)
    assert set(handle.requests) == {"ab", "abc"}
    assert "p0" in srv.plans
    srv.run()
    assert handle.done
    assert "p0" not in srv.plans        # completed handles are dropped
    _assert_plan_parity(srv, handle.results(), seed=7)


def test_plan_cache_and_zero_recompiles():
    srv = _abc_server()
    h1 = srv.submit_plan(_PLAN, query_id="p1", seed=1)
    srv.run()
    assert srv.diagnostics.plan_compiles == 1
    compiles = srv.diagnostics.compiles
    h2 = srv.submit_plan(_PLAN, query_id="p2", seed=2)
    srv.run()
    assert srv.diagnostics.plan_cache_hits == 1
    assert srv.diagnostics.compiles == compiles   # warm executables reused
    assert h1.results().keys() == h2.results().keys()


def test_plan_pushdown_model_beats_binary_tree():
    """The compiled byte model: fusing to one n-way stage with the full
    cascaded intersection pushed down must beat the left-deep binary tree
    (which ships intermediates and can only 2-way filter)."""
    compiled = _abc_server().compile_plan(_PLAN)
    m2, m3 = compiled.bytes_model["ab"], compiled.bytes_model["abc"]
    assert m2["reduction_x"] == 1.0               # 2-way: same plan either way
    assert m3["bytes_pushdown"] < m3["bytes_binary"]
    assert m3["reduction_x"] > 1.0
    assert 0.0 < m3["overlap"] <= 1.0


def test_plan_survives_snapshot_restore():
    """A failover never drops an in-flight plan: snapshot with the plan
    queued, restore into a fresh engine, serve there — handle regrouped,
    results bit-identical to the original engine's."""
    src = _abc_server()
    h_src = src.submit_plan(_PLAN, query_id="pf", seed=9)
    flat, meta = src.snapshot_state()

    dst = JoinServer(batch_slots=4)
    restored = dst.restore_state(flat, meta)
    assert len(restored) == 2
    assert "pf" in dst.plans
    h_dst = dst.plans["pf"]
    assert set(h_dst.requests) == {"ab", "abc"}
    dst.run()
    assert h_dst.done and "pf" not in dst.plans
    src.run()
    for name in ("ab", "abc"):
        assert _identical(h_dst.results()[name], h_src.results()[name]), name
    _assert_plan_parity(dst, h_dst.results(), seed=9, query_id="pf")


def test_plan_async_served_bit_identical():
    from repro.runtime.async_serve import AsyncJoinServer
    inner = _abc_server()
    with AsyncJoinServer(inner) as asrv:
        futs = asrv.submit_plan(_PLAN, query_id="ap", seed=11)
        results = {name: f.result(timeout=120).result
                   for name, f in futs.items()}
    _assert_plan_parity(inner, results, seed=11, query_id="ap")


def test_plan_front_door_routes_plan_whole():
    from repro.runtime.async_serve import AsyncJoinFrontDoor
    rels = _rels(3)
    with AsyncJoinFrontDoor(replicas=2) as door:
        for name, r in zip("abc", rels):
            door.register_dataset(name, [r])
        futs = door.submit_plan(_PLAN, query_id="fd", seed=4)
        served = {name: f.result(timeout=120) for name, f in futs.items()}
        # one tenant -> one replica: the whole plan landed on one engine
        owners = [rep for rep in door.replicas
                  if rep.engine.diagnostics.queries > 0]
        assert len(owners) == 1
    assert all(r.done and r.result is not None for r in served.values())
    direct = approx_join([r for r in rels], ERR, seed=4, query_id="fd/abc",
                         max_strata=max(r.capacity for r in rels))
    assert _identical(served["abc"].result, direct)


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
def test_plan_mesh2_bit_identical_to_meshless():
    """A 3-way plan on a 2-device mesh (exact-parity merge) reproduces the
    meshless engine float-for-float, node by node."""
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    srv_mesh = JoinServer(batch_slots=4, mesh=mesh)
    srv_flat = JoinServer(batch_slots=4)
    for name, r in zip("abcd", _rels(4)):
        srv_mesh.register_dataset(name, [r])
        srv_flat.register_dataset(name, [r])
    h_mesh = srv_mesh.submit_plan(_PLAN, query_id="m", seed=3)
    h_flat = srv_flat.submit_plan(_PLAN, query_id="m", seed=3)
    srv_mesh.run()
    srv_flat.run()
    for name in ("ab", "abc"):
        assert _identical(h_mesh.results()[name], h_flat.results()[name])


# -- statistical accuracy gate for plans ------------------------------------

PLAN_CFG = GateConfig(n_rels=3, replications=12)
PLAN_PSUM_CFG = GateConfig(n_rels=3, replications=12, count_rtol=2e-2)


def make_plan_backend(server: JoinServer):
    """One 3-way single-node plan per replication, served end to end."""
    def backend(rels, seed):
        names = []
        for i, r in enumerate(rels):
            name = f"rep{seed}_{i}"
            server.register_dataset(name, [r])
            names.append(name)
        plan = Plan((PlanNode(
            "node", tuple(names),
            budget=QueryBudget(error=0.5,
                               pilot_fraction=PLAN_CFG.pilot_fraction),
            max_strata=PLAN_CFG.max_strata, b_max=PLAN_CFG.b_max),))
        handle = server.submit_plan(plan, query_id=f"rep{seed}", seed=seed)
        server.run()
        res = handle.results()["node"]
        return (float(res.estimate), float(res.error_bound),
                float(res.count), res.stats)
    return backend


def test_plan_accuracy_gate_mesh1():
    rep = run_accuracy_gate(make_plan_backend(JoinServer(batch_slots=1)),
                            PLAN_CFG)
    assert rep.passed, rep.summary()
    assert rep.checked_allocation


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from jax.sharding import Mesh
from test_plan import (PLAN_CFG, PLAN_PSUM_CFG, make_plan_backend,
                       run_accuracy_gate)
from repro.runtime.join_serve import JoinServer

for d in (2, 4, 8):
    for mode, cfg in (("exact-parity", PLAN_CFG), ("psum", PLAN_PSUM_CFG)):
        mesh = Mesh(np.array(jax.devices()[:d]), ("data",))
        srv = JoinServer(batch_slots=1, mesh=mesh, serve_mode=mode)
        rep = run_accuracy_gate(make_plan_backend(srv), cfg)
        print(f"mesh{d} {mode}: {rep.summary()}", flush=True)
        assert rep.passed, (d, mode, rep.summary())
        if mode == "exact-parity":
            assert srv.diagnostics.dist_dropped_tuples == 0.0
print("PLAN-GATE-OK")
"""


@pytest.mark.slow
def test_plan_accuracy_gate_mesh_2_4_8():
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(["src", "tests"]))
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PLAN-GATE-OK" in out.stdout, out.stdout[-2000:]


def test_node_bytes_model_two_way_equal():
    """n = 2 sanity: pushdown and binary models coincide exactly."""
    m = node_bytes_model(_rels(2, rows=512))
    assert m["bytes_pushdown"] == m["bytes_binary"]
    assert m["reduction_x"] == 1.0
