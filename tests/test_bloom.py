"""Bloom sketch properties: no false negatives (ever), FPR within bound,
filter algebra (Alg. 1), Appendix-B size models."""

import jax.numpy as jnp
import numpy as np

from conftest import hypothesis_or_stubs
from repro.core import bloom

given, settings, st = hypothesis_or_stubs()

U32 = st.integers(min_value=0, max_value=2**32 - 2)


@settings(max_examples=25, deadline=None)
@given(st.lists(U32, min_size=1, max_size=300), st.integers(0, 5))
def test_no_false_negatives(keys, seed):
    ks = jnp.asarray(np.array(keys, np.uint32))
    nb = bloom.num_blocks_for(len(keys), 0.01)
    f = bloom.build(ks, jnp.ones(len(keys), bool), nb, seed)
    assert bool(bloom.contains(f, ks).all())


@settings(max_examples=10, deadline=None)
@given(st.lists(U32, min_size=1, max_size=100),
       st.lists(U32, min_size=1, max_size=100), st.integers(0, 3))
def test_union_covers_both(a, b, seed):
    nb = bloom.num_blocks_for(200, 0.01)
    fa = bloom.build(jnp.asarray(np.array(a, np.uint32)),
                     jnp.ones(len(a), bool), nb, seed)
    fb = bloom.build(jnp.asarray(np.array(b, np.uint32)),
                     jnp.ones(len(b), bool), nb, seed)
    u = bloom.union(fa, fb)
    both = jnp.asarray(np.array(a + b, np.uint32))
    assert bool(bloom.contains(u, both).all())


@settings(max_examples=10, deadline=None)
@given(st.lists(U32, min_size=1, max_size=100),
       st.lists(U32, min_size=1, max_size=100), st.integers(0, 3))
def test_intersect_superset_of_intersection(a, b, seed):
    """AND of filters contains (at least) the true intersection (§3.1)."""
    nb = bloom.num_blocks_for(200, 0.01)
    fa = bloom.build(jnp.asarray(np.array(a, np.uint32)),
                     jnp.ones(len(a), bool), nb, seed)
    fb = bloom.build(jnp.asarray(np.array(b, np.uint32)),
                     jnp.ones(len(b), bool), nb, seed)
    inter = bloom.intersect(fa, fb)
    common = sorted(set(a) & set(b))
    if common:
        ks = jnp.asarray(np.array(common, np.uint32))
        assert bool(bloom.contains(inter, ks).all())


def test_fpr_within_bound():
    n = 20_000
    keys = jnp.arange(n, dtype=jnp.uint32)
    for target in (0.1, 0.01, 0.001):
        nb = bloom.num_blocks_for(n, target)
        f = bloom.build(keys, jnp.ones(n, bool), nb, seed=3)
        probe = jnp.arange(10 * n, 12 * n, dtype=jnp.uint32)
        fpr = float(bloom.contains(f, probe).mean())
        # split-block costs a small constant vs optimal flat; allow 4x slack
        assert fpr <= max(4 * target, 5e-4), (target, fpr)
        pred = bloom.false_positive_rate(nb, n)
        assert fpr <= 3 * pred + 1e-4


def test_valid_mask_respected():
    keys = jnp.arange(100, dtype=jnp.uint32)
    valid = keys < 50
    nb = bloom.num_blocks_for(100, 0.001)
    f = bloom.build(keys, valid, nb, seed=1)
    assert bool(bloom.contains(f, keys[:50]).all())
    # invalid keys mostly absent (none were added)
    assert float(bloom.contains(f, keys[50:]).mean()) < 0.2


def test_eq27_sizing_monotonic():
    assert bloom.num_blocks_for(1000, 0.01) <= bloom.num_blocks_for(
        10_000, 0.01)
    assert bloom.num_blocks_for(1000, 0.01) <= bloom.num_blocks_for(
        1000, 0.001)


def test_counting_filter_remove():
    nb = 64
    keys = jnp.arange(100, dtype=jnp.uint32)
    f = bloom.counting_empty(nb, seed=2)
    f = bloom.counting_add(f, keys, jnp.ones(100, bool))
    assert bool(bloom.counting_contains(f, keys).all())
    f = bloom.counting_add(f, keys[:50], jnp.ones(50, bool), sign=-1)
    assert bool(bloom.counting_contains(f, keys[50:]).all())
    assert float(bloom.counting_contains(f, keys[:50]).mean()) < 0.3


def test_appendix_b_size_ordering():
    """Fig. 15: regular < counting < invertible; scalable finite."""
    n, p = 100_000, 0.01
    flat = bloom.flat_filter_bits(n, p)
    cbf = bloom.counting_filter_bits(n, p)
    ibf = bloom.invertible_filter_bits(n, p)
    sbf = bloom.scalable_filter_bits(n, p)
    assert flat < cbf < ibf
    assert sbf > 0


def test_fill_fraction_near_half_at_design_load():
    n = 50_000
    nb = bloom.num_blocks_for(n, 0.01)
    f = bloom.build(jnp.arange(n, dtype=jnp.uint32), jnp.ones(n, bool), nb)
    assert 0.2 < float(bloom.fill_fraction(f)) < 0.6


def test_scalable_filter_grows_and_merges():
    """Appendix B-III: SBF spills to new stages past capacity, never loses a
    key, and merges stage-pairwise (the paper's upstream-PR union)."""
    from repro.core.bloom import ScalableFilter
    a = ScalableFilter(initial_capacity=256, fp_rate=0.01, seed=1)
    ka = np.arange(2000, dtype=np.uint32)
    a.add(ka)
    assert len(a.stages) >= 3           # grew past the initial capacity
    assert bool(a.contains(ka).all())
    b = ScalableFilter(initial_capacity=256, fp_rate=0.01, seed=1)
    kb = np.arange(5000, 6000, dtype=np.uint32)
    b.add(kb)
    m = a.merge(b)
    assert bool(m.contains(ka).all()) and bool(m.contains(kb).all())
    fpr = float(m.contains(np.arange(10**5, 10**5 + 10**4,
                                     dtype=np.uint32)).mean())
    assert fpr < 0.15
